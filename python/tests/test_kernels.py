"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes and adversarial values with hypothesis. This is the core
correctness signal for the compute layer the Rust engine executes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.frontier import frontier_pallas
from compile.kernels.gts import gts_pallas
from compile.kernels.ref import NEG_INF, POS_INF, frontier_ref, gts_ref

MAX_ENC = 2**40  # encoded timestamps stay far below the sentinels


def enc(t, g):
    return (t << 8) | g


# ---------- deterministic cases ----------


def test_gts_matches_hand_computed():
    lts = jnp.array([[enc(1, 0), enc(1, 1)], [enc(5, 0), enc(3, 1)]], dtype=jnp.int64)
    mask = jnp.ones((2, 2), dtype=jnp.int64)
    out = gts_pallas(lts, mask)
    # row 0: (1,g1) > (1,g0); row 1: (5,g0) > (3,g1)
    np.testing.assert_array_equal(np.asarray(out), [enc(1, 1), enc(5, 0)])


def test_gts_mask_excludes_groups():
    lts = jnp.array([[enc(9, 0), enc(1, 1)]], dtype=jnp.int64)
    mask = jnp.array([[0, 1]], dtype=jnp.int64)
    out = gts_pallas(lts, mask)
    np.testing.assert_array_equal(np.asarray(out), [enc(1, 1)])


def test_gts_empty_row_is_neg_inf():
    lts = jnp.zeros((1, 4), dtype=jnp.int64)
    mask = jnp.zeros((1, 4), dtype=jnp.int64)
    out = gts_pallas(lts, mask)
    assert int(out[0]) == int(NEG_INF)


def test_frontier_empty_is_pos_inf():
    p = jnp.zeros((256,), dtype=jnp.int64)
    m = jnp.zeros((256,), dtype=jnp.int64)
    out = frontier_pallas(p, m)
    assert int(out[0]) == int(POS_INF)


def test_frontier_multi_block_accumulates():
    # min lives in the second block: exercises the grid accumulator
    p = np.full(512, enc(100, 0), dtype=np.int64)
    p[300] = enc(2, 3)
    m = np.ones(512, dtype=np.int64)
    out = frontier_pallas(jnp.asarray(p), jnp.asarray(m))
    assert int(out[0]) == enc(2, 3)


# ---------- hypothesis sweeps ----------


@st.composite
def gts_case(draw):
    b = draw(st.sampled_from([1, 2, 4, 8, 16, 64]))
    g = draw(st.integers(min_value=1, max_value=16))
    lts = draw(
        st.lists(
            st.lists(st.integers(min_value=0, max_value=MAX_ENC), min_size=g, max_size=g),
            min_size=b,
            max_size=b,
        )
    )
    mask = draw(
        st.lists(st.lists(st.integers(0, 1), min_size=g, max_size=g), min_size=b, max_size=b)
    )
    return np.array(lts, dtype=np.int64), np.array(mask, dtype=np.int64)


@settings(max_examples=60, deadline=None)
@given(gts_case())
def test_gts_kernel_equals_ref(case):
    lts, mask = case
    got = gts_pallas(jnp.asarray(lts), jnp.asarray(mask))
    want = gts_ref(jnp.asarray(lts), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@st.composite
def frontier_case(draw):
    p_len = draw(st.sampled_from([1, 2, 8, 256, 512]))
    vals = draw(
        st.lists(st.integers(min_value=0, max_value=MAX_ENC), min_size=p_len, max_size=p_len)
    )
    mask = draw(st.lists(st.integers(0, 1), min_size=p_len, max_size=p_len))
    return np.array(vals, dtype=np.int64), np.array(mask, dtype=np.int64)


@settings(max_examples=60, deadline=None)
@given(frontier_case())
def test_frontier_kernel_equals_ref(case):
    vals, mask = case
    got = frontier_pallas(jnp.asarray(vals), jnp.asarray(mask))
    want = frontier_ref(jnp.asarray(vals), jnp.asarray(mask))
    assert int(got[0]) == int(want)


def test_gts_rejects_unaligned_batch():
    # batch not a multiple of the block: explicit error, not silence
    lts = jnp.zeros((65, 4), dtype=jnp.int64)
    mask = jnp.ones((65, 4), dtype=jnp.int64)
    with pytest.raises(AssertionError):
        gts_pallas(lts, mask)
