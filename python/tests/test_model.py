"""L2 correctness: the fused commit_batch graph vs the oracle, plus the
quantile metrics computation and artifact shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels.ref import commit_batch_ref

B, G, P = 16, 16, 256


def mk(lts_rows, pending_vals):
    lts = np.zeros((B, G), dtype=np.int64)
    mask = np.zeros((B, G), dtype=np.int64)
    for i, row in enumerate(lts_rows):
        for j, v in enumerate(row):
            lts[i, j] = v
            mask[i, j] = 1
    pending = np.zeros(P, dtype=np.int64)
    pmask = np.zeros(P, dtype=np.int64)
    for i, v in enumerate(pending_vals):
        pending[i] = v
        pmask[i] = 1
    return map(jnp.asarray, (lts, mask, pending, pmask))


def test_commit_batch_deliverable_logic():
    # msg0 gts=5 deliverable (pending min 7); msg1 gts=9 blocked
    lts, mask, pending, pmask = mk([[5], [9]], [7, 8])
    gts, deliv, pmin = model.commit_batch(lts, mask, pending, pmask)
    assert int(gts[0]) == 5 and int(gts[1]) == 9
    assert int(deliv[0]) == 1 and int(deliv[1]) == 0
    assert int(pmin[0]) == 7


def test_commit_batch_empty_pending_delivers_all():
    lts, mask, pending, pmask = mk([[5], [9]], [])
    _, deliv, _ = model.commit_batch(lts, mask, pending, pmask)
    assert int(deliv[0]) == 1 and int(deliv[1]) == 1


@st.composite
def batch_case(draw):
    lts = draw(
        st.lists(
            st.lists(st.integers(1, 2**40), min_size=G, max_size=G), min_size=B, max_size=B
        )
    )
    mask = draw(st.lists(st.lists(st.integers(0, 1), min_size=G, max_size=G), min_size=B, max_size=B))
    pending = draw(st.lists(st.integers(1, 2**40), min_size=P, max_size=P))
    pmask = draw(st.lists(st.integers(0, 1), min_size=P, max_size=P))
    return tuple(
        jnp.asarray(np.array(x, dtype=np.int64)) for x in (lts, mask, pending, pmask)
    )


@settings(max_examples=25, deadline=None)
@given(batch_case())
def test_commit_batch_equals_ref(case):
    got = model.commit_batch(*case)
    want = commit_batch_ref(*case)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_quantiles_monotone_and_bounded():
    rng = np.random.default_rng(0)
    samples = jnp.asarray(rng.exponential(1e6, size=1024).astype(np.float32))
    (qs,) = model.latency_quantiles(samples)
    qs = np.asarray(qs)
    assert qs.shape == (len(model.QUANTILES),)
    assert np.all(np.diff(qs) >= 0), "quantiles must be monotone"
    assert qs[0] >= float(np.min(np.asarray(samples)))
    assert qs[-1] <= float(np.max(np.asarray(samples)))


def test_quantiles_exact_on_known_distribution():
    samples = jnp.asarray(np.arange(1024, dtype=np.float32))
    (qs,) = model.latency_quantiles(samples)
    # 50th percentile of 0..1023 is ~511.5
    assert abs(float(qs[0]) - 511.5) < 1.0
    assert abs(float(qs[3]) - 1012.8) < 2.0


def test_aot_lowering_produces_hlo_text():
    from compile import aot

    text = aot.lower_commit_batch(16)
    assert "HloModule" in text
    assert "ENTRY" in text
    tq = aot.lower_quantiles()
    assert "HloModule" in tq
