"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness
baseline; pytest checks kernel == ref on randomized shapes/values).

Timestamps are lexicographic pairs (t, g) encoded into a single int64 lane
as ``t << 8 | g`` (g < 256), which preserves the order — see
``rust/src/types/mod.rs::Ts::encode``.
"""

import jax.numpy as jnp

# sentinel bounds: encodings are non-negative, < 2**62
NEG_INF = -(2**62)  # plain ints: Pallas kernels cannot capture traced consts
POS_INF = 2**62


def gts_ref(lts, mask):
    """Global timestamps: per-row masked max (Fig. 4 line 19).

    lts:  [B, G] int64 encoded local timestamps
    mask: [B, G] int64 0/1 destination mask
    returns [B] int64 (NEG_INF where the row mask is empty)
    """
    masked = jnp.where(mask != 0, lts, NEG_INF)
    return jnp.max(masked, axis=1)


def frontier_ref(pending, pmask):
    """Delivery frontier: masked min over pending local timestamps
    (Fig. 4 line 21: a committed message delivers only below this).

    pending: [P] int64; pmask: [P] int64 0/1
    returns scalar int64 (POS_INF when nothing is pending)
    """
    masked = jnp.where(pmask != 0, pending, POS_INF)
    return jnp.min(masked)


def commit_batch_ref(lts, mask, pending, pmask):
    """Reference for the full L2 ``commit_batch`` computation."""
    gts = gts_ref(lts, mask)
    pmin = frontier_ref(pending, pmask)
    deliverable = (gts < pmin).astype(jnp.int64)
    return gts, deliverable, pmin.reshape((1,))
