"""L1 Pallas kernel: delivery-frontier reduction.

Computes the masked minimum over the pending (PROPOSED/ACCEPTED) local
timestamps — the frontier of Fig. 4 line 21: a committed message m' is
deliverable iff every pending m'' has ``LocalTS[m''] > GlobalTS[m']``,
i.e. iff ``GlobalTS[m'] < min(pending)``.

The kernel tiles the pending vector and reduces block-minima through an
accumulator in the output ref (grid iterations run sequentially on TPU,
which makes the read-modify-write safe; interpret mode preserves the
semantics on CPU).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import POS_INF

BLOCK_P = 256


def _frontier_kernel(pending_ref, pmask_ref, o_ref):
    i = pl.program_id(0)
    p = pending_ref[...]
    m = pmask_ref[...]
    block_min = jnp.min(jnp.where(m != 0, p, POS_INF))

    @pl.when(i == 0)
    def _init():
        o_ref[0] = block_min

    @pl.when(i != 0)
    def _acc():
        o_ref[0] = jnp.minimum(o_ref[0], block_min)


def frontier_pallas(pending, pmask, *, interpret=True):
    """[P] int64 x [P] int64(0/1) -> [1] int64 masked min."""
    (p,) = pending.shape
    block_p = min(BLOCK_P, p)
    assert p % block_p == 0, f"pending {p} not a multiple of block {block_p}"
    grid = (p // block_p,)
    return pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int64),
        interpret=interpret,
    )(pending, pmask)
