"""L1 Pallas kernel: batched global-timestamp resolution.

Computes, for a batch of B messages over (up to) G destination groups, the
masked lexicographic maximum of encoded local timestamps — Fig. 4 line 19
(``GlobalTS[m] = max { Lts(g) | g in dest(m) }``) vectorised over the
commit batch of the Rust leader hot path.

TPU mapping (EXPERIMENTS.md §Hardware-Adaptation): the [B, G] timestamp matrix
is tiled over the batch dimension with BlockSpec so each block fits VMEM;
the reduction is a vector-lane max, no MXU involvement. On CPU PJRT we
must lower with ``interpret=True`` (real TPU lowering emits a Mosaic
custom-call the CPU plugin cannot execute).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# batch rows per block: VMEM-friendly tile (8 KiB per block at G = 16)
BLOCK_B = 64


def _gts_kernel(lts_ref, mask_ref, o_ref):
    lts = lts_ref[...]
    mask = mask_ref[...]
    masked = jnp.where(mask != 0, lts, NEG_INF)
    o_ref[...] = jnp.max(masked, axis=1)


def gts_pallas(lts, mask, *, interpret=True):
    """[B, G] int64 x [B, G] int64(0/1) -> [B] int64 masked row max."""
    b, g = lts.shape
    block_b = min(BLOCK_B, b)
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _gts_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int64),
        interpret=interpret,
    )(lts, mask)
