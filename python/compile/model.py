"""L2 — the JAX compute graph invoked from the Rust leader hot path.

``commit_batch`` fuses the two Pallas kernels into the batched commit
computation of the WbCast leader (Fig. 4 lines 19+21):

    gts[b]         = masked lex-max of local timestamps      (kernels.gts)
    pending_min    = masked min over pending local timestamps (kernels.frontier)
    deliverable[b] = gts[b] < pending_min

All lanes are int64 (timestamps encoded ``t << 8 | g``; masks 0/1). The
ordering constraint *among* the committed batch (deliver in gts order) is
enforced by the Rust coordinator, which sorts by the returned gts.

``latency_quantiles`` is the metrics computation used by the stats
pipeline: per-quantile latency estimates over a sample buffer.

Python runs only at build time: ``compile.aot`` lowers these functions to
HLO text once; the Rust runtime loads and executes the artifacts.
"""

import jax
import jax.numpy as jnp

from .kernels.frontier import frontier_pallas
from .kernels.gts import gts_pallas

jax.config.update("jax_enable_x64", True)

#: quantiles reported by the stats pipeline (artifact bakes them in)
QUANTILES = (0.5, 0.9, 0.95, 0.99)


def commit_batch(lts, mask, pending, pmask):
    """Batched commit: global timestamps + deliverability flags.

    lts:     [B, G] int64 — encoded local timestamps per message x group
    mask:    [B, G] int64 — 1 where group g is a destination of message b
    pending: [P]    int64 — encoded local timestamps of PROPOSED/ACCEPTED
    pmask:   [P]    int64 — 1 for live pending slots

    Returns (gts [B] int64, deliverable [B] int64, pending_min [1] int64).
    """
    gts = gts_pallas(lts, mask)
    pmin = frontier_pallas(pending, pmask)
    deliverable = (gts < pmin[0]).astype(jnp.int64)
    return gts, deliverable, pmin


def latency_quantiles(samples):
    """Latency quantile sketch: [N] float32 ns -> [len(QUANTILES)] float32."""
    qs = jnp.asarray(QUANTILES, dtype=jnp.float32)
    return (jnp.quantile(samples, qs).astype(jnp.float32),)


def commit_batch_tuple(lts, mask, pending, pmask):
    """Tuple-returning wrapper for AOT export (single-output convention)."""
    return commit_batch(lts, mask, pending, pmask)
