"""AOT driver: lower the L2 computations to HLO **text** artifacts.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402  (needs x64 flag first)

#: batch-size variants compiled for the Rust engine (it pads to the next)
BATCH_SIZES = (16, 64, 256)
#: destination-group lanes (>= max groups; the paper deploys 10)
G_LANES = 16
#: pending-frontier slots (power of two, padded by the engine)
P_SLOTS = 256
#: latency sample buffer for the quantile artifact
N_SAMPLES = 1024


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_commit_batch(b: int) -> str:
    i64 = jnp.int64
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.commit_batch_tuple).lower(
        spec((b, G_LANES), i64),
        spec((b, G_LANES), i64),
        spec((P_SLOTS,), i64),
        spec((P_SLOTS,), i64),
    )
    return to_hlo_text(lowered)


def lower_quantiles() -> str:
    lowered = jax.jit(model.latency_quantiles).lower(
        jax.ShapeDtypeStruct((N_SAMPLES,), jnp.float32)
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for b in BATCH_SIZES:
        path = os.path.join(args.out_dir, f"commit_batch_b{b}.hlo.txt")
        text = lower_commit_batch(b)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    path = os.path.join(args.out_dir, "quantiles.hlo.txt")
    text = lower_quantiles()
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
