//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on real
//! threads — 10 groups x 3 replicas on an in-process transport mesh,
//! closed-loop clients, leaders committing through the **AOT-compiled
//! XLA batch engine** (JAX/Pallas `commit_batch` artifacts), and the
//! latency report computed by the XLA quantile artifact. This proves all
//! three layers compose: Rust coordinator (L3) → XLA executable (L2) →
//! Pallas kernels (L1), with Python nowhere on the request path.
//!
//!     make artifacts && cargo run --release --example e2e_cluster
//!
//! Env knobs: WBAM_E2E_SECS (default 10), WBAM_E2E_CLIENTS (default 40),
//! WBAM_E2E_DEST (default 3), WBAM_E2E_BACKEND=xla|native, and
//! WBAM_E2E_TRANSPORT=inproc|tcp|epoll (default inproc) — tcp/epoll run
//! every endpoint over real localhost sockets through the same
//! transport-generic cluster launcher the benches use.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::time::{Duration, Instant};
use wbam::client::{Client, ClientCfg};
use wbam::coordinator::{Cluster, DeliverFn};
use wbam::net::{TcpTransport, Transport};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::Node;
use wbam::runtime::{spawn_engine, QuantileEngine, XlaBackend};
use wbam::stats::Histogram;
use wbam::sync::{Arc, Mutex};
use wbam::types::{FlushPolicy, MsgId, Pid, Topology, Ts};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Launch the node set over the transport named by WBAM_E2E_TRANSPORT:
/// the in-process mesh (default), or real localhost sockets over the
/// threaded TCP transport / the epoll event loop (one endpoint per
/// node, ports from 39000).
fn launch(kind: &str, nodes: Vec<Box<dyn Node>>, cb: Arc<Mutex<DeliverFn>>) -> Cluster {
    if kind == "inproc" {
        return Cluster::launch(nodes, Some(cb));
    }
    let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
    for (i, n) in nodes.iter().enumerate() {
        addrs.insert(n.pid(), format!("127.0.0.1:{}", 39000 + i as u16).parse().unwrap());
    }
    let hosts: Vec<Vec<Box<dyn Node>>> = nodes.into_iter().map(|n| vec![n]).collect();
    Cluster::launch_hosts_over(hosts, Some(cb), FlushPolicy::default(), |pids| -> Box<dyn Transport> {
        match kind {
            "tcp" => Box::new(TcpTransport::bind(pids[0], addrs.clone()).expect("bind tcp")),
            #[cfg(target_os = "linux")]
            "epoll" => Box::new(wbam::net::EpollTransport::bind(pids[0], addrs.clone()).expect("bind epoll")),
            other => panic!("WBAM_E2E_TRANSPORT={other}: unknown transport (inproc|tcp|epoll)"),
        }
    })
}

fn main() -> anyhow::Result<()> {
    let secs = env_u64("WBAM_E2E_SECS", 10);
    let n_clients = env_u64("WBAM_E2E_CLIENTS", 40) as u32;
    let dest_groups = env_u64("WBAM_E2E_DEST", 3) as usize;
    let backend = std::env::var("WBAM_E2E_BACKEND").unwrap_or_else(|_| "xla".into());
    let transport = std::env::var("WBAM_E2E_TRANSPORT").unwrap_or_else(|_| "inproc".into());

    let topo = Topology::new(10, 1);
    println!(
        "e2e cluster: {} groups x {} replicas + {} clients (dest={}, backend={}, transport={}, {}s)",
        topo.num_groups(),
        topo.group_size(),
        n_clients,
        dest_groups,
        backend,
        transport,
        secs
    );

    // the XLA engine service thread (shared by all leaders)
    let engine = if backend == "xla" {
        Some(spawn_engine(wbam::runtime::engine::artifacts_dir())?)
    } else {
        None
    };

    let wb = WbConfig {
        hb_interval: 50_000_000, // 50 ms heartbeats
        batch_threshold: 8,      // engine path: amortise PJRT round trips
        batch_flush_after: 500_000, // …but never hold commits > 0.5 ms
        ..WbConfig::default()
    };

    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            let node = match &engine {
                Some(h) => WbNode::with_backend(p, topo.clone(), wb, Box::new(XlaBackend::new(h.clone()))),
                None => WbNode::new(p, topo.clone(), wb),
            };
            nodes.push(Box::new(node));
        }
    }
    for c in 0..n_clients {
        let pid = Pid(topo.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups, resend_after: 2_000_000_000, ..Default::default() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, 0xE2E + c as u64)));
    }

    // delivery accounting: first delivery per (message, group)
    #[derive(Default)]
    struct Acct {
        first: HashMap<(MsgId, u32), u64>,
        count: u64,
    }
    let acct = Arc::new(Mutex::new(Acct::default()));
    let acct2 = Arc::clone(&acct);
    let topo2 = topo.clone();
    let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid: Pid, m: MsgId, _gts: Ts, t: u64| {
        let mut a = acct2.lock().unwrap();
        a.count += 1;
        if let Some(g) = topo2.group_of(pid) {
            a.first.entry((m, g.0)).or_insert(t);
        }
    })));

    let t0 = Instant::now();
    let cluster = launch(&transport, nodes, cb);
    std::thread::sleep(Duration::from_secs(secs));
    let nodes = cluster.shutdown();
    let wall = t0.elapsed().as_secs_f64();

    // ---- harvest ----
    let mut h = Histogram::new();
    let mut completed = 0u64;
    let mut samples: Vec<u64> = Vec::new();
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len() as u64;
            for s in &c.completed {
                let lat = s.done_at - s.sent_at;
                h.record(lat.max(1));
                samples.push(lat);
            }
        }
    }
    let mut commits = 0u64;
    let mut delivered = 0u64;
    let mut recoveries = 0u64;
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(w) = (any as &dyn std::any::Any).downcast_ref::<WbNode>() {
            commits += w.stats.committed;
            delivered += w.stats.delivered;
            recoveries += w.stats.recoveries_started;
        }
    }
    let a = acct.lock().unwrap();

    println!("\n== results ({wall:.1}s wall) ==");
    println!("completed multicasts:    {completed} ({:.0}/s)", completed as f64 / wall);
    println!("deliveries (all nodes):  {} (callback: {})", delivered, a.count);
    println!("leader commits:          {commits}");
    println!("unexpected recoveries:   {recoveries}");
    println!(
        "client latency:          mean {:.3} ms  p50 {:.3}  p99 {:.3}  max {:.3}",
        h.mean() / 1e6,
        h.p50() as f64 / 1e6,
        h.p99() as f64 / 1e6,
        h.max() as f64 / 1e6
    );

    // latency quantiles through the second XLA artifact
    if !samples.is_empty() {
        let q = QuantileEngine::load(&wbam::runtime::engine::artifacts_dir())?;
        let qs = q.quantiles(&samples)?;
        println!(
            "XLA quantile artifact:   p50 {:.3} ms  p90 {:.3}  p95 {:.3}  p99 {:.3}",
            qs[0] / 1e6,
            qs[1] / 1e6,
            qs[2] / 1e6,
            qs[3] / 1e6
        );
    }

    assert!(completed > 0, "no progress");
    assert_eq!(recoveries, 0, "leaders were wrongly suspected");
    println!("\ne2e OK — all three layers composed (rust L3 → XLA L2 → Pallas L1)");
    Ok(())
}
