//! Partitioned, replicated key-value store — the paper's motivating use
//! case (§I: "scale fault-tolerant transaction processing systems").
//!
//! Keys are partitioned twice: by **shard** (independent ordering
//! domains, `account % SHARDS` — the per-core partitioning of the
//! sharded runtime) and, within a shard, across 4 **groups** of 3
//! replicas. Single-key writes multicast to one group; cross-partition
//! *transfers* multicast to the two groups owning the accounts. Atomic
//! multicast gives every replica of every partition the same relative
//! order for conflicting transactions, which makes the bank-transfer
//! invariant (total balance conservation) hold without any extra
//! concurrency control. Transfers never cross shards — each client and
//! each account belongs to exactly one shard.
//!
//! The bank is **durable**: every replica journals its protocol state
//! into simulated storage ([`wbam::storage::MemWal`], the exact on-disk
//! record codec), one replica is killed mid-run and restarted from its
//! journal after the workload drains — it rejoins through the recovery
//! protocol, catches up on every transfer it missed, and the final
//! replica-agreement and conservation checks include it.
//!
//!     cargo run --release --example kvstore

use std::collections::HashMap;
use wbam::invariants;
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::{Node, Outbox, TimerKind};
use wbam::sim::{SimConfig, World, MS};
use wbam::sync::{Arc, Mutex};
use wbam::types::{FlushPolicy, Gid, GidSet, MsgId, MsgMeta, Pid, ShardMap, Topology, Wire};
use wbam::util::Rng;

const SHARDS: usize = 2;
const GROUPS: usize = 4;
const ACCOUNTS: u64 = 64;
const INITIAL: i64 = 1000;

/// Ordering domain of an account: transfers stay within one shard.
fn shard_of_account(account: u64) -> usize {
    (account % SHARDS as u64) as usize
}

/// Partition (group) of an account within its shard.
fn partition(account: u64) -> Gid {
    Gid(((account / SHARDS as u64) % GROUPS as u64) as u32)
}

/// A bank transaction shipped as the multicast payload.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// move `amount` from `from` to `to` (possibly cross-partition,
    /// never cross-shard)
    Transfer { from: u64, to: u64, amount: i64 },
    /// set an account balance (single partition, setup)
    Deposit { account: u64, amount: i64 },
}

impl Op {
    fn dest(&self) -> GidSet {
        match *self {
            Op::Transfer { from, to, .. } => GidSet::from_iter([partition(from), partition(to)]),
            Op::Deposit { account, .. } => GidSet::single(partition(account)),
        }
    }
    fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(25);
        match *self {
            Op::Transfer { from, to, amount } => {
                v.push(0);
                v.extend_from_slice(&from.to_le_bytes());
                v.extend_from_slice(&to.to_le_bytes());
                v.extend_from_slice(&amount.to_le_bytes());
            }
            Op::Deposit { account, amount } => {
                v.push(1);
                v.extend_from_slice(&account.to_le_bytes());
                v.extend_from_slice(&amount.to_le_bytes());
            }
        }
        v
    }
}

/// Transactional client: issues transfers between random accounts *of
/// its shard* in a closed loop, registering each op so replicas can
/// apply payloads.
struct TxClient {
    pid: Pid,
    /// this client's shard topology (leader pids of its ordering domain)
    topo: Topology,
    shard: usize,
    rng: Rng,
    registry: Arc<Mutex<HashMap<MsgId, Op>>>,
    seq: u32,
    max: u32,
    pending: Option<(MsgId, GidSet, GidSet)>, // (id, dest, acked)
    pub done: u32,
}

impl TxClient {
    fn next(&mut self, _now: u64, out: &mut Outbox) {
        if self.seq >= self.max {
            return;
        }
        self.seq += 1;
        // random pair of distinct accounts of this shard,
        // cross-partition with high probability
        let per_shard = ACCOUNTS / SHARDS as u64;
        let x = self.rng.below(per_shard);
        let y = (x + 1 + self.rng.below(per_shard - 1)) % per_shard;
        let from = self.shard as u64 + SHARDS as u64 * x;
        let to = self.shard as u64 + SHARDS as u64 * y;
        let op = Op::Transfer { from, to, amount: self.rng.range(1, 20) as i64 };
        let id = MsgId::new(self.pid.0, self.seq);
        self.registry.lock().unwrap().insert(id, op);
        let dest = op.dest();
        let meta = MsgMeta::new(id, dest, op.encode());
        self.pending = Some((id, dest, GidSet::EMPTY));
        for g in dest.iter() {
            out.send(self.topo.initial_leader(g), Wire::Multicast { meta: meta.clone() });
        }
    }
}

impl Node for TxClient {
    fn pid(&self) -> Pid {
        self.pid
    }
    fn on_start(&mut self, now: u64, out: &mut Outbox) {
        self.next(now, out);
    }
    fn on_wire(&mut self, _from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
        let Wire::Delivered { m, g, .. } = wire else { return };
        let Some((id, dest, acked)) = &mut self.pending else { return };
        if *id != m || !dest.contains(g) {
            return;
        }
        acked.insert(g);
        if acked != dest {
            return;
        }
        self.done += 1;
        self.pending = None;
        self.next(now, out);
    }
    fn on_timer(&mut self, _t: TimerKind, _now: u64, _out: &mut Outbox) {}
}

/// One partition replica's materialised state, rebuilt from the
/// delivery trace (the per-pid projection of the shard's total order).
fn replay(
    deliveries: &[(MsgId, Gid)],
    registry: &HashMap<MsgId, Op>,
    my_shard: usize,
    my_group: Gid,
) -> HashMap<u64, i64> {
    let mut kv: HashMap<u64, i64> = (0..ACCOUNTS)
        .filter(|&a| shard_of_account(a) == my_shard && partition(a) == my_group)
        .map(|a| (a, INITIAL))
        .collect();
    for (m, _g) in deliveries {
        match registry[m] {
            Op::Transfer { from, to, amount } => {
                if shard_of_account(from) == my_shard && partition(from) == my_group {
                    *kv.get_mut(&from).unwrap() -= amount;
                }
                if shard_of_account(to) == my_shard && partition(to) == my_group {
                    *kv.get_mut(&to).unwrap() += amount;
                }
            }
            Op::Deposit { account, amount } => {
                if shard_of_account(account) == my_shard && partition(account) == my_group {
                    kv.insert(account, amount);
                }
            }
        }
    }
    kv
}

fn main() {
    let map = ShardMap::new(GROUPS, 1, SHARDS);
    let registry: Arc<Mutex<HashMap<MsgId, Op>>> = Arc::new(Mutex::new(HashMap::new()));

    // durable replicas: every member journals into simulated storage
    let wb = WbConfig { durability: true, ..WbConfig::default() };
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for s in 0..map.shards {
        let topo = map.topo(s);
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(WbNode::new(p, topo.clone(), wb)));
            }
        }
    }
    let n_clients = 6u32; // 3 per shard
    let tx_per_client = 50;
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let shard = map.client_shard(pid);
        nodes.push(Box::new(TxClient {
            pid,
            topo: map.topo(shard),
            shard,
            rng: Rng::new(0xBA2C + c as u64),
            registry: Arc::clone(&registry),
            seq: 0,
            max: tx_per_client,
            pending: None,
            done: 0,
        }));
    }
    // adaptive per-link coalescing: hold a link's wires up to 100 µs for
    // companions (no early quiet flush). Transfers tolerate the batching
    // window with zero change to atomicity or replica agreement — the
    // invariant checks below are the proof.
    let sim = SimConfig {
        flush: FlushPolicy { max_delay_us: 100, max_bytes: 1 << 20, flush_on_quiet: false },
        ..SimConfig::theory(MS)
    };
    let mut world = World::new_sharded(map, nodes, sim);
    // every member can be rebuilt from its journal on a Restart event
    for s in 0..map.shards {
        wbam::harness::enable_wb_storage(&mut world, &map.topo(s), wb);
    }
    // kill one replica (a follower of shard 0, group 0) mid-run: its
    // clients keep completing (followers send no client notifications),
    // but it misses a chunk of the committed transfer history
    let victim = Pid(1);
    world.crash_at(victim, 20 * MS);
    world.run_to_quiescence(10_000_000);
    // ...then restart it from its journal: it replays the WAL fold,
    // rejoins via the recovery protocol and catches up on every missed
    // delivery before the books are audited below
    let journaled = world.store(victim).unwrap().len();
    world.restart_at(victim, world.now() + 10 * MS);
    world.run_to_quiescence(10_000_000);
    invariants::assert_correct_sharded(&world.trace);
    for c in 0..n_clients {
        let t = world.node_as::<TxClient>(Pid(map.first_client_pid().0 + c));
        assert_eq!(t.done, tx_per_client, "client {c} stalled");
    }
    let revived = world.node_as::<WbNode>(victim);
    assert!(revived.stats.recoveries_started >= 1, "restarted replica never rejoined");
    assert!(revived.stats.delivered > 0, "restarted replica caught up nothing");

    let registry = registry.lock().unwrap();
    println!(
        "kvstore — {SHARDS} shards x {GROUPS} partitions x 3 replicas, {} cross-partition transfers",
        registry.len()
    );
    println!(
        "durable restart: {victim:?} killed at t=20ms with {journaled} journal records, \
         restarted from its WAL, rejoined (recoveries={}) and re-delivered {} transfers\n",
        revived.stats.recoveries_completed, revived.stats.delivered
    );

    // rebuild every replica's state from its delivery sequence
    let mut total_across_partitions = 0i64;
    for s in 0..map.shards {
        let topo = map.topo(s);
        for g in topo.gids() {
            let mut states = Vec::new();
            for &p in topo.members(g) {
                let dels: Vec<(MsgId, Gid)> =
                    world.trace.deliveries.iter().filter(|d| d.pid == p).map(|d| (d.m, g)).collect();
                states.push((p, replay(&dels, &registry, s, g)));
            }
            // replica agreement within the partition
            for w in states.windows(2) {
                assert_eq!(w[0].1, w[1].1, "replica divergence in shard {s} {g:?}");
            }
            let sum: i64 = states[0].1.values().sum();
            let keys = states[0].1.len();
            total_across_partitions += sum;
            println!("  shard {s} {g:?}: {keys} keys, partition balance {sum}, replicas agree ✓");
        }
    }

    let expected = ACCOUNTS as i64 * INITIAL;
    println!("\ntotal balance across shards+partitions: {total_across_partitions} (expected {expected})");
    assert_eq!(total_across_partitions, expected, "conservation violated — transfers were not atomic");
    println!("cross-partition atomicity + replica agreement: OK");
}
