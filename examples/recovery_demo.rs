//! Leader-failure demo, in two acts:
//!
//! 1. **Leader change** (the paper's crash-stop model): crash the
//!    leader of group 0 mid-run and watch the white-box recovery
//!    protocol (Fig. 4 lines 35–66) elect a new leader, resynchronise a
//!    quorum and resume delivery — with the safety checker verifying
//!    that the total order survived.
//! 2. **Process rejoin from disk** (beyond crash-stop): the same crash,
//!    but the victim journaled every promise into durable storage
//!    ([`wbam::storage`]); it restarts from the WAL fold, rejoins
//!    through the *same* recovery protocol, catches up on everything it
//!    missed, and the strict checker (which now counts it as a correct
//!    process again) stays green.
//!
//!     cargo run --release --example recovery_demo

use wbam::client::ClientCfg;
use wbam::harness::{build_world, enable_wb_storage, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::sim::MS;
use wbam::types::{Pid, Status, Topology};

fn main() {
    let delta = MS;
    let mut cfg = RunCfg::new(Proto::WbCast, 2, 4, 2, Net::Theory { delta });
    cfg.max_requests = Some(50);
    cfg.record_full = true;
    cfg.wb = WbConfig::with_failures(delta);
    cfg.resend_after = 30 * delta;
    let _ = ClientCfg::default();

    let mut world = build_world(&cfg);
    let crash_at = 20 * delta;
    world.crash_at(Pid(0), crash_at);
    world.run_until(3_000 * delta);

    println!("WbCast recovery demo — 2 groups x 3 replicas, leader p0 crashes at t = 20δ\n");

    // who leads group 0 now?
    for p in [Pid(1), Pid(2)] {
        let n = world.node_as::<WbNode>(p);
        println!(
            "  {p:?}: status={:?} cballot={:?} recoveries: started={} completed={}",
            n.status(),
            n.cballot(),
            n.stats.recoveries_started,
            n.stats.recoveries_completed
        );
    }
    let new_leader =
        [Pid(1), Pid(2)].into_iter().find(|&p| world.node_as::<WbNode>(p).status() == Status::Leader);
    println!("\nnew leader of group 0: {:?}", new_leader.expect("no leader elected"));

    // delivery timeline around the crash
    let stalled = world
        .trace
        .completions
        .windows(2)
        .map(|w| w[1].saturating_sub(w[0]))
        .max()
        .unwrap_or(0);
    println!("longest delivery stall:  {:.1} ms (recovery window)", stalled as f64 / 1e6);
    println!("completed multicasts:    {} / 200", world.trace.completions.len());
    println!("messages in flight left: {}", world.trace.incomplete());

    invariants::assert_safe(&world.trace);
    let term = invariants::check_termination(&world.trace);
    assert!(term.is_empty(), "{term:?}");
    println!("\nsafety + termination across the crash: OK");

    // ---- Act 2: kill -9 and rejoin from the journal ----
    println!("\n--- Act 2: the victim restarts from durable storage ---\n");
    cfg.wb.durability = true;
    let mut world = build_world(&cfg);
    enable_wb_storage(&mut world, &Topology::new(2, 1), cfg.wb);
    world.crash_at(Pid(0), crash_at);
    world.restart_at(Pid(0), 400 * delta);
    world.run_until(3_000 * delta);

    let journaled = world.store(Pid(0)).unwrap().len();
    let revived = world.node_as::<WbNode>(Pid(0));
    println!("  p0 journaled {journaled} records before/after the crash");
    println!(
        "  p0 after restart: status={:?} cballot={:?} recoveries: started={} completed={} re-delivered={}",
        revived.status(),
        revived.cballot(),
        revived.stats.recoveries_started,
        revived.stats.recoveries_completed,
        revived.stats.delivered,
    );
    assert!(revived.stats.recoveries_started >= 1, "p0 never rejoined");
    assert!(revived.stats.delivered > 0, "p0 caught up nothing");
    println!(
        "  completed multicasts: {} / 200; restarts recorded: {:?}",
        world.trace.completions.len(),
        world.trace.restarts.iter().map(|&(t, p)| (t / delta, p)).collect::<Vec<_>>(),
    );

    // the restart withdrew p0's crash entry: the STRICT checker applies —
    // safety spans both incarnations and termination demands a full
    // quorum including the reborn p0
    assert!(world.trace.crashes.is_empty());
    invariants::assert_correct(&world.trace);
    println!("\nstrict safety + termination across kill and rejoin: OK");
}
