//! Leader-failure demo: crash the leader of group 0 mid-run and watch
//! the white-box recovery protocol (Fig. 4 lines 35–66) elect a new
//! leader, resynchronise a quorum and resume delivery — with the
//! safety checker verifying that the total order survived.
//!
//!     cargo run --release --example recovery_demo

use wbam::client::ClientCfg;
use wbam::harness::{build_world, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::sim::MS;
use wbam::types::{Pid, Status};

fn main() {
    let delta = MS;
    let mut cfg = RunCfg::new(Proto::WbCast, 2, 4, 2, Net::Theory { delta });
    cfg.max_requests = Some(50);
    cfg.record_full = true;
    cfg.wb = WbConfig::with_failures(delta);
    cfg.resend_after = 30 * delta;
    let _ = ClientCfg::default();

    let mut world = build_world(&cfg);
    let crash_at = 20 * delta;
    world.crash_at(Pid(0), crash_at);
    world.run_until(3_000 * delta);

    println!("WbCast recovery demo — 2 groups x 3 replicas, leader p0 crashes at t = 20δ\n");

    // who leads group 0 now?
    for p in [Pid(1), Pid(2)] {
        let n = world.node_as::<WbNode>(p);
        println!(
            "  {p:?}: status={:?} cballot={:?} recoveries: started={} completed={}",
            n.status(),
            n.cballot(),
            n.stats.recoveries_started,
            n.stats.recoveries_completed
        );
    }
    let new_leader =
        [Pid(1), Pid(2)].into_iter().find(|&p| world.node_as::<WbNode>(p).status() == Status::Leader);
    println!("\nnew leader of group 0: {:?}", new_leader.expect("no leader elected"));

    // delivery timeline around the crash
    let stalled = world
        .trace
        .completions
        .windows(2)
        .map(|w| w[1].saturating_sub(w[0]))
        .max()
        .unwrap_or(0);
    println!("longest delivery stall:  {:.1} ms (recovery window)", stalled as f64 / 1e6);
    println!("completed multicasts:    {} / 200", world.trace.completions.len());
    println!("messages in flight left: {}", world.trace.incomplete());

    invariants::assert_safe(&world.trace);
    let term = invariants::check_termination(&world.trace);
    assert!(term.is_empty(), "{term:?}");
    println!("\nsafety + termination across the crash: OK");
}
