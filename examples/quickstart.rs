//! Quickstart: three replicated groups, a handful of multicasts, and the
//! resulting total order — everything the paper's abstract promises in
//! ~60 lines of user code.
//!
//!     cargo run --release --example quickstart

use wbam::harness::{build_world, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::sim::MS;
use wbam::types::Pid;

fn main() {
    // 3 groups x 3 replicas (f = 1), 2 clients multicasting to 2 random
    // groups each, LAN-like network
    let mut cfg = RunCfg::new(Proto::WbCast, 3, 2, 2, Net::Theory { delta: MS });
    cfg.max_requests = Some(5);
    cfg.record_full = true;

    let mut world = build_world(&cfg);
    world.run_to_quiescence(1_000_000);

    // machine-checked: Validity, Integrity, Ordering, Termination
    invariants::assert_correct(&world.trace);

    println!("WbCast quickstart — 3 groups x 3 replicas, 10 multicasts\n");
    println!("deliveries at each group leader (global-timestamp order):");
    for pid in [Pid(0), Pid(3), Pid(6)] {
        let seq: Vec<String> = world
            .trace
            .deliveries
            .iter()
            .filter(|d| d.pid == pid)
            .map(|d| format!("{:?}@{:?}", d.m, d.gts))
            .collect();
        println!("  {pid:?}: {}", seq.join(" → "));
    }
    println!("\nmean first-delivery latency: {:.2} ms (3δ with δ = 1 ms)", world.trace.mean_latency() / 1e6);
    println!("protocol messages sent:      {}", world.trace.sends);
    println!("safety + termination checks: OK");
}
