//! Readiness-driven TCP transport on raw `epoll` (Linux only, no new
//! dependencies): **one event-loop thread per endpoint** multiplexes the
//! listener, every accepted connection and every dialed connection,
//! retiring the thread-per-connection cost of [`super::TcpTransport`].
//!
//! Design:
//!
//! * All sockets are nonblocking. The loop sleeps in `epoll_wait` and is
//!   woken by readiness events or by an `eventfd` the send halves write
//!   after queueing a frame.
//! * **Sends** are encoded by the calling [`EpollSender`] into a
//!   complete `u32 len ++ from ++ to ++ codec` frame (the exact wire
//!   format of the threaded TCP transport, so the two interoperate) and
//!   handed to the loop over a channel. The loop appends the frame to
//!   the destination connection's queue and writes as much as the
//!   socket accepts; a partial write parks the remainder and arms
//!   `EPOLLOUT` — **backpressure never blocks a sender thread**. A
//!   connection whose unwritten backlog exceeds [`MAX_PENDING_BYTES`]
//!   drops further frames *visibly* ([`NetStats::dropped_frames`]).
//! * **Dialing** is a nonblocking `connect`: frames queue while the
//!   connect is in flight and flush when `EPOLLOUT` reports completion
//!   (`SO_ERROR` checked). Outgoing connections are cached per remote
//!   *address* — all shard traffic to one endpoint shares a socket.
//! * **Receives** run through the shared [`FrameAssembler`]: reads land
//!   in a per-connection buffer and every *complete* frame is decoded
//!   and forwarded, so frames split across arbitrary read boundaries
//!   reassemble exactly (property-tested in `tests/properties.rs`).
//! * **Dead links** need no probe: a peer close is delivered as
//!   `EPOLLRDHUP`/EOF the moment the FIN arrives, counted in
//!   [`NetStats::probes_dead`] (the readiness analogue of the threaded
//!   transport's idle-probe verdict). The connection's pending whole
//!   frames are requeued on one fresh connection
//!   ([`NetStats::reconnects_attempted`]/`reconnects_succeeded`) — the
//!   same reconnect-and-retry-once contract as the threaded transport —
//!   and dropped (counted, warned) if the retry fails too. A frame whose
//!   prefix was already written is resent whole: the receiver abandons a
//!   torn stream with its connection, so no byte ever duplicates.
//!
//! Shutdown: dropping the [`EpollTransport`] raises a stop flag, wakes
//! the loop and joins it (bounded by the 50 ms idle tick), closing every
//! connection. Frames already handed to the loop are written if the
//! sockets accept them before the stop is observed; per-link FIFO order
//! is preserved to the end.

use super::{count_syscalls, FrameAssembler, Incoming, NetStats, Transport, TransportTx};
use crate::codec;
use crate::types::{Pid, Wire};
use std::collections::{HashMap, HashSet, VecDeque};
use std::fs::File;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// Upper bound on one connection's unwritten send backlog. A peer that
/// stops reading (or a WAN link slower than the offered load) fills the
/// queue up to here; frames beyond it are dropped visibly instead of
/// blocking the event loop or growing without bound.
pub const MAX_PENDING_BYTES: usize = 64 << 20;

/// How long `epoll_wait` may sleep before rechecking the stop flag.
const IDLE_TICK_MS: i32 = 50;

/// Readiness events fetched per `epoll_wait` call.
const EVENTS_CAP: usize = 64;

/// Raw Linux syscall shims (glibc symbols; the offline image has no
/// `libc` crate). Only what the event loop needs: epoll, `eventfd` for
/// cross-thread wakeups, and a nonblocking `socket`/`connect` pair std
/// does not expose.
mod sys {
    use std::io;
    use std::net::{SocketAddr, TcpStream};
    use std::os::fd::{FromRawFd, RawFd};

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_MOD: i32 = 3;
    /// == `O_CLOEXEC`; `EFD_NONBLOCK` == `O_NONBLOCK`.
    const CLOEXEC: i32 = 0o2000000;
    const NONBLOCK: i32 = 0o4000;

    const AF_INET: i32 = 2;
    const AF_INET6: i32 = 10;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SO_ERROR: i32 = 4;
    const EINPROGRESS: i32 = 115;

    /// One readiness event, matching the kernel ABI: x86-64 packs
    /// `struct epoll_event` to 12 bytes, every other architecture uses
    /// the natural 16-byte layout (`data` at offset 8). Fields are read
    /// by value only (no references into the packed variant).
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    /// One readiness event (non-x86-64 layout; see above).
    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn connect(fd: i32, addr: *const u8, len: u32) -> i32;
        fn getsockopt(fd: i32, level: i32, optname: i32, optval: *mut i32, optlen: *mut u32) -> i32;
    }

    fn cvt(ret: i32) -> io::Result<i32> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    pub fn epoll_create() -> io::Result<RawFd> {
        // SAFETY: no pointers; kernel returns a new fd or an error code
        cvt(unsafe { epoll_create1(CLOEXEC) })
    }

    pub fn new_eventfd() -> io::Result<RawFd> {
        // SAFETY: no pointers; kernel returns a new fd or an error code
        cvt(unsafe { eventfd(0, CLOEXEC | NONBLOCK) })
    }

    pub fn add(epfd: RawFd, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        // SAFETY: `ev` is a live, correctly-laid-out (#[repr(C, packed)])
        // EpollEvent; the kernel copies it before the call returns
        cvt(unsafe { epoll_ctl(epfd, EPOLL_CTL_ADD, fd, &mut ev) })?;
        Ok(())
    }

    pub fn modify(epfd: RawFd, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        // SAFETY: as in `add`: `ev` is live and correctly laid out, and
        // the kernel copies it before the call returns
        cvt(unsafe { epoll_ctl(epfd, EPOLL_CTL_MOD, fd, &mut ev) })?;
        Ok(())
    }

    /// `epoll_wait` restarted over `EINTR`.
    pub fn wait(epfd: RawFd, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            // SAFETY: the kernel writes at most `events.len()` entries
            // into the caller's live, mutably-borrowed buffer
            let n = unsafe { epoll_wait(epfd, events.as_mut_ptr(), events.len() as i32, timeout_ms) };
            if n >= 0 {
                return Ok(n as usize);
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }

    /// Start a nonblocking TCP connect. Returns the stream (owned by a
    /// std `TcpStream` so it closes on drop) and whether the connect
    /// already completed; when `false`, completion is reported by
    /// `EPOLLOUT` and must be confirmed with [`take_socket_error`].
    ///
    /// The sockaddr is assembled by byte layout (`sockaddr_in` /
    /// `sockaddr_in6`): family in host order, port/flowinfo/address in
    /// network order — the kernel copies it, so a stack buffer suffices.
    pub fn connect_nonblocking(addr: &SocketAddr) -> io::Result<(TcpStream, bool)> {
        let mut sa = [0u8; 28];
        let (domain, len): (i32, u32) = match addr {
            SocketAddr::V4(v4) => {
                sa[0..2].copy_from_slice(&(AF_INET as u16).to_ne_bytes());
                sa[2..4].copy_from_slice(&v4.port().to_be_bytes());
                sa[4..8].copy_from_slice(&v4.ip().octets());
                (AF_INET, 16)
            }
            SocketAddr::V6(v6) => {
                sa[0..2].copy_from_slice(&(AF_INET6 as u16).to_ne_bytes());
                sa[2..4].copy_from_slice(&v6.port().to_be_bytes());
                sa[4..8].copy_from_slice(&v6.flowinfo().to_be_bytes());
                sa[8..24].copy_from_slice(&v6.ip().octets());
                sa[24..28].copy_from_slice(&v6.scope_id().to_ne_bytes());
                (AF_INET6, 28)
            }
        };
        // SAFETY: no pointers; kernel returns a new fd or an error code
        let fd = cvt(unsafe { socket(domain, SOCK_STREAM | NONBLOCK | CLOEXEC, 0) })?;
        // SAFETY: `fd` is a freshly-created, valid socket owned by nobody
        // else; the TcpStream takes sole ownership (closes it on drop)
        let stream = unsafe { TcpStream::from_raw_fd(fd) };
        // SAFETY: `sa` holds a sockaddr of `len` <= 28 bytes assembled
        // above; the kernel copies it before the call returns
        if unsafe { connect(fd, sa.as_ptr(), len) } == 0 {
            return Ok((stream, true));
        }
        let err = io::Error::last_os_error();
        if err.raw_os_error() == Some(EINPROGRESS) {
            Ok((stream, false))
        } else {
            Err(err)
        }
    }

    /// Fetch and clear the pending socket error (`SO_ERROR`): `Ok` means
    /// the nonblocking connect completed successfully.
    pub fn take_socket_error(fd: RawFd) -> io::Result<()> {
        let mut val: i32 = 0;
        let mut len: u32 = std::mem::size_of::<i32>() as u32;
        // SAFETY: `val`/`len` are live stack slots sized for SO_ERROR's
        // i32 result; the kernel writes within `len` bytes
        cvt(unsafe { getsockopt(fd, SOL_SOCKET, SO_ERROR, &mut val, &mut len) })?;
        if val == 0 {
            Ok(())
        } else {
            Err(io::Error::from_raw_os_error(val))
        }
    }
}

/// Reserved tokens; connection tokens count up from [`TOK_CONN0`] and
/// are never reused, so a stale readiness event can only miss a lookup.
const TOK_LISTENER: u64 = 0;
const TOK_WAKE: u64 = 1;
const TOK_CONN0: u64 = 2;

/// One frame handed from a send half to the event loop, already encoded
/// in the wire format (`from`/`to`/`tag` ride along for drop warnings).
struct SendCmd {
    from: Pid,
    to: Pid,
    tag: &'static str,
    frame: Vec<u8>,
}

/// An accepted (inbound) connection: read-only, like the threaded
/// transport's reader threads.
struct InState {
    stream: TcpStream,
    asm: FrameAssembler,
}

/// A dialed (outbound) connection with its unwritten frame queue.
struct OutState {
    stream: TcpStream,
    addr: SocketAddr,
    token: u64,
    /// nonblocking connect completed (writes are allowed)
    connected: bool,
    /// whole frames not yet fully written, FIFO
    queue: VecDeque<Vec<u8>>,
    /// unwritten bytes across `queue` (the backpressure gauge)
    queued_bytes: usize,
    /// bytes of `queue[0]` already written
    front_written: usize,
    /// `EPOLLOUT` currently armed
    want_out: bool,
    /// this connection IS the one-shot reconnect retry: if it dies with
    /// frames still pending they are dropped, not requeued again.
    /// Cleared once a whole frame lands (the link visibly repaired).
    retry: bool,
    /// inbound bytes on a dialed link (stray frames are forwarded; EOF
    /// is the readiness-driven peer-close detector)
    asm: FrameAssembler,
}

enum Conn {
    In(InState),
    Out(OutState),
}

/// What a readiness event did to a connection.
enum Act {
    Keep,
    /// accepted connection finished (EOF) or went bad: just drop it
    Close,
    /// dialed connection died: run the reconnect/drop policy
    Died(SocketAddr),
}

enum FlushRes {
    /// queue fully written, `EPOLLOUT` disarmed
    Idle,
    /// socket full, remainder parked, `EPOLLOUT` armed
    Blocked,
    /// write error: the connection is dead
    Dead,
}

enum ReadRes {
    Open,
    Eof,
    /// framing/decode error: the stream is unrecoverable
    Bad,
}

/// Drain the socket into the assembler, forwarding every complete frame.
fn read_into(
    stream: &TcpStream,
    asm: &mut FrameAssembler,
    incoming: &Sender<(Pid, Pid, Wire)>,
    stats: &NetStats,
) -> ReadRes {
    let mut buf = [0u8; 16384];
    loop {
        let mut s = stream;
        count_syscalls(1); // nonblocking read
        match s.read(&mut buf) {
            Ok(0) => return ReadRes::Eof,
            Ok(n) => {
                let ok = asm.push(&buf[..n], &mut |from, to, wire| {
                    let _ = incoming.send((from, to, wire));
                });
                if let Err(e) = ok {
                    // receive-side loss is a loss too: count it, then
                    // abandon the stream (framing is unrecoverable)
                    stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                    log::warn!("epoll: abandoning stream: {e}");
                    return ReadRes::Bad;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return ReadRes::Open,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return ReadRes::Eof,
        }
    }
}

/// Arm or disarm `EPOLLOUT` on a dialed connection.
fn set_interest(epfd: RawFd, o: &mut OutState, out: bool) {
    if o.want_out == out {
        return;
    }
    let ev = sys::EPOLLIN | sys::EPOLLRDHUP | if out { sys::EPOLLOUT } else { 0 };
    if sys::modify(epfd, o.stream.as_raw_fd(), ev, o.token).is_ok() {
        o.want_out = out;
    }
}

/// Write as much of the queue as the socket accepts right now.
fn flush_out(o: &mut OutState, epfd: RawFd) -> FlushRes {
    while !o.queue.is_empty() {
        let r = {
            let front = o.queue.front().expect("nonempty queue");
            let mut s = &o.stream;
            count_syscalls(1); // nonblocking write
            s.write(&front[o.front_written..])
        };
        match r {
            Ok(0) => return FlushRes::Dead,
            Ok(n) => {
                o.front_written += n;
                o.queued_bytes -= n;
                let done = o.front_written == o.queue.front().expect("nonempty queue").len();
                if done {
                    o.queue.pop_front();
                    o.front_written = 0;
                    o.retry = false; // a whole frame landed: link healthy
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                set_interest(epfd, o, true);
                return FlushRes::Blocked;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return FlushRes::Dead,
        }
    }
    set_interest(epfd, o, false);
    FlushRes::Idle
}

/// Handle one readiness event on a dialed connection.
fn out_event(
    o: &mut OutState,
    bits: u32,
    epfd: RawFd,
    incoming: &Sender<(Pid, Pid, Wire)>,
    stats: &NetStats,
    dead: &mut HashSet<SocketAddr>,
) -> Act {
    if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
        return Act::Died(o.addr);
    }
    if !o.connected && bits & sys::EPOLLOUT != 0 {
        if sys::take_socket_error(o.stream.as_raw_fd()).is_err() {
            return Act::Died(o.addr);
        }
        o.connected = true;
        if o.retry {
            stats.reconnects_succeeded.fetch_add(1, Ordering::Relaxed);
        }
        dead.remove(&o.addr);
    }
    if bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 {
        match read_into(&o.stream, &mut o.asm, incoming, stats) {
            ReadRes::Open => {}
            // EOF or garbage on a dialed link: the peer is gone — the
            // readiness-driven analogue of a dead idle-probe verdict
            _ => return Act::Died(o.addr),
        }
    }
    if o.connected && matches!(flush_out(o, epfd), FlushRes::Dead) {
        return Act::Died(o.addr);
    }
    Act::Keep
}

/// The endpoint's event loop: owns the epoll instance, the listener and
/// every connection; runs on one dedicated thread.
struct EventLoop {
    /// keeps the epoll fd open for the loop's lifetime
    _ep: File,
    epfd: RawFd,
    wake: Arc<File>,
    listener: TcpListener,
    addrs: Arc<HashMap<Pid, SocketAddr>>,
    stats: Arc<NetStats>,
    incoming: Sender<(Pid, Pid, Wire)>,
    cmds: Receiver<SendCmd>,
    stop: Arc<AtomicBool>,
    conns: HashMap<u64, Conn>,
    /// dialed connection per remote address
    out_tokens: HashMap<SocketAddr, u64>,
    /// addresses whose previous connection died: the next dial to one is
    /// a *reconnect* and is counted as such
    dead: HashSet<SocketAddr>,
    next_token: u64,
}

impl EventLoop {
    fn run(mut self) {
        let mut events = [sys::EpollEvent { events: 0, data: 0 }; EVENTS_CAP];
        'outer: loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            count_syscalls(1); // epoll_wait
            let n = match sys::wait(self.epfd, &mut events, IDLE_TICK_MS) {
                Ok(n) => n,
                Err(e) => {
                    log::warn!("epoll: wait failed, transport stopping: {e}");
                    break;
                }
            };
            for ev in events.iter().take(n) {
                let token = ev.data;
                let bits = ev.events;
                match token {
                    TOK_LISTENER => self.accept_all(),
                    TOK_WAKE => self.drain_wake(),
                    t => self.conn_event(t, bits),
                }
            }
            // drain queued sends (whether woken by the eventfd or not)
            loop {
                match self.cmds.try_recv() {
                    Ok(cmd) => self.handle_send(cmd),
                    Err(TryRecvError::Empty) => break,
                    // every handle and send half is gone: nothing can
                    // ever queue a frame or read an incoming one again
                    Err(TryRecvError::Disconnected) => break 'outer,
                }
            }
        }
    }

    fn drain_wake(&mut self) {
        let mut b = [0u8; 8];
        let mut r: &File = &self.wake;
        count_syscalls(1);
        let _ = r.read(&mut b); // reading an eventfd clears its counter
    }

    fn accept_all(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let token = self.next_token;
                    self.next_token += 1;
                    if sys::add(self.epfd, stream.as_raw_fd(), sys::EPOLLIN | sys::EPOLLRDHUP, token).is_ok() {
                        self.conns.insert(token, Conn::In(InState { stream, asm: FrameAssembler::new() }));
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn conn_event(&mut self, token: u64, bits: u32) {
        let epfd = self.epfd;
        let act = match self.conns.get_mut(&token) {
            None => return, // stale event for a closed connection
            Some(Conn::In(i)) => {
                let hup = bits & (sys::EPOLLHUP | sys::EPOLLERR) != 0;
                match read_into(&i.stream, &mut i.asm, &self.incoming, &self.stats) {
                    ReadRes::Open if !hup => Act::Keep,
                    _ => Act::Close,
                }
            }
            Some(Conn::Out(o)) => out_event(o, bits, epfd, &self.incoming, &self.stats, &mut self.dead),
        };
        match act {
            Act::Keep => {}
            Act::Close => {
                self.conns.remove(&token);
            }
            Act::Died(addr) => self.conn_failed(addr),
        }
    }

    /// A dialed connection died: tear it down, then either requeue its
    /// pending whole frames on one fresh connection (retry-once) or drop
    /// them visibly.
    fn conn_failed(&mut self, addr: SocketAddr) {
        let Some(token) = self.out_tokens.remove(&addr) else { return };
        let Some(Conn::Out(o)) = self.conns.remove(&token) else { return };
        self.stats.probes_dead.fetch_add(1, Ordering::Relaxed);
        self.dead.insert(addr);
        let OutState { stream, queue, retry, .. } = o;
        drop(stream); // closing the fd deregisters it from epoll
        if queue.is_empty() {
            return;
        }
        if retry {
            let n = queue.len() as u64;
            self.stats.dropped_frames.fetch_add(n, Ordering::Relaxed);
            log::warn!("epoll: dropping {n} queued frame(s) to {addr} after reconnect retry");
            return;
        }
        // one-shot link repair: the partially written front frame is
        // resent whole — the receiver abandoned the torn stream with the
        // connection, so nothing duplicates
        if let Err(q) = self.dial(addr, queue) {
            let n = q.len() as u64;
            self.stats.dropped_frames.fetch_add(n, Ordering::Relaxed);
            log::warn!("epoll: dropping {n} queued frame(s) to {addr}: reconnect failed");
        }
    }

    /// Open a nonblocking connection to `addr` carrying `queue`. On an
    /// immediate failure the queue is handed back for accounting.
    fn dial(&mut self, addr: SocketAddr, queue: VecDeque<Vec<u8>>) -> Result<(), VecDeque<Vec<u8>>> {
        let reconnect = self.dead.contains(&addr);
        if reconnect {
            self.stats.reconnects_attempted.fetch_add(1, Ordering::Relaxed);
        }
        count_syscalls(1); // nonblocking connect
        let (stream, connected) = match sys::connect_nonblocking(&addr) {
            Ok(x) => x,
            Err(e) => {
                log::warn!("epoll: connect to {addr} failed: {e}");
                return Err(queue);
            }
        };
        stream.set_nodelay(true).ok();
        let token = self.next_token;
        self.next_token += 1;
        // EPOLLOUT stays armed until the connect completes and the queue
        // drains; level-triggered, so nothing is missed
        if sys::add(self.epfd, stream.as_raw_fd(), sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLOUT, token).is_err() {
            return Err(queue);
        }
        if connected {
            if reconnect {
                self.stats.reconnects_succeeded.fetch_add(1, Ordering::Relaxed);
            }
            self.dead.remove(&addr);
        }
        let queued_bytes = queue.iter().map(|f| f.len()).sum();
        let state = OutState {
            stream,
            addr,
            token,
            connected,
            queue,
            queued_bytes,
            front_written: 0,
            want_out: true,
            retry: reconnect,
            asm: FrameAssembler::new(),
        };
        self.conns.insert(token, Conn::Out(state));
        self.out_tokens.insert(addr, token);
        if connected {
            let epfd = self.epfd;
            let mut died = false;
            if let Some(Conn::Out(o)) = self.conns.get_mut(&token) {
                died = matches!(flush_out(o, epfd), FlushRes::Dead);
            }
            if died {
                self.conn_failed(addr);
            }
        }
        Ok(())
    }

    fn handle_send(&mut self, cmd: SendCmd) {
        let SendCmd { from, to, tag, frame } = cmd;
        let Some(&addr) = self.addrs.get(&to) else {
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("epoll: dropping {tag} {from:?}->{to:?}: destination has no address");
            return;
        };
        let epfd = self.epfd;
        if let Some(&token) = self.out_tokens.get(&addr) {
            let Some(Conn::Out(o)) = self.conns.get_mut(&token) else { return };
            if o.queued_bytes + frame.len() > MAX_PENDING_BYTES {
                self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                log::warn!("epoll: dropping {tag} {from:?}->{to:?} ({addr}): send backlog full");
                return;
            }
            o.queued_bytes += frame.len();
            o.queue.push_back(frame);
            let died = o.connected && matches!(flush_out(o, epfd), FlushRes::Dead);
            if died {
                self.conn_failed(addr);
            }
            return;
        }
        let mut queue = VecDeque::with_capacity(4);
        queue.push_back(frame);
        if let Err(q) = self.dial(addr, queue) {
            self.stats.dropped_frames.fetch_add(q.len() as u64, Ordering::Relaxed);
            log::warn!("epoll: dropping {tag} {from:?}->{to:?} ({addr}): connect failed");
        }
    }
}

/// Send half of the epoll transport: encodes each wire into a complete
/// frame in a reused buffer and hands it to the event loop (which owns
/// every socket). Usable from any thread; all of a runtime's traffic
/// should flow through one half so per-link FIFO order is preserved.
pub struct EpollSender {
    cmds: Sender<SendCmd>,
    wake: Arc<File>,
    stats: Arc<NetStats>,
    enc: codec::Enc,
}

impl TransportTx for EpollSender {
    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        let tag = wire.tag();
        super::encode_frame(&mut self.enc, from, to, &wire);
        let cmd = SendCmd { from, to, tag, frame: self.enc.buf.clone() };
        if self.cmds.send(cmd).is_err() {
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("epoll: dropping {tag} {from:?}->{to:?}: event loop stopped");
            return;
        }
        let mut w: &File = &self.wake;
        count_syscalls(1); // eventfd wake
        let _ = w.write(&1u64.to_ne_bytes());
    }
}

/// The event-loop TCP endpoint: implements [`Transport`] with the exact
/// on-wire format and reliability contract of [`super::TcpTransport`]
/// while spawning **one thread total** instead of a listener thread plus
/// one reader thread per accepted connection. See the module docs.
pub struct EpollTransport {
    tx_half: EpollSender,
    cmds: Sender<SendCmd>,
    rx: Receiver<(Pid, Pid, Wire)>,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    wake: Arc<File>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl EpollTransport {
    /// Bind the endpoint for `pid` at `addrs[&pid]` and start its event
    /// loop. Like [`super::TcpTransport::bind`], `addrs` must map every
    /// addressable pid (including shard counterparts aliased to their
    /// endpoint's address) to the address of the endpoint hosting it.
    pub fn bind(pid: Pid, addrs: HashMap<Pid, SocketAddr>) -> io::Result<Self> {
        let listener = TcpListener::bind(addrs[&pid])?;
        listener.set_nonblocking(true)?;
        // SAFETY: the epoll fd was just created and is owned by nothing
        // else; the File takes sole ownership (closes it on drop)
        let ep = unsafe { File::from_raw_fd(sys::epoll_create()?) };
        let epfd = ep.as_raw_fd();
        // SAFETY: likewise — a fresh eventfd, solely owned by this File
        let wake = Arc::new(unsafe { File::from_raw_fd(sys::new_eventfd()?) });
        sys::add(epfd, listener.as_raw_fd(), sys::EPOLLIN, TOK_LISTENER)?;
        sys::add(epfd, wake.as_raw_fd(), sys::EPOLLIN, TOK_WAKE)?;
        let (in_tx, in_rx) = mpsc::channel();
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let stats = Arc::new(NetStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let lp = EventLoop {
            _ep: ep,
            epfd,
            wake: Arc::clone(&wake),
            listener,
            addrs: Arc::new(addrs),
            stats: Arc::clone(&stats),
            incoming: in_tx,
            cmds: cmd_rx,
            stop: Arc::clone(&stop),
            conns: HashMap::new(),
            out_tokens: HashMap::new(),
            dead: HashSet::new(),
            next_token: TOK_CONN0,
        };
        let handle = std::thread::Builder::new().name(format!("wbam-epoll-{}", pid.0)).spawn(move || lp.run())?;
        let tx_half = EpollSender {
            cmds: cmd_tx.clone(),
            wake: Arc::clone(&wake),
            stats: Arc::clone(&stats),
            enc: codec::Enc::new(),
        };
        Ok(EpollTransport { tx_half, cmds: cmd_tx, rx: in_rx, stats, stop, wake, handle: Some(handle) })
    }
}

impl Transport for EpollTransport {
    fn sender(&self) -> Box<dyn TransportTx> {
        Box::new(EpollSender {
            cmds: self.cmds.clone(),
            wake: Arc::clone(&self.wake),
            stats: Arc::clone(&self.stats),
            enc: codec::Enc::new(),
        })
    }

    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        self.tx_half.send(from, to, wire)
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        match self.rx.recv_timeout(d) {
            Ok((from, to, wire)) => Some(Incoming::Wire(from, to, wire)),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Incoming::Closed),
        }
    }

    fn net_stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }
}

impl Drop for EpollTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let mut w: &File = &self.wake;
        let _ = w.write(&1u64.to_ne_bytes());
        if let Some(h) = self.handle.take() {
            let _ = h.join(); // exits within one idle tick
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::read_frame;
    use crate::types::{Ballot, GidSet, MsgId, MsgMeta};
    use std::io::BufReader;
    use std::sync::atomic::{AtomicU16, Ordering};
    use std::time::Instant;

    fn mcast(id: u64) -> Wire {
        Wire::Multicast { meta: MsgMeta::new(MsgId(id), GidSet::single(crate::types::Gid(0)), vec![1, 2, 3]) }
    }

    /// Per-process unique localhost ports, disjoint from the ranges the
    /// threaded-TCP tests use (tests run concurrently).
    fn next_port() -> u16 {
        static NEXT: AtomicU16 = AtomicU16::new(0);
        56000 + (std::process::id() % 250) as u16 * 32 + NEXT.fetch_add(1, Ordering::Relaxed)
    }

    fn wait_until(what: &str, mut pred: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !pred() {
            assert!(Instant::now() < deadline, "timeout waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn epoll_roundtrip_and_fifo() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = EpollTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = EpollTransport::bind(Pid(2), addrs).unwrap();
        for i in 0..50 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        for i in 0..50 {
            match b.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!(from, Pid(1));
                    assert_eq!(to, Pid(2));
                    assert_eq!(meta.id, MsgId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // bidirectional: b replies over its own dialed connection
        b.send(Pid(2), Pid(1), Wire::Heartbeat { bal: Ballot::new(1, Pid(2)) });
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Heartbeat { .. })) => {}
            other => panic!("unexpected {other:?}"),
        }
        // a clean run drops nothing
        assert_eq!(a.net_stats().dropped_frames.load(Ordering::Relaxed), 0);
        assert_eq!(b.net_stats().dropped_frames.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn epoll_interoperates_with_threaded_tcp() {
        // same wire format: an epoll endpoint and a threaded endpoint
        // converse transparently
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = EpollTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = crate::net::TcpTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(7));
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(7)),
            other => panic!("unexpected {other:?}"),
        }
        b.send(Pid(2), Pid(1), mcast(8));
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(8)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn epoll_carries_batch_frames_intact() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = EpollTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = EpollTransport::bind(Pid(2), addrs).unwrap();
        let frame = Wire::Batch((0..5).map(mcast).collect());
        a.send(Pid(1), Pid(2), frame.clone());
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), w)) => assert_eq!(w, frame),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn epoll_shard_pids_share_one_connection_per_address() {
        let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
        let host_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), host_addr);
        addrs.insert(Pid(12), host_addr);
        let mut a = EpollTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut host = EpollTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(1));
        a.send(Pid(11), Pid(12), mcast(2)); // different source shard, same socket
        for expect in [(Pid(1), Pid(2), 1u64), (Pid(11), Pid(12), 2)] {
            match host.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!((from, to, meta.id.0), expect);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // detached sender half: works from another thread's state
        let mut tx = host.sender();
        tx.send(Pid(2), Pid(1), mcast(3));
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(3)),
            other => panic!("unexpected {other:?}"),
        }
    }

    /// A destination that refuses connections is counted dropped (after
    /// the async reconnect retry), and an address-less pid immediately.
    #[test]
    fn epoll_unreachable_destination_is_counted_dropped() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse::<SocketAddr>().unwrap());
        addrs.insert(Pid(7), format!("127.0.0.1:{}", next_port()).parse::<SocketAddr>().unwrap());
        let mut a = EpollTransport::bind(Pid(1), addrs).unwrap();
        let stats = a.net_stats();
        a.send(Pid(1), Pid(7), mcast(99)); // nothing listens on p7's port
        wait_until("unreachable send counted", || stats.dropped_frames.load(Ordering::Relaxed) >= 1);
        // connection-refused surfaces asynchronously; the one-shot
        // reconnect retry ran (and failed) before the frame was dropped
        assert!(stats.reconnects_attempted.load(Ordering::Relaxed) >= 1, "refused connect never retried");
        a.send(Pid(1), Pid(42), mcast(100)); // no address at all
        wait_until("address-less send counted", || stats.dropped_frames.load(Ordering::Relaxed) >= 2);
    }

    /// Acceptance (kill-one-connection): frames sent across a
    /// dropped-then-reconnected link are either delivered in FIFO order
    /// or visibly counted as dropped — never silently lost — and the
    /// repair shows up in [`NetStats::reconnects_attempted`]/
    /// [`NetStats::reconnects_succeeded`].
    #[test]
    fn epoll_dropped_link_reconnects_or_warns() {
        let a_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let b_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), a_addr);
        addrs.insert(Pid(2), b_addr);

        // raw receiver we can kill: read 3 frames on the first
        // connection, hard-close it, then collect everything resent
        let listener = TcpListener::bind(b_addr).unwrap();
        let server = std::thread::spawn(move || -> Vec<u64> {
            let mut got = Vec::new();
            let (s1, _) = listener.accept().unwrap();
            let mut r1 = BufReader::new(s1);
            for _ in 0..3 {
                let bytes = read_frame(&mut r1).unwrap();
                let Wire::Multicast { meta } = codec::decode(&bytes[8..]).unwrap() else { panic!() };
                got.push(meta.id.0);
            }
            drop(r1);
            let (s2, _) = listener.accept().unwrap();
            let mut r2 = BufReader::new(s2);
            while let Ok(bytes) = read_frame(&mut r2) {
                let Wire::Multicast { meta } = codec::decode(&bytes[8..]).unwrap() else { panic!() };
                got.push(meta.id.0);
            }
            got
        });

        let mut a = EpollTransport::bind(Pid(1), addrs).unwrap();
        let stats = a.net_stats();
        for i in 0..3 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        // let the server read + close; the event loop observes the FIN
        // as EPOLLRDHUP and tears the connection down eagerly
        std::thread::sleep(Duration::from_millis(300));
        for i in 3..8 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        std::thread::sleep(Duration::from_millis(300));
        // close our side so the server's second read loop terminates
        drop(a);
        let got = server.join().unwrap();

        let dropped = stats.dropped_frames.load(Ordering::Relaxed) as usize;
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(got, sorted, "redelivered frames out of FIFO order: {got:?}");
        assert_eq!(got.len() + dropped, 8, "silently lost frames: delivered {got:?}, dropped {dropped}");
        assert!(got.len() >= 3, "first connection frames lost: {got:?}");
        // the peer close was observed (readiness-driven probe verdict)
        // and repaired through a counted reconnect
        assert!(stats.probes_dead.load(Ordering::Relaxed) >= 1, "peer close never observed");
        assert!(stats.reconnects_attempted.load(Ordering::Relaxed) >= 1, "reconnect not counted");
        assert!(stats.reconnects_succeeded.load(Ordering::Relaxed) >= 1, "successful reconnect not counted");
    }

    /// One endpoint serving many dialing peers stays at exactly one
    /// event-loop thread (the tentpole's O(connections) -> O(1) claim,
    /// asserted structurally via thread names on /proc).
    #[test]
    fn epoll_single_thread_serves_many_connections() {
        let host_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
        addrs.insert(Pid(0), host_addr);
        let n_peers = 6u32;
        for i in 1..=n_peers {
            addrs.insert(Pid(i), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        }
        let mut host = EpollTransport::bind(Pid(0), addrs.clone()).unwrap();
        let before = count_threads_named("wbam-epoll-0");
        assert_eq!(before, 1, "one endpoint must run one event-loop thread");
        let mut peers: Vec<EpollTransport> =
            (1..=n_peers).map(|i| EpollTransport::bind(Pid(i), addrs.clone()).unwrap()).collect();
        for (i, p) in peers.iter_mut().enumerate() {
            let pid = Pid(i as u32 + 1);
            p.send(pid, Pid(0), mcast(i as u64));
        }
        let mut seen = Vec::new();
        for _ in 0..n_peers {
            match host.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(_, Pid(0), Wire::Multicast { meta })) => seen.push(meta.id.0),
                other => panic!("unexpected {other:?}"),
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n_peers as u64).collect::<Vec<_>>());
        // still exactly one thread for the host despite 6 live inbound
        // connections (the threaded transport would hold 6 readers)
        assert_eq!(count_threads_named("wbam-epoll-0"), 1);
    }

    /// Count this process's threads whose name starts with `prefix`.
    fn count_threads_named(prefix: &str) -> usize {
        let Ok(tasks) = std::fs::read_dir("/proc/self/task") else { return 0 };
        tasks
            .filter_map(|e| e.ok())
            .filter(|e| {
                std::fs::read_to_string(e.path().join("comm")).map(|c| c.trim().starts_with(prefix)).unwrap_or(false)
            })
            .count()
    }
}
