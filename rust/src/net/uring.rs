//! Completion-driven TCP transport on raw `io_uring` (Linux only, no
//! new dependencies): like [`super::epoll`], **one loop thread per
//! endpoint** owns every socket — but instead of readiness + one
//! syscall per read/write, the loop batches submissions and reaps
//! completions through shared rings, so a burst of traffic costs one
//! `io_uring_enter` rather than one syscall per frame per direction.
//!
//! Design:
//!
//! * One ring per endpoint ([`SQ_ENTRIES`] submission slots). The loop
//!   sleeps in `io_uring_enter(GETEVENTS)` with a bounded timeout and
//!   is woken early by completions or by an `eventfd` READ the send
//!   halves write after queueing a frame.
//! * **Accepts** are one multishot `ACCEPT` submission that keeps
//!   producing a completion per inbound connection until cancelled.
//! * **Receives** are multishot `RECV` with `IOSQE_BUFFER_SELECT`: the
//!   kernel picks a buffer from a registered *buffer ring*
//!   ([`RECV_BUFS`] × [`RECV_BUF_BYTES`], mmap'd once and registered
//!   with `IORING_REGISTER_PBUF_RING`), so no read buffer is passed per
//!   operation. Each completion carries a buffer id; the loop copies
//!   the bytes into the connection's [`FrameAssembler`] (which freezes
//!   complete frames into shared `Arc` payload backings — the zero-copy
//!   handoff) and immediately republishes the buffer to the kernel.
//! * **Sends** keep **one outstanding SEND per connection** and
//!   resubmit the remainder on a short write. Linked SQE chains were
//!   rejected deliberately: a short send does *not* cancel its linked
//!   successors, which would transmit later frames after a gap and
//!   corrupt the byte stream. Frames of at least [`ZC_THRESHOLD`] bytes
//!   whose front is untouched go out as `SEND_ZC`; the frame buffer is
//!   then kept alive until the kernel's NOTIF completion says the pages
//!   are no longer referenced (see `zc_held`/`Dying` below).
//! * **Contract** is identical to tcp and epoll (same wire format, so
//!   all three interoperate): per-link FIFO, bounded backlog
//!   ([`MAX_PENDING_BYTES`], overflow dropped visibly in
//!   [`NetStats::dropped_frames`]), dead links repaired by exactly one
//!   counted reconnect with whole-frame requeue, then counted drops.
//!
//! Buffer lifecycle around teardown: a dead connection may still have
//! CQEs in flight (a pending `SEND_ZC` NOTIF still references the frame
//! pages). Its buffers are parked in a `Dying` graveyard keyed by the
//! connection token and freed only when the expected number of stale
//! completions has been reaped — never while the kernel can still read
//! them.
//!
//! Availability is probed ([`uring_probe`]) with a throwaway ring +
//! `IORING_REGISTER_PROBE`: old kernels or seccomp'd CI return a
//! printable reason instead of failing mid-run, and callers (CLI,
//! tests, CI) fall back or skip on it.
//!
//! Shutdown: dropping the [`UringTransport`] raises a stop flag, wakes
//! the loop via the eventfd and joins it (bounded by the 50 ms idle
//! tick). Dropping the ring fd releases every in-flight operation.

use super::{count_syscalls, FrameAssembler, Incoming, NetStats, Transport, TransportTx};
use crate::codec;
use crate::types::{Pid, Wire};
use std::collections::{HashMap, HashSet, VecDeque};
use std::fs::File;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU16, AtomicU32, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Upper bound on one connection's unwritten send backlog (same
/// contract as the epoll transport).
pub const MAX_PENDING_BYTES: usize = 64 << 20;

/// How long `io_uring_enter(GETEVENTS)` may sleep before rechecking the
/// stop flag.
const IDLE_TICK_MS: u64 = 50;

/// Submission queue depth; the kernel sizes the completion queue at 2×.
const SQ_ENTRIES: u32 = 256;

/// Registered receive buffers: count (must be a power of two for the
/// buffer-ring mask) and size of each.
const RECV_BUFS: u32 = 32;
const RECV_BUF_BYTES: usize = 16384;

/// Buffer-group id of the one registered receive buffer ring.
const BGID: u16 = 0;

/// Frames at least this large (with nothing already written) are sent
/// with `SEND_ZC`; smaller ones take the plain copying `SEND`, whose
/// single copy is cheaper than pinning pages.
const ZC_THRESHOLD: usize = 32 * 1024;

/// Raw `io_uring` ABI (syscalls 425/426/427 via the glibc `syscall`
/// shim; the offline image has no `libc` crate). Struct layouts follow
/// `<linux/io_uring.h>`; only the fields and opcodes the loop uses.
/// Fields exist to match the kernel ABI byte-for-byte — several are
/// written for (or by) the kernel and never read from Rust.
#[allow(dead_code)]
mod sys {
    use std::io;
    use std::os::raw::{c_long, c_void};

    pub const SYS_IO_URING_SETUP: c_long = 425;
    pub const SYS_IO_URING_ENTER: c_long = 426;
    pub const SYS_IO_URING_REGISTER: c_long = 427;

    pub const PROT_READ: c_long = 1;
    pub const PROT_WRITE: c_long = 2;
    pub const MAP_SHARED: c_long = 1;
    pub const MAP_PRIVATE: c_long = 2;
    pub const MAP_ANONYMOUS: c_long = 0x20;

    /// mmap offsets selecting which ring region to map.
    pub const OFF_SQ_RING: i64 = 0;
    pub const OFF_CQ_RING: i64 = 0x8000000;
    pub const OFF_SQES: i64 = 0x10000000;

    pub const OP_ACCEPT: u8 = 13;
    pub const OP_CONNECT: u8 = 16;
    pub const OP_READ: u8 = 22;
    pub const OP_SEND: u8 = 26;
    pub const OP_RECV: u8 = 27;
    pub const OP_SEND_ZC: u8 = 47;

    /// `IOSQE_BUFFER_SELECT`: pick the buffer from `buf_group`.
    pub const SQE_BUFFER_SELECT: u8 = 1 << 5;

    /// `ioprio` bits for multishot accept/recv.
    pub const ACCEPT_MULTISHOT: u16 = 1;
    pub const RECV_MULTISHOT: u16 = 1 << 1;

    /// CQE flag bits.
    pub const CQE_F_BUFFER: u32 = 1;
    pub const CQE_F_MORE: u32 = 1 << 1;
    pub const CQE_F_NOTIF: u32 = 1 << 3;

    /// `io_uring_enter` flags.
    pub const ENTER_GETEVENTS: u32 = 1;
    pub const ENTER_EXT_ARG: u32 = 1 << 3;

    /// Feature bits reported in `io_uring_params.features`.
    pub const FEAT_SINGLE_MMAP: u32 = 1;
    pub const FEAT_EXT_ARG: u32 = 1 << 8;

    /// `io_uring_register` opcodes.
    pub const REGISTER_PROBE: u32 = 8;
    pub const REGISTER_PBUF_RING: u32 = 22;

    pub const IO_URING_OP_SUPPORTED: u16 = 1;

    pub const ENOBUFS: i32 = 105;
    pub const ETIME: i32 = 62;
    pub const EINTR: i32 = 4;
    pub const EBUSY: i32 = 16;

    pub const SOCK_CLOEXEC: u32 = 0o2000000;
    pub const MSG_NOSIGNAL: u32 = 0x4000;

    /// Offsets into the SQ/CQ ring mmaps (`io_sqring_offsets` /
    /// `io_cqring_offsets`). Fields are written by the kernel at setup
    /// and read here to locate the shared atomics.
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct SqringOffsets {
        pub head: u32,
        pub tail: u32,
        pub ring_mask: u32,
        pub ring_entries: u32,
        pub flags: u32,
        pub dropped: u32,
        pub array: u32,
        pub resv1: u32,
        pub user_addr: u64,
    }

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct CqringOffsets {
        pub head: u32,
        pub tail: u32,
        pub ring_mask: u32,
        pub ring_entries: u32,
        pub overflow: u32,
        pub cqes: u32,
        pub flags: u32,
        pub resv1: u32,
        pub user_addr: u64,
    }

    /// `struct io_uring_params` (120 bytes).
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct IoUringParams {
        pub sq_entries: u32,
        pub cq_entries: u32,
        pub flags: u32,
        pub sq_thread_cpu: u32,
        pub sq_thread_idle: u32,
        pub features: u32,
        pub wq_fd: u32,
        pub resv: [u32; 3],
        pub sq_off: SqringOffsets,
        pub cq_off: CqringOffsets,
    }

    /// One submission queue entry (64 bytes). The union-heavy kernel
    /// layout is flattened to the aliases this module uses; `rw_flags`
    /// doubles as accept flags / send flags, `off` as the connect
    /// addrlen, `buf_group` lives at the union offset 44.
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct Sqe {
        pub opcode: u8,
        pub flags: u8,
        pub ioprio: u16,
        pub fd: i32,
        pub off: u64,
        pub addr: u64,
        pub len: u32,
        pub rw_flags: u32,
        pub user_data: u64,
        pub buf_group: u16,
        pub personality: u16,
        pub splice_fd_in: i32,
        pub addr3: u64,
        pub pad2: u64,
    }

    /// One completion queue entry (16 bytes).
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct Cqe {
        pub user_data: u64,
        pub res: i32,
        pub flags: u32,
    }

    /// Argument block for `ENTER_EXT_ARG` timed waits.
    #[repr(C)]
    pub struct GeteventsArg {
        pub sigmask: u64,
        pub sigmask_sz: u32,
        pub pad: u32,
        pub ts: u64,
    }

    #[repr(C)]
    pub struct KernelTimespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    /// Header of the `IORING_REGISTER_PROBE` reply.
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct ProbeHeader {
        pub last_op: u8,
        pub ops_len: u8,
        pub resv: u16,
        pub resv2: [u32; 3],
    }

    /// One per-opcode probe entry following the header.
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    pub struct ProbeOp {
        pub op: u8,
        pub resv: u8,
        pub flags: u16,
        pub resv2: u32,
    }

    /// `struct io_uring_buf_reg` for `REGISTER_PBUF_RING`.
    #[repr(C)]
    pub struct BufReg {
        pub ring_addr: u64,
        pub ring_entries: u32,
        pub bgid: u16,
        pub flags: u16,
        pub resv: [u64; 3],
    }

    /// One entry of a registered buffer ring (`struct io_uring_buf`).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct BufRingEntry {
        pub addr: u64,
        pub len: u32,
        pub bid: u16,
        pub resv: u16,
    }

    pub const AF_INET: i32 = 2;
    pub const AF_INET6: i32 = 10;
    const SOCK_STREAM: i32 = 1;
    /// == `O_CLOEXEC` (also `SOCK_CLOEXEC` / `EFD_CLOEXEC`).
    const CLOEXEC: i32 = 0o2000000;
    const NONBLOCK: i32 = 0o4000;

    extern "C" {
        fn syscall(num: c_long, ...) -> c_long;
        fn mmap(addr: *mut c_void, len: usize, prot: c_long, flags: c_long, fd: i32, off: i64) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
    }

    /// A plain (blocking) TCP socket fd for an io_uring CONNECT — the
    /// ring supplies the asynchrony, so `O_NONBLOCK` is not needed.
    pub fn tcp_socket(domain: i32) -> io::Result<i32> {
        // SAFETY: no pointers; kernel returns a new fd or an error code
        let fd = unsafe { socket(domain, SOCK_STREAM | CLOEXEC, 0) };
        if fd < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(fd)
        }
    }

    /// Assemble a `sockaddr_in`/`sockaddr_in6` by byte layout (family
    /// in host order, port/flowinfo/address in network order); returns
    /// `(domain, bytes, len)`. The buffer must stay at a stable address
    /// until the CONNECT completion (the kernel reads it asynchronously).
    pub fn sockaddr_bytes(addr: &std::net::SocketAddr) -> (i32, [u8; 28], u32) {
        use std::net::SocketAddr;
        let mut sa = [0u8; 28];
        match addr {
            SocketAddr::V4(v4) => {
                sa[0..2].copy_from_slice(&(AF_INET as u16).to_ne_bytes());
                sa[2..4].copy_from_slice(&v4.port().to_be_bytes());
                sa[4..8].copy_from_slice(&v4.ip().octets());
                (AF_INET, sa, 16)
            }
            SocketAddr::V6(v6) => {
                sa[0..2].copy_from_slice(&(AF_INET6 as u16).to_ne_bytes());
                sa[2..4].copy_from_slice(&v6.port().to_be_bytes());
                sa[4..8].copy_from_slice(&v6.flowinfo().to_be_bytes());
                sa[8..24].copy_from_slice(&v6.ip().octets());
                sa[24..28].copy_from_slice(&v6.scope_id().to_ne_bytes());
                (AF_INET6, sa, 28)
            }
        }
    }

    fn cvt(ret: c_long) -> io::Result<c_long> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    pub fn io_uring_setup(entries: u32, params: &mut IoUringParams) -> io::Result<RawFdOwned> {
        // SAFETY: `params` is a live, #[repr(C)] IoUringParams the
        // kernel reads and fills in before the syscall returns
        let fd = cvt(unsafe { syscall(SYS_IO_URING_SETUP, entries as c_long, params as *mut IoUringParams) })?;
        Ok(RawFdOwned(fd as i32))
    }

    pub fn io_uring_enter(
        fd: i32,
        to_submit: u32,
        min_complete: u32,
        flags: u32,
        arg: *const c_void,
        argsz: usize,
    ) -> io::Result<u32> {
        // SAFETY: callers pass either a null `arg` (argsz 0) or a live
        // enter-argument struct of `argsz` bytes; the fd is a ring fd
        let ret = unsafe {
            syscall(
                SYS_IO_URING_ENTER,
                fd as c_long,
                to_submit as c_long,
                min_complete as c_long,
                flags as c_long,
                arg,
                argsz as c_long,
            )
        };
        cvt(ret).map(|n| n as u32)
    }

    pub fn io_uring_register(fd: i32, opcode: u32, arg: *const c_void, nr_args: u32) -> io::Result<()> {
        // SAFETY: callers pass an `arg` array with `nr_args` live
        // elements of the layout the opcode dictates; kernel copies it
        cvt(unsafe { syscall(SYS_IO_URING_REGISTER, fd as c_long, opcode as c_long, arg, nr_args as c_long) })?;
        Ok(())
    }

    /// The ring fd, closed on drop (wrapped in `File` upstream is not
    /// possible: it is not a regular file descriptor to hand to std IO,
    /// but close-on-drop is all we need).
    pub struct RawFdOwned(pub i32);

    impl Drop for RawFdOwned {
        fn drop(&mut self) {
            extern "C" {
                fn close(fd: i32) -> i32;
            }
            // SAFETY: sole owner of the fd; drop runs exactly once
            unsafe { close(self.0) };
        }
    }

    pub fn map(len: usize, fd: i32, off: i64) -> io::Result<*mut u8> {
        // SAFETY: null hint + kernel-chosen address; the ring fd and
        // offset come from io_uring_setup, so the mapping is valid
        let p = unsafe { mmap(std::ptr::null_mut(), len, PROT_READ | PROT_WRITE, MAP_SHARED, fd, off) };
        if p as isize == -1 {
            Err(io::Error::last_os_error())
        } else {
            Ok(p as *mut u8)
        }
    }

    pub fn map_anon(len: usize) -> io::Result<*mut u8> {
        // SAFETY: anonymous private mapping at a kernel-chosen address;
        // no fd involved
        let p = unsafe { mmap(std::ptr::null_mut(), len, PROT_READ | PROT_WRITE, MAP_PRIVATE | MAP_ANONYMOUS, -1, 0) };
        if p as isize == -1 {
            Err(io::Error::last_os_error())
        } else {
            Ok(p as *mut u8)
        }
    }

    pub fn unmap(addr: *mut u8, len: usize) {
        // SAFETY: callers pass the exact (addr, len) pair returned by
        // `map`/`map_anon`, unmapped at most once (owned by Mapping)
        unsafe { munmap(addr as *mut c_void, len) };
    }

    /// == `O_CLOEXEC` | `O_NONBLOCK` for `eventfd`.
    pub fn new_eventfd() -> io::Result<i32> {
        // SAFETY: no pointers; kernel returns a new fd or an error code
        let fd = unsafe { eventfd(0, 0o2000000 | 0o4000) };
        if fd < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(fd)
        }
    }
}

/// An owned memory mapping, unmapped on drop.
struct Mmap {
    ptr: *mut u8,
    len: usize,
}

impl Drop for Mmap {
    fn drop(&mut self) {
        sys::unmap(self.ptr, self.len);
    }
}

/// One `io_uring` instance: the ring fd, its three shared-memory
/// regions and cached pointers to the kernel-shared head/tail atomics.
/// Owned (and only touched) by the event-loop thread; `Send` so the
/// loop struct can move onto that thread.
struct Ring {
    fd: sys::RawFdOwned,
    _sq: Mmap,
    /// `None` when the kernel reports `FEAT_SINGLE_MMAP` (the CQ shares
    /// the SQ mapping).
    _cq: Option<Mmap>,
    _sqes: Mmap,
    sq_khead: *const AtomicU32,
    sq_ktail: *const AtomicU32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    sqes: *mut sys::Sqe,
    cq_khead: *const AtomicU32,
    cq_ktail: *const AtomicU32,
    cq_mask: u32,
    cqes: *const sys::Cqe,
    /// SQEs prepared but not yet published to the kernel tail.
    local_tail: u32,
    features: u32,
}

// SAFETY: the raw pointers target the ring mmaps owned by this struct;
// the struct moves to the event-loop thread once and is never shared.
unsafe impl Send for Ring {}

impl Ring {
    fn new(entries: u32) -> io::Result<Ring> {
        let mut p = sys::IoUringParams::default();
        let fd = sys::io_uring_setup(entries, &mut p)?;
        let sq_len = p.sq_off.array as usize + p.sq_entries as usize * std::mem::size_of::<u32>();
        let cq_len = p.cq_off.cqes as usize + p.cq_entries as usize * std::mem::size_of::<sys::Cqe>();
        let single = p.features & sys::FEAT_SINGLE_MMAP != 0;
        let sq_map_len = if single { sq_len.max(cq_len) } else { sq_len };
        let sq = Mmap { ptr: sys::map(sq_map_len, fd.0, sys::OFF_SQ_RING)?, len: sq_map_len };
        let (cq_base, cq) = if single {
            (sq.ptr, None)
        } else {
            let m = Mmap { ptr: sys::map(cq_len, fd.0, sys::OFF_CQ_RING)?, len: cq_len };
            (m.ptr, Some(m))
        };
        let sqes_len = p.sq_entries as usize * std::mem::size_of::<sys::Sqe>();
        let sqes = Mmap { ptr: sys::map(sqes_len, fd.0, sys::OFF_SQES)?, len: sqes_len };
        // SAFETY: offsets come from the kernel for these mappings; the
        // head/tail words are 4-aligned u32s shared with the kernel,
        // accessed through atomics exactly as the ABI prescribes.
        unsafe {
            let ring = Ring {
                sq_khead: sq.ptr.add(p.sq_off.head as usize) as *const AtomicU32,
                sq_ktail: sq.ptr.add(p.sq_off.tail as usize) as *const AtomicU32,
                sq_mask: *(sq.ptr.add(p.sq_off.ring_mask as usize) as *const u32),
                sq_entries: p.sq_entries,
                sq_array: sq.ptr.add(p.sq_off.array as usize) as *mut u32,
                sqes: sqes.ptr as *mut sys::Sqe,
                cq_khead: cq_base.add(p.cq_off.head as usize) as *const AtomicU32,
                cq_ktail: cq_base.add(p.cq_off.tail as usize) as *const AtomicU32,
                cq_mask: *(cq_base.add(p.cq_off.ring_mask as usize) as *const u32),
                cqes: cq_base.add(p.cq_off.cqes as usize) as *const sys::Cqe,
                local_tail: (*(sq.ptr.add(p.sq_off.tail as usize) as *const AtomicU32)).load(Ordering::Relaxed),
                features: p.features,
                fd,
                _sq: sq,
                _cq: cq,
                _sqes: sqes,
            };
            Ok(ring)
        }
    }

    fn sq_free(&self) -> u32 {
        // SAFETY: sq_khead points into the live SQ mapping.
        let head = unsafe { (*self.sq_khead).load(Ordering::Acquire) };
        self.sq_entries - self.local_tail.wrapping_sub(head)
    }

    /// Claim the next SQE slot, zeroed. On a full SQ the pending batch
    /// is flushed once; `None` only if the kernel cannot drain (the
    /// caller treats that as a dead ring).
    fn sqe(&mut self) -> Option<&mut sys::Sqe> {
        if self.sq_free() == 0 {
            let _ = self.enter(0, None);
            if self.sq_free() == 0 {
                return None;
            }
        }
        let idx = (self.local_tail & self.sq_mask) as usize;
        self.local_tail = self.local_tail.wrapping_add(1);
        // SAFETY: idx is masked into the array/SQE mappings; the slot
        // is free (checked above), so the kernel is not reading it.
        unsafe {
            *self.sq_array.add(idx) = idx as u32;
            let sqe = &mut *self.sqes.add(idx);
            *sqe = sys::Sqe::default();
            Some(sqe)
        }
    }

    /// Publish prepared SQEs and optionally wait for completions, with
    /// an optional timeout (`ENTER_EXT_ARG`). `-ETIME`/`-EINTR`/`-EBUSY`
    /// are normal outcomes (timeout, signal, CQ saturated) — the caller
    /// just reaps and loops.
    fn enter(&mut self, min_complete: u32, timeout_ms: Option<u64>) -> io::Result<()> {
        // SAFETY: ring pointers are valid for the ring's lifetime.
        unsafe { (*self.sq_ktail).store(self.local_tail, Ordering::Release) };
        // SAFETY: sq_khead points into the same live SQ ring mapping
        let khead = unsafe { (*self.sq_khead).load(Ordering::Acquire) };
        let to_submit = self.local_tail.wrapping_sub(khead);
        let mut flags = 0u32;
        if min_complete > 0 {
            flags |= sys::ENTER_GETEVENTS;
        }
        count_syscalls(1); // io_uring_enter
        let r = match timeout_ms {
            Some(ms) => {
                let ts = sys::KernelTimespec { tv_sec: (ms / 1000) as i64, tv_nsec: ((ms % 1000) * 1_000_000) as i64 };
                let arg = sys::GeteventsArg { sigmask: 0, sigmask_sz: 8, pad: 0, ts: &ts as *const _ as u64 };
                flags |= sys::ENTER_EXT_ARG;
                sys::io_uring_enter(
                    self.fd.0,
                    to_submit,
                    min_complete,
                    flags,
                    &arg as *const sys::GeteventsArg as *const _,
                    std::mem::size_of::<sys::GeteventsArg>(),
                )
            }
            None => sys::io_uring_enter(self.fd.0, to_submit, min_complete, flags, std::ptr::null(), 0),
        };
        match r {
            Ok(_) => Ok(()),
            Err(e) => match e.raw_os_error() {
                Some(sys::ETIME) | Some(sys::EINTR) | Some(sys::EBUSY) => Ok(()),
                _ => Err(e),
            },
        }
    }

    /// Reap every pending completion into `out`.
    fn take_cqes(&mut self, out: &mut Vec<sys::Cqe>) {
        // SAFETY: CQ pointers are valid; entries below the tail were
        // fully written by the kernel before the release-store we
        // acquire here.
        let tail = unsafe { (*self.cq_ktail).load(Ordering::Acquire) };
        // SAFETY: cq_khead points into the live CQ ring mapping; only
        // this thread writes it, so Relaxed suffices for our own head
        let mut head = unsafe { (*self.cq_khead).load(Ordering::Relaxed) };
        while head != tail {
            // SAFETY: head is masked into the CQE array; entries below
            // `tail` are fully written (acquire-load above)
            out.push(unsafe { *self.cqes.add((head & self.cq_mask) as usize) });
            head = head.wrapping_add(1);
        }
        // SAFETY: same live CQ head pointer; release makes the reaped
        // slots reusable by the kernel
        unsafe { (*self.cq_khead).store(head, Ordering::Release) };
    }

    /// Ask the kernel which opcodes it supports
    /// (`IORING_REGISTER_PROBE`); index = opcode.
    fn probe_ops(&self) -> io::Result<Vec<bool>> {
        const NOPS: usize = 64;
        #[repr(C)]
        struct ProbeBuf {
            hdr: sys::ProbeHeader,
            ops: [sys::ProbeOp; NOPS],
        }
        let mut buf = ProbeBuf { hdr: sys::ProbeHeader::default(), ops: [sys::ProbeOp::default(); NOPS] };
        sys::io_uring_register(self.fd.0, sys::REGISTER_PROBE, &mut buf as *mut ProbeBuf as *const _, NOPS as u32)?;
        Ok(buf.ops.iter().map(|o| o.flags & sys::IO_URING_OP_SUPPORTED != 0).collect())
    }
}

/// A registered provided-buffer ring (`IORING_REGISTER_PBUF_RING`):
/// `entries` buffers of `buf_size` bytes the kernel picks from for
/// multishot receives. Publishing is a ring write plus a release-store
/// of the tail (a `u16` aliased over the first entry's `resv` field,
/// per the ABI) — no syscall to return a buffer.
struct BufRing {
    ring: Mmap,
    data: Mmap,
    buf_size: usize,
    tail: u16,
    mask: u16,
}

// SAFETY: both mappings are anonymous and owned; moved to the loop
// thread once, never shared.
unsafe impl Send for BufRing {}

impl BufRing {
    fn new(ring_fd: i32, entries: u32, buf_size: usize, bgid: u16) -> io::Result<BufRing> {
        debug_assert!(entries.is_power_of_two());
        let ring_len = entries as usize * std::mem::size_of::<sys::BufRingEntry>();
        let ring = Mmap { ptr: sys::map_anon(ring_len)?, len: ring_len };
        let data = Mmap { ptr: sys::map_anon(entries as usize * buf_size)?, len: entries as usize * buf_size };
        let reg =
            sys::BufReg { ring_addr: ring.ptr as u64, ring_entries: entries, bgid, flags: 0, resv: [0; 3] };
        sys::io_uring_register(ring_fd, sys::REGISTER_PBUF_RING, &reg as *const sys::BufReg as *const _, 1)?;
        let mut br = BufRing { ring, data, buf_size, tail: 0, mask: (entries - 1) as u16 };
        for bid in 0..entries as u16 {
            br.publish(bid);
        }
        br.commit();
        Ok(br)
    }

    /// Hand buffer `bid` (back) to the kernel; visible after `commit`.
    fn publish(&mut self, bid: u16) {
        let idx = (self.tail & self.mask) as usize;
        // SAFETY: idx is masked into the ring mapping; the slot is past
        // the published tail, so the kernel is not reading it.
        unsafe {
            let e = (self.ring.ptr as *mut sys::BufRingEntry).add(idx);
            (*e).addr = self.data.ptr.add(bid as usize * self.buf_size) as u64;
            (*e).len = self.buf_size as u32;
            (*e).bid = bid;
            (*e).resv = 0;
        }
        self.tail = self.tail.wrapping_add(1);
    }

    /// Release-store the new tail (byte offset 14 = the ABI's tail slot).
    fn commit(&self) {
        // SAFETY: offset 14 is within the first 16-byte entry; the ABI
        // defines it as the ring tail, shared with the kernel.
        let tail_ptr = unsafe { self.ring.ptr.add(14) } as *const AtomicU16;
        // SAFETY: tail_ptr is 2-aligned within the owned ring mapping;
        // the release-store publishes the entries written above
        unsafe { (*tail_ptr).store(self.tail, Ordering::Release) };
    }

    fn republish(&mut self, bid: u16) {
        self.publish(bid);
        self.commit();
    }

    /// The first `len` bytes the kernel wrote into buffer `bid`.
    fn slice(&self, bid: u16, len: usize) -> &[u8] {
        let len = len.min(self.buf_size);
        // SAFETY: bid*buf_size..+len is within the data mapping; the
        // kernel wrote these bytes before completing the recv.
        unsafe { std::slice::from_raw_parts(self.data.ptr.add(bid as usize * self.buf_size), len) }
    }
}

/// Probe once whether this kernel (and sandbox) can run the transport:
/// a throwaway ring, the `EXT_ARG` timed-wait feature, every opcode the
/// loop uses, and a registered buffer ring.
fn probe_impl() -> Result<(), String> {
    let ring = Ring::new(8).map_err(|e| format!("io_uring_setup unavailable: {e}"))?;
    if ring.features & sys::FEAT_EXT_ARG == 0 {
        return Err("kernel lacks IORING_FEAT_EXT_ARG (pre-5.11)".into());
    }
    let ops = ring.probe_ops().map_err(|e| format!("IORING_REGISTER_PROBE failed: {e}"))?;
    let need: [(u8, &str); 6] = [
        (sys::OP_ACCEPT, "ACCEPT"),
        (sys::OP_CONNECT, "CONNECT"),
        (sys::OP_READ, "READ"),
        (sys::OP_SEND, "SEND"),
        (sys::OP_RECV, "RECV"),
        (sys::OP_SEND_ZC, "SEND_ZC"),
    ];
    for (op, name) in need {
        if !ops.get(op as usize).copied().unwrap_or(false) {
            return Err(format!("kernel does not support IORING_OP_{name}"));
        }
    }
    BufRing::new(ring.fd.0, 8, 4096, BGID).map_err(|e| format!("buffer-ring registration failed: {e}"))?;
    Ok(())
}

/// `Ok(())` if [`UringTransport`] can run here, else a printable reason
/// (old kernel, seccomp, missing opcode). Probed once per process.
pub fn uring_probe() -> Result<(), String> {
    static PROBE: OnceLock<Result<(), String>> = OnceLock::new();
    PROBE.get_or_init(probe_impl).clone()
}

/// Convenience boolean form of [`uring_probe`].
pub fn uring_available() -> bool {
    uring_probe().is_ok()
}

/// `user_data` encodes `(token << 3) | kind` so a completion routes to
/// its handler without a lookup. Connection tokens count up and are
/// never reused, so a stale completion can only miss a map lookup.
const KIND_ACCEPT: u64 = 0;
const KIND_WAKE: u64 = 1;
const KIND_RECV: u64 = 2;
const KIND_SEND: u64 = 3;
const KIND_CONNECT: u64 = 4;
const KIND_MASK: u64 = 7;

fn ud(token: u64, kind: u64) -> u64 {
    (token << 3) | kind
}

/// One frame handed from a send half to the event loop, already encoded
/// in the wire format (`from`/`to`/`tag` ride along for drop warnings).
struct SendCmd {
    from: Pid,
    to: Pid,
    tag: &'static str,
    frame: Vec<u8>,
}

/// One connection owned by the loop. Accepted (inbound) connections
/// have `addr == None` and never send; dialed ones own the send queue
/// and the reconnect-retry-once policy.
struct UConn {
    stream: TcpStream,
    addr: Option<SocketAddr>,
    /// stable storage the kernel reads during an async CONNECT
    sockaddr: Option<Box<[u8; 28]>>,
    connected: bool,
    /// exactly one SEND/SEND_ZC outstanding at a time (see module docs
    /// on why linked chains were rejected)
    send_inflight: bool,
    /// the outstanding send is a `SEND_ZC`
    zc_inflight: bool,
    /// the front frame's pages are pinned by a pending ZC NOTIF
    front_zc: bool,
    /// NOTIF completions the kernel still owes this connection
    zc_notifs: u32,
    /// completed frames whose pages `SEND_ZC` still references, oldest
    /// first; popped as NOTIFs arrive
    zc_held: VecDeque<Vec<u8>>,
    /// whole frames not yet fully written, FIFO
    queue: VecDeque<Vec<u8>>,
    /// unwritten bytes across `queue` (the backpressure gauge)
    queued_bytes: usize,
    /// bytes of `queue[0]` already written
    front_written: usize,
    /// this connection IS the one-shot reconnect retry (same semantics
    /// as the epoll transport; cleared once a whole frame lands)
    retry: bool,
    asm: FrameAssembler,
}

impl UConn {
    fn new(stream: TcpStream, addr: Option<SocketAddr>, connected: bool, retry: bool) -> UConn {
        UConn {
            stream,
            addr,
            sockaddr: None,
            connected,
            send_inflight: false,
            zc_inflight: false,
            front_zc: false,
            zc_notifs: 0,
            zc_held: VecDeque::new(),
            queue: VecDeque::new(),
            queued_bytes: 0,
            front_written: 0,
            retry,
            asm: FrameAssembler::new(),
        }
    }
}

/// Graveyard entry for a dead connection with completions still in
/// flight: `_bufs` keeps every buffer the kernel may still reference
/// (queued frames, ZC-pinned frames) alive until `outstanding` stale
/// send-side completions have been reaped.
struct Dying {
    _bufs: Vec<Vec<u8>>,
    outstanding: u32,
}

/// The endpoint's submission/completion loop: owns the ring, the
/// listener and every connection; runs on one dedicated thread.
struct EventLoop {
    /// Declared first so it drops first: closing the ring fd releases
    /// the kernel's in-flight operations before the buffers they
    /// reference (`bufs`, `wake_buf`, connection queues) are freed.
    ring: Ring,
    bufs: BufRing,
    listener: TcpListener,
    wake: Arc<File>,
    /// stable target of the pending eventfd READ
    wake_buf: Box<[u8; 8]>,
    addrs: Arc<HashMap<Pid, SocketAddr>>,
    stats: Arc<NetStats>,
    incoming: Sender<(Pid, Pid, Wire)>,
    cmds: Receiver<SendCmd>,
    stop: Arc<AtomicBool>,
    conns: HashMap<u64, UConn>,
    /// dialed connection per remote address
    out_tokens: HashMap<SocketAddr, u64>,
    /// addresses whose previous connection died: the next dial is a
    /// counted *reconnect*
    dead: HashSet<SocketAddr>,
    dying: HashMap<u64, Dying>,
    next_token: u64,
}

impl EventLoop {
    fn run(mut self) {
        self.arm_accept();
        self.arm_wake();
        let mut cqes: Vec<sys::Cqe> = Vec::with_capacity(128);
        'outer: loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            // queue sends first so the enter below submits them in the
            // same syscall that waits for completions
            loop {
                match self.cmds.try_recv() {
                    Ok(cmd) => self.handle_send(cmd),
                    Err(TryRecvError::Empty) => break,
                    // every handle and send half is gone: nothing can
                    // ever queue a frame or read an incoming one again
                    Err(TryRecvError::Disconnected) => break 'outer,
                }
            }
            if let Err(e) = self.ring.enter(1, Some(IDLE_TICK_MS)) {
                log::warn!("uring: enter failed, transport stopping: {e}");
                break;
            }
            cqes.clear();
            self.ring.take_cqes(&mut cqes);
            for i in 0..cqes.len() {
                let cqe = cqes[i];
                let kind = cqe.user_data & KIND_MASK;
                let token = cqe.user_data >> 3;
                match kind {
                    KIND_ACCEPT => self.on_accept(cqe),
                    KIND_WAKE => self.arm_wake(),
                    KIND_RECV => self.on_recv(token, cqe),
                    KIND_SEND => self.on_send(token, cqe),
                    KIND_CONNECT => self.on_connect(token, cqe),
                    _ => {}
                }
            }
        }
    }

    /// One multishot ACCEPT covers the listener's lifetime (re-armed if
    /// the kernel retires it).
    fn arm_accept(&mut self) {
        let fd = self.listener.as_raw_fd();
        if let Some(sqe) = self.ring.sqe() {
            sqe.opcode = sys::OP_ACCEPT;
            sqe.fd = fd;
            sqe.ioprio = sys::ACCEPT_MULTISHOT;
            sqe.rw_flags = sys::SOCK_CLOEXEC;
            sqe.user_data = ud(0, KIND_ACCEPT);
        }
    }

    /// One READ on the eventfd; completes per wake, re-armed each time.
    fn arm_wake(&mut self) {
        let fd = self.wake.as_raw_fd();
        let addr = self.wake_buf.as_mut_ptr() as u64;
        if let Some(sqe) = self.ring.sqe() {
            sqe.opcode = sys::OP_READ;
            sqe.fd = fd;
            sqe.addr = addr;
            sqe.len = 8;
            sqe.user_data = ud(0, KIND_WAKE);
        }
    }

    /// Multishot RECV with kernel-selected registered buffers.
    fn arm_recv(&mut self, token: u64) {
        let Some(c) = self.conns.get(&token) else { return };
        let fd = c.stream.as_raw_fd();
        if let Some(sqe) = self.ring.sqe() {
            sqe.opcode = sys::OP_RECV;
            sqe.fd = fd;
            sqe.ioprio = sys::RECV_MULTISHOT;
            sqe.flags = sys::SQE_BUFFER_SELECT;
            sqe.buf_group = BGID;
            sqe.user_data = ud(token, KIND_RECV);
        }
    }

    fn on_accept(&mut self, cqe: sys::Cqe) {
        if cqe.flags & sys::CQE_F_MORE == 0 {
            self.arm_accept(); // multishot retired (e.g. transient error)
        }
        if cqe.res < 0 {
            return;
        }
        // SAFETY: a non-negative ACCEPT result is a fresh socket fd
        // owned by no one else.
        let stream = unsafe { TcpStream::from_raw_fd(cqe.res) };
        stream.set_nodelay(true).ok();
        let token = self.next_token;
        self.next_token += 1;
        self.conns.insert(token, UConn::new(stream, None, true, false));
        self.arm_recv(token);
    }

    fn on_recv(&mut self, token: u64, cqe: sys::Cqe) {
        let bid = (cqe.flags & sys::CQE_F_BUFFER != 0).then_some((cqe.flags >> 16) as u16);
        if !self.conns.contains_key(&token) {
            // stale completion for a torn-down connection: recycle the
            // buffer, account the graveyard, done
            if let Some(b) = bid {
                self.bufs.republish(b);
            }
            self.reap_dying(token, KIND_RECV);
            return;
        }
        let mut bad = false;
        if cqe.res > 0 {
            if let Some(b) = bid {
                let chunk = self.bufs.slice(b, cqe.res as usize);
                let c = self.conns.get_mut(&token).expect("presence checked");
                let incoming = &self.incoming;
                if let Err(e) = c.asm.push(chunk, &mut |from, to, wire| {
                    let _ = incoming.send((from, to, wire));
                }) {
                    // receive-side loss is a loss too: count it, then
                    // abandon the stream (framing is unrecoverable)
                    self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                    log::warn!("uring: abandoning stream: {e}");
                    bad = true;
                }
            }
        }
        if let Some(b) = bid {
            self.bufs.republish(b);
        }
        // -ENOBUFS just means the buffer ring ran dry for a moment: the
        // republishes above refilled it, so re-arm and continue
        if bad || cqe.res == 0 || (cqe.res < 0 && cqe.res != -sys::ENOBUFS) {
            self.conn_dead(token);
            return;
        }
        if cqe.flags & sys::CQE_F_MORE == 0 {
            self.arm_recv(token);
        }
    }

    fn on_connect(&mut self, token: u64, cqe: sys::Cqe) {
        let addr = match self.conns.get_mut(&token) {
            None => return,
            Some(c) => {
                c.sockaddr = None; // kernel is done with the sockaddr
                c.addr
            }
        };
        if cqe.res < 0 {
            if let Some(a) = addr {
                self.conn_failed(a);
            }
            return;
        }
        let retry = {
            let c = self.conns.get_mut(&token).expect("presence checked");
            c.connected = true;
            c.retry
        };
        if retry {
            self.stats.reconnects_succeeded.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(a) = addr {
            self.dead.remove(&a);
        }
        self.arm_recv(token);
        self.pump_send(token);
    }

    /// Submit the next SEND/SEND_ZC if the connection is idle. Exactly
    /// one op per connection is in flight; a short write resubmits the
    /// remainder (as a plain SEND — at most one ZC op, hence one NOTIF,
    /// per frame, which keeps the `zc_held` accounting FIFO).
    fn pump_send(&mut self, token: u64) {
        let (fd, ptr, len, zc) = {
            let Some(c) = self.conns.get_mut(&token) else { return };
            if c.send_inflight || !c.connected {
                return;
            }
            let Some(front) = c.queue.front() else { return };
            let remaining = front.len() - c.front_written;
            let zc = c.front_written == 0 && remaining >= ZC_THRESHOLD;
            // SAFETY: pointer into the front frame's heap buffer, which
            // stays in `queue` (or moves whole into `zc_held`/`Dying`)
            // until this op's completions are reaped.
            let ptr = unsafe { front.as_ptr().add(c.front_written) } as u64;
            (c.stream.as_raw_fd(), ptr, remaining as u32, zc)
        };
        let Some(sqe) = self.ring.sqe() else { return };
        sqe.opcode = if zc { sys::OP_SEND_ZC } else { sys::OP_SEND };
        sqe.fd = fd;
        sqe.addr = ptr;
        sqe.len = len;
        sqe.rw_flags = sys::MSG_NOSIGNAL;
        sqe.user_data = ud(token, KIND_SEND);
        let c = self.conns.get_mut(&token).expect("still present");
        c.send_inflight = true;
        c.zc_inflight = zc;
    }

    fn on_send(&mut self, token: u64, cqe: sys::Cqe) {
        if !self.conns.contains_key(&token) {
            self.reap_dying(token, KIND_SEND);
            return;
        }
        if cqe.flags & sys::CQE_F_NOTIF != 0 {
            // the kernel released the pages of the oldest pinned frame
            let c = self.conns.get_mut(&token).expect("presence checked");
            c.zc_notifs = c.zc_notifs.saturating_sub(1);
            if c.zc_held.pop_front().is_none() {
                // NOTIF beat the frame's completion: unpin the front
                c.front_zc = false;
            }
            return;
        }
        let (failed, addr) = {
            let c = self.conns.get_mut(&token).expect("presence checked");
            c.send_inflight = false;
            let was_zc = c.zc_inflight;
            c.zc_inflight = false;
            if was_zc && cqe.flags & sys::CQE_F_MORE != 0 {
                c.zc_notifs += 1; // a NOTIF will follow for this op
                c.front_zc = true;
            }
            if cqe.res < 0 {
                (true, c.addr)
            } else {
                let n = cqe.res as usize;
                c.front_written += n;
                c.queued_bytes -= n;
                let done = c.front_written >= c.queue.front().map_or(0, |f| f.len());
                if done {
                    let f = c.queue.pop_front().expect("front exists");
                    c.front_written = 0;
                    c.retry = false; // a whole frame landed: link healthy
                    if c.front_zc {
                        c.zc_held.push_back(f); // pinned until its NOTIF
                        c.front_zc = false;
                    }
                }
                (false, None)
            }
        };
        if failed {
            match addr {
                Some(a) => self.conn_failed(a),
                None => {
                    if let Some(c) = self.conns.remove(&token) {
                        self.park_dying(token, c);
                    }
                }
            }
            return;
        }
        self.pump_send(token);
    }

    /// A connection hit EOF or an unrecoverable error.
    fn conn_dead(&mut self, token: u64) {
        match self.conns.get(&token).and_then(|c| c.addr) {
            Some(addr) => self.conn_failed(addr),
            None => {
                if let Some(c) = self.conns.remove(&token) {
                    self.park_dying(token, c);
                }
            }
        }
    }

    /// Tear down a dead connection whose kernel-side completions may
    /// still be in flight: park every buffer the kernel could still
    /// read until the expected stale completions are reaped.
    fn park_dying(&mut self, token: u64, c: UConn) {
        let outstanding = c.zc_notifs + if c.send_inflight { 1 + c.zc_inflight as u32 } else { 0 };
        if outstanding == 0 {
            return; // nothing in flight: dropping `c` frees everything
        }
        let mut bufs: Vec<Vec<u8>> = c.zc_held.into();
        bufs.extend(c.queue);
        self.dying.insert(token, Dying { _bufs: bufs, outstanding });
    }

    /// A stale send-side completion (data or NOTIF) for a parked
    /// connection arrived: one fewer reason to keep its buffers.
    fn reap_dying(&mut self, token: u64, kind: u64) {
        if kind != KIND_SEND {
            return;
        }
        if let Some(d) = self.dying.get_mut(&token) {
            d.outstanding = d.outstanding.saturating_sub(1);
            if d.outstanding == 0 {
                self.dying.remove(&token);
            }
        }
    }

    /// A dialed connection died: tear it down, then either requeue its
    /// pending whole frames on one fresh connection (retry-once) or
    /// drop them visibly. The originals ride into the graveyard whole
    /// (the kernel may still reference them); the retry sends clones.
    fn conn_failed(&mut self, addr: SocketAddr) {
        let Some(token) = self.out_tokens.remove(&addr) else { return };
        let Some(c) = self.conns.remove(&token) else { return };
        self.stats.probes_dead.fetch_add(1, Ordering::Relaxed);
        self.dead.insert(addr);
        let retry = c.retry;
        let pending: VecDeque<Vec<u8>> = c.queue.iter().cloned().collect();
        self.park_dying(token, c);
        if pending.is_empty() {
            return;
        }
        if retry {
            let n = pending.len() as u64;
            self.stats.dropped_frames.fetch_add(n, Ordering::Relaxed);
            log::warn!("uring: dropping {n} queued frame(s) to {addr} after reconnect retry");
            return;
        }
        // one-shot link repair: the partially written front frame is
        // resent whole — the receiver abandoned the torn stream with
        // the connection, so no byte ever duplicates
        if let Err(q) = self.dial(addr, pending) {
            let n = q.len() as u64;
            self.stats.dropped_frames.fetch_add(n, Ordering::Relaxed);
            log::warn!("uring: dropping {n} queued frame(s) to {addr}: reconnect failed");
        }
    }

    /// Open a connection to `addr` carrying `queue`: a socket now, an
    /// async CONNECT through the ring. On an immediate failure the
    /// queue is handed back for accounting.
    fn dial(&mut self, addr: SocketAddr, queue: VecDeque<Vec<u8>>) -> Result<(), VecDeque<Vec<u8>>> {
        let reconnect = self.dead.contains(&addr);
        if reconnect {
            self.stats.reconnects_attempted.fetch_add(1, Ordering::Relaxed);
        }
        let (domain, sa, sa_len) = sys::sockaddr_bytes(&addr);
        count_syscalls(1); // socket
        let fd = match sys::tcp_socket(domain) {
            Ok(fd) => fd,
            Err(e) => {
                log::warn!("uring: socket for {addr} failed: {e}");
                return Err(queue);
            }
        };
        // SAFETY: fresh fd from socket(2), owned by no one else.
        let stream = unsafe { TcpStream::from_raw_fd(fd) };
        stream.set_nodelay(true).ok();
        let sa_box = Box::new(sa);
        let sa_ptr = sa_box.as_ptr() as u64;
        let token = self.next_token;
        self.next_token += 1;
        {
            let Some(sqe) = self.ring.sqe() else { return Err(queue) };
            sqe.opcode = sys::OP_CONNECT;
            sqe.fd = fd;
            sqe.addr = sa_ptr;
            sqe.off = sa_len as u64;
            sqe.user_data = ud(token, KIND_CONNECT);
        }
        let queued_bytes = queue.iter().map(|f| f.len()).sum();
        let mut c = UConn::new(stream, Some(addr), false, reconnect);
        c.sockaddr = Some(sa_box);
        c.queue = queue;
        c.queued_bytes = queued_bytes;
        self.conns.insert(token, c);
        self.out_tokens.insert(addr, token);
        Ok(())
    }

    fn handle_send(&mut self, cmd: SendCmd) {
        let SendCmd { from, to, tag, frame } = cmd;
        let Some(&addr) = self.addrs.get(&to) else {
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("uring: dropping {tag} {from:?}->{to:?}: destination has no address");
            return;
        };
        if let Some(&token) = self.out_tokens.get(&addr) {
            {
                let Some(c) = self.conns.get_mut(&token) else { return };
                if c.queued_bytes + frame.len() > MAX_PENDING_BYTES {
                    self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                    log::warn!("uring: dropping {tag} {from:?}->{to:?} ({addr}): send backlog full");
                    return;
                }
                c.queued_bytes += frame.len();
                c.queue.push_back(frame);
            }
            self.pump_send(token);
            return;
        }
        let mut queue = VecDeque::with_capacity(4);
        queue.push_back(frame);
        if let Err(q) = self.dial(addr, queue) {
            self.stats.dropped_frames.fetch_add(q.len() as u64, Ordering::Relaxed);
            log::warn!("uring: dropping {tag} {from:?}->{to:?} ({addr}): connect failed");
        }
    }
}

/// Send half of the io_uring transport: encodes each wire into a
/// complete frame in a reused buffer and hands it to the event loop
/// (which owns the ring and every socket). Usable from any thread.
pub struct UringSender {
    cmds: Sender<SendCmd>,
    wake: Arc<File>,
    stats: Arc<NetStats>,
    enc: codec::Enc,
}

impl TransportTx for UringSender {
    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        let tag = wire.tag();
        super::encode_frame(&mut self.enc, from, to, &wire);
        let cmd = SendCmd { from, to, tag, frame: self.enc.buf.clone() };
        if self.cmds.send(cmd).is_err() {
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("uring: dropping {tag} {from:?}->{to:?}: event loop stopped");
            return;
        }
        let mut w: &File = &self.wake;
        count_syscalls(1); // eventfd wake
        let _ = w.write(&1u64.to_ne_bytes());
    }
}

/// The io_uring endpoint: implements [`Transport`] with the exact
/// on-wire format and reliability contract of [`super::TcpTransport`]
/// and [`super::EpollTransport`] (all three interoperate) while running
/// one loop thread whose IO is batched through a shared ring. See the
/// module docs.
pub struct UringTransport {
    tx_half: UringSender,
    cmds: Sender<SendCmd>,
    rx: Receiver<(Pid, Pid, Wire)>,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    wake: Arc<File>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl UringTransport {
    /// Bind the endpoint for `pid` at `addrs[&pid]` and start its loop.
    /// Fails with [`io::ErrorKind::Unsupported`] (carrying the
    /// [`uring_probe`] reason) where the kernel or sandbox cannot run
    /// io_uring — callers fall back to another transport on that.
    pub fn bind(pid: Pid, addrs: HashMap<Pid, SocketAddr>) -> io::Result<Self> {
        if let Err(reason) = uring_probe() {
            return Err(io::Error::new(io::ErrorKind::Unsupported, reason));
        }
        let listener = TcpListener::bind(addrs[&pid])?;
        let ring = Ring::new(SQ_ENTRIES)?;
        let bufs = BufRing::new(ring.fd.0, RECV_BUFS, RECV_BUF_BYTES, BGID)?;
        // SAFETY: fresh eventfd owned by no one else.
        let wake = Arc::new(unsafe { File::from_raw_fd(sys::new_eventfd()?) });
        let (in_tx, in_rx) = mpsc::channel();
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let stats = Arc::new(NetStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let lp = EventLoop {
            ring,
            bufs,
            listener,
            wake: Arc::clone(&wake),
            wake_buf: Box::new([0u8; 8]),
            addrs: Arc::new(addrs),
            stats: Arc::clone(&stats),
            incoming: in_tx,
            cmds: cmd_rx,
            stop: Arc::clone(&stop),
            conns: HashMap::new(),
            out_tokens: HashMap::new(),
            dead: HashSet::new(),
            dying: HashMap::new(),
            next_token: 1,
        };
        let handle = std::thread::Builder::new().name(format!("wbam-uring-{}", pid.0)).spawn(move || lp.run())?;
        let tx_half = UringSender {
            cmds: cmd_tx.clone(),
            wake: Arc::clone(&wake),
            stats: Arc::clone(&stats),
            enc: codec::Enc::new(),
        };
        Ok(UringTransport { tx_half, cmds: cmd_tx, rx: in_rx, stats, stop, wake, handle: Some(handle) })
    }
}

impl Transport for UringTransport {
    fn sender(&self) -> Box<dyn TransportTx> {
        Box::new(UringSender {
            cmds: self.cmds.clone(),
            wake: Arc::clone(&self.wake),
            stats: Arc::clone(&self.stats),
            enc: codec::Enc::new(),
        })
    }

    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        self.tx_half.send(from, to, wire)
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        match self.rx.recv_timeout(d) {
            Ok((from, to, wire)) => Some(Incoming::Wire(from, to, wire)),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Incoming::Closed),
        }
    }

    fn net_stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }
}

impl Drop for UringTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let mut w: &File = &self.wake;
        let _ = w.write(&1u64.to_ne_bytes());
        if let Some(h) = self.handle.take() {
            let _ = h.join(); // exits within one idle tick
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::read_frame;
    use crate::types::{Ballot, GidSet, MsgId, MsgMeta};
    use std::io::BufReader;
    use std::sync::atomic::{AtomicU16 as PortCounter, Ordering};
    use std::time::Instant;

    /// Every test self-gates on the runtime probe: on kernels or
    /// sandboxes without io_uring it prints the reason and passes
    /// vacuously (the CI `uring` job greps for these skips).
    fn uring_or_skip(test: &str) -> bool {
        match uring_probe() {
            Ok(()) => true,
            Err(reason) => {
                eprintln!("SKIP {test}: io_uring unavailable: {reason}");
                false
            }
        }
    }

    fn mcast(id: u64) -> Wire {
        Wire::Multicast { meta: MsgMeta::new(MsgId(id), GidSet::single(crate::types::Gid(0)), vec![1, 2, 3]) }
    }

    /// Per-process unique localhost ports, disjoint from the ranges the
    /// tcp/epoll tests use (tests run concurrently).
    fn next_port() -> u16 {
        static NEXT: PortCounter = PortCounter::new(0);
        39000 + (std::process::id() % 90) as u16 * 32 + NEXT.fetch_add(1, Ordering::Relaxed)
    }

    fn wait_until(what: &str, mut pred: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !pred() {
            assert!(Instant::now() < deadline, "timeout waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn uring_roundtrip_and_fifo() {
        if !uring_or_skip("uring_roundtrip_and_fifo") {
            return;
        }
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = UringTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = UringTransport::bind(Pid(2), addrs).unwrap();
        for i in 0..50 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        for i in 0..50 {
            match b.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!(from, Pid(1));
                    assert_eq!(to, Pid(2));
                    assert_eq!(meta.id, MsgId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // bidirectional: b replies over its own dialed connection
        b.send(Pid(2), Pid(1), Wire::Heartbeat { bal: Ballot::new(1, Pid(2)) });
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Heartbeat { .. })) => {}
            other => panic!("unexpected {other:?}"),
        }
        // a clean run drops nothing
        assert_eq!(a.net_stats().dropped_frames.load(Ordering::Relaxed), 0);
        assert_eq!(b.net_stats().dropped_frames.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn uring_interoperates_with_threaded_tcp() {
        if !uring_or_skip("uring_interoperates_with_threaded_tcp") {
            return;
        }
        // same wire format: an io_uring endpoint and a threaded TCP
        // endpoint converse transparently
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = UringTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = crate::net::TcpTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(7));
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(7)),
            other => panic!("unexpected {other:?}"),
        }
        b.send(Pid(2), Pid(1), mcast(8));
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(8)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn uring_interoperates_with_epoll() {
        if !uring_or_skip("uring_interoperates_with_epoll") {
            return;
        }
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = UringTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = crate::net::EpollTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(17));
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(17)),
            other => panic!("unexpected {other:?}"),
        }
        b.send(Pid(2), Pid(1), mcast(18));
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(18)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn uring_carries_batch_frames_intact() {
        if !uring_or_skip("uring_carries_batch_frames_intact") {
            return;
        }
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = UringTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = UringTransport::bind(Pid(2), addrs).unwrap();
        let frame = Wire::Batch((0..5).map(mcast).collect());
        a.send(Pid(1), Pid(2), frame.clone());
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), w)) => assert_eq!(w, frame),
            other => panic!("unexpected {other:?}"),
        }
    }

    /// A frame big enough for the `SEND_ZC` path (and larger than one
    /// registered receive buffer) survives the zero-copy send and the
    /// multi-buffer reassembly byte-for-byte.
    #[test]
    fn uring_large_frame_takes_send_zc_path_intact() {
        if !uring_or_skip("uring_large_frame_takes_send_zc_path_intact") {
            return;
        }
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = UringTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = UringTransport::bind(Pid(2), addrs).unwrap();
        let payload: Vec<u8> = (0..(3 * ZC_THRESHOLD)).map(|i| (i % 251) as u8).collect();
        let big = Wire::Multicast { meta: MsgMeta::new(MsgId(1), GidSet::single(crate::types::Gid(0)), payload.clone()) };
        a.send(Pid(1), Pid(2), big);
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), Wire::Multicast { meta })) => {
                assert_eq!(meta.payload.as_slice(), &payload[..]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(a.net_stats().dropped_frames.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn uring_shard_pids_share_one_connection_per_address() {
        if !uring_or_skip("uring_shard_pids_share_one_connection_per_address") {
            return;
        }
        let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
        let host_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), host_addr);
        addrs.insert(Pid(12), host_addr);
        let mut a = UringTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut host = UringTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(1));
        a.send(Pid(11), Pid(12), mcast(2)); // different source shard, same socket
        for expect in [(Pid(1), Pid(2), 1u64), (Pid(11), Pid(12), 2)] {
            match host.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!((from, to, meta.id.0), expect);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // detached sender half: works from another thread's state
        let mut tx = host.sender();
        tx.send(Pid(2), Pid(1), mcast(3));
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Multicast { meta })) => assert_eq!(meta.id, MsgId(3)),
            other => panic!("unexpected {other:?}"),
        }
    }

    /// A destination that refuses connections is counted dropped (after
    /// the async reconnect retry), and an address-less pid immediately.
    #[test]
    fn uring_unreachable_destination_is_counted_dropped() {
        if !uring_or_skip("uring_unreachable_destination_is_counted_dropped") {
            return;
        }
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse::<SocketAddr>().unwrap());
        addrs.insert(Pid(7), format!("127.0.0.1:{}", next_port()).parse::<SocketAddr>().unwrap());
        let mut a = UringTransport::bind(Pid(1), addrs).unwrap();
        let stats = a.net_stats();
        a.send(Pid(1), Pid(7), mcast(99)); // nothing listens on p7's port
        wait_until("unreachable send counted", || stats.dropped_frames.load(Ordering::Relaxed) >= 1);
        // connection-refused surfaces asynchronously; the one-shot
        // reconnect retry ran (and failed) before the frame was dropped
        assert!(stats.reconnects_attempted.load(Ordering::Relaxed) >= 1, "refused connect never retried");
        a.send(Pid(1), Pid(42), mcast(100)); // no address at all
        wait_until("address-less send counted", || stats.dropped_frames.load(Ordering::Relaxed) >= 2);
    }

    /// Acceptance (kill-one-connection): frames sent across a
    /// dropped-then-reconnected link are either delivered in FIFO order
    /// or visibly counted as dropped — never silently lost — and the
    /// repair shows up in [`NetStats::reconnects_attempted`]/
    /// [`NetStats::reconnects_succeeded`]. Exact parity with the tcp
    /// and epoll versions of this test.
    #[test]
    fn uring_dropped_link_reconnects_or_warns() {
        if !uring_or_skip("uring_dropped_link_reconnects_or_warns") {
            return;
        }
        let a_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let b_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), a_addr);
        addrs.insert(Pid(2), b_addr);

        // raw receiver we can kill: read 3 frames on the first
        // connection, hard-close it, then collect everything resent
        let listener = TcpListener::bind(b_addr).unwrap();
        let server = std::thread::spawn(move || -> Vec<u64> {
            let mut got = Vec::new();
            let (s1, _) = listener.accept().unwrap();
            let mut r1 = BufReader::new(s1);
            for _ in 0..3 {
                let bytes = read_frame(&mut r1).unwrap();
                let Wire::Multicast { meta } = codec::decode(&bytes[8..]).unwrap() else { panic!() };
                got.push(meta.id.0);
            }
            drop(r1);
            let (s2, _) = listener.accept().unwrap();
            let mut r2 = BufReader::new(s2);
            while let Ok(bytes) = read_frame(&mut r2) {
                let Wire::Multicast { meta } = codec::decode(&bytes[8..]).unwrap() else { panic!() };
                got.push(meta.id.0);
            }
            got
        });

        let mut a = UringTransport::bind(Pid(1), addrs).unwrap();
        let stats = a.net_stats();
        for i in 0..3 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        // let the server read + close; the loop observes the peer close
        // as a recv EOF/reset completion and tears the connection down
        std::thread::sleep(Duration::from_millis(300));
        for i in 3..8 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        std::thread::sleep(Duration::from_millis(300));
        // close our side so the server's second read loop terminates
        drop(a);
        let got = server.join().unwrap();

        let dropped = stats.dropped_frames.load(Ordering::Relaxed) as usize;
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(got, sorted, "redelivered frames out of FIFO order: {got:?}");
        assert_eq!(got.len() + dropped, 8, "silently lost frames: delivered {got:?}, dropped {dropped}");
        assert!(got.len() >= 3, "first connection frames lost: {got:?}");
        // the peer close was observed and repaired through a counted
        // reconnect
        assert!(stats.probes_dead.load(Ordering::Relaxed) >= 1, "peer close never observed");
        assert!(stats.reconnects_attempted.load(Ordering::Relaxed) >= 1, "reconnect not counted");
        assert!(stats.reconnects_succeeded.load(Ordering::Relaxed) >= 1, "successful reconnect not counted");
    }

    /// One endpoint serving many dialing peers stays at exactly one
    /// loop thread (asserted structurally via thread names on /proc).
    #[test]
    fn uring_single_thread_serves_many_connections() {
        if !uring_or_skip("uring_single_thread_serves_many_connections") {
            return;
        }
        let host_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
        addrs.insert(Pid(0), host_addr);
        let n_peers = 6u32;
        for i in 1..=n_peers {
            addrs.insert(Pid(i), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        }
        let mut host = UringTransport::bind(Pid(0), addrs.clone()).unwrap();
        let before = count_threads_named("wbam-uring-0");
        assert_eq!(before, 1, "one endpoint must run one loop thread");
        let mut peers: Vec<UringTransport> =
            (1..=n_peers).map(|i| UringTransport::bind(Pid(i), addrs.clone()).unwrap()).collect();
        for (i, p) in peers.iter_mut().enumerate() {
            let pid = Pid(i as u32 + 1);
            p.send(pid, Pid(0), mcast(i as u64));
        }
        let mut seen = Vec::new();
        for _ in 0..n_peers {
            match host.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(_, Pid(0), Wire::Multicast { meta })) => seen.push(meta.id.0),
                other => panic!("unexpected {other:?}"),
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n_peers as u64).collect::<Vec<_>>());
        // still exactly one thread for the host despite 6 live inbound
        // connections
        assert_eq!(count_threads_named("wbam-uring-0"), 1);
    }

    /// Count this process's threads whose name starts with `prefix`.
    fn count_threads_named(prefix: &str) -> usize {
        let Ok(tasks) = std::fs::read_dir("/proc/self/task") else { return 0 };
        tasks
            .filter_map(|e| e.ok())
            .filter(|e| {
                std::fs::read_to_string(e.path().join("comm")).map(|c| c.trim().starts_with(prefix)).unwrap_or(false)
            })
            .count()
    }
}
