//! Real transports for the coordinator runtime (the request path never
//! touches Python). Four implementations of one [`Transport`] contract:
//!
//! * [`InProcMesh`] / [`InProcTransport`] — an in-process channel mesh
//!   for single-machine deployments and tests.
//! * [`TcpTransport`] — blocking `std::net` TCP (the offline image has
//!   no tokio): one listener thread plus **one reader thread per
//!   accepted connection**; sends are blocking writes guarded by an
//!   idle-connection liveness probe.
//! * [`EpollTransport`] (Linux) — the same wire format driven by **one
//!   event-loop thread per endpoint** over raw `epoll`: nonblocking
//!   connects, per-connection reassembly buffers, `EPOLLOUT`-driven
//!   backpressure. Retires the O(connections) thread cost; see
//!   [`epoll`] for the loop design.
//! * [`UringTransport`] (Linux, kernel-gated) — one submission/
//!   completion loop per endpoint over raw `io_uring`: multishot accept,
//!   multishot receive into a registered buffer ring, and `SEND_ZC` for
//!   large frames. Retires the O(frames) syscall cost on top of epoll's
//!   thread savings; probe availability with [`uring::uring_available`]
//!   (see [`uring`] for the ring design and buffer lifecycle).
//!
//! All of them preserve the protocol's channel assumptions: reliable
//! FIFO per-link delivery, where a *link* is an ordered `(from, to)`
//! pid pair. One endpoint may host several local pids (the shards of a
//! [`crate::types::ShardMap`]): every frame carries its source and
//! destination pid so the receiving runtime can demux to the right
//! shard, and outgoing socket connections are shared per remote
//! *address*, not per pid.
//!
//! A send that hits a dead connection re-establishes the connection and
//! retries once (counted: [`NetStats::reconnects_attempted`] /
//! [`NetStats::reconnects_succeeded`]); a frame that still cannot be
//! *written* is `log::warn!`ed **and counted**
//! ([`NetStats::dropped_frames`]) rather than vanishing. The threaded
//! transport detects peer death with an idle-connection probe (outcomes
//! counted too); the epoll transport sees the FIN as a readiness event
//! the moment it arrives. The residual TCP in-flight loss (peer dies
//! mid-stream with writes succeeding into the kernel buffer) is
//! inherent to TCP without application acks — that is exactly what the
//! protocol's retransmit timers (§IV message recovery) absorb; the
//! transport's job is to make every *locally observed* failure visible.

use crate::codec;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use crate::sync::{thread, Arc, Mutex};
use crate::types::{Pid, Wire};
use std::collections::{HashMap, HashSet};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

#[cfg(target_os = "linux")]
pub mod epoll;
#[cfg(target_os = "linux")]
pub use epoll::{EpollSender, EpollTransport};
#[cfg(target_os = "linux")]
pub mod uring;
#[cfg(target_os = "linux")]
pub use uring::{uring_available, uring_probe, UringSender, UringTransport};

/// Process-wide count of transport-issued network syscalls on the send /
/// wake / event-wait paths: TCP probe reads, connects and buffered-write
/// flushes; epoll eventfd wakes, `epoll_wait` returns, connects, reads
/// and writes; `io_uring_enter` calls (one `enter` covers every queued
/// submission *and* completion reaping — that is the point of the uring
/// transport). TCP's receive-side `read` calls are **not** counted (the
/// reader threads' `BufReader` hides syscall boundaries), so
/// cross-transport comparisons should lean on the send/wait columns; the
/// hotpath bench reports this as syscalls-per-multicast per transport.
static SYSCALLS: AtomicU64 = AtomicU64::new(0);

#[inline]
pub(crate) fn count_syscalls(n: u64) {
    SYSCALLS.fetch_add(n, Ordering::Relaxed);
}

/// Current value of the process-wide transport syscall gauge: TCP probe
/// reads, connects and buffered-write flushes; epoll eventfd wakes,
/// `epoll_wait` returns, connects, reads and writes; `io_uring_enter`
/// calls. TCP receive-side reads are excluded (`BufReader` hides the
/// syscall boundaries). Benches diff this across a measurement window.
pub fn syscalls_observed() -> u64 {
    SYSCALLS.load(Ordering::Relaxed)
}

/// Incoming event at an endpoint.
#[derive(Debug)]
pub enum Incoming {
    /// `(from, to, wire)`: an addressed frame. `to` selects the local
    /// shard node at endpoints hosting more than one pid.
    Wire(Pid, Pid, Wire),
    /// transport shut down
    Closed,
}

/// Transport-level counters. Every frame loss the transport can locally
/// observe is counted here (as well as `log::warn!`ed), so tests and
/// operators assert on numbers instead of scraping logs; the idle-probe
/// outcomes make the TCP peer-close detector observable too.
///
/// Shared by [`Transport::net_stats`]: per endpoint for TCP, mesh-wide
/// for the in-process transport (an InProc drop is a cluster-level event
/// — the destination is not registered).
#[derive(Default)]
pub struct NetStats {
    /// frames this side observably lost (warned, never silent): sends
    /// that could not be put on the wire, and received frames that
    /// failed framing/decoding (the reader then abandons the stream, so
    /// trailing frames on that connection die with the peer's retransmit
    /// timers as the backstop)
    pub dropped_frames: AtomicU64,
    /// idle-probe verdicts on cached TCP connections: still healthy
    pub probes_alive: AtomicU64,
    /// dead-link verdicts: the idle probe found the peer closed (TCP),
    /// or the event loop observed EOF/`EPOLLRDHUP`/an error on a dialed
    /// connection (epoll) — the connection is torn down before another
    /// frame can vanish into it
    pub probes_dead: AtomicU64,
    /// re-establishment attempts for an address whose previous
    /// connection was observed dead (the retry-once link repair); a
    /// first-ever connect is not a reconnect
    pub reconnects_attempted: AtomicU64,
    /// reconnect attempts that produced a working connection again
    pub reconnects_succeeded: AtomicU64,
    /// capability fallbacks at startup: the requested transport is
    /// unavailable on this kernel (e.g. `--transport uring` with
    /// `io_uring` compiled out or seccomp'd away) and a compatible
    /// transport was substituted. Nonzero means "you are not running
    /// what you asked for" — warned once and visible here instead of
    /// aborting the deployment
    pub transport_fallbacks: AtomicU64,
}

/// The send half of a transport, usable from a thread other than the
/// receiver's (the sharded runtime's flusher thread). `send` takes the
/// wire by value: the flush hands each per-link frame over exactly once,
/// so the in-process mesh forwards it without a clone and the socket
/// transports encode it once into a reused buffer.
///
/// `send` never blocks on a slow peer beyond the kernel's socket buffer
/// (TCP) or at all (epoll, in-proc), and never returns failure: a frame
/// the transport cannot put on the wire after the reconnect retry is
/// dropped *visibly* — warned and counted in
/// [`NetStats::dropped_frames`] — because the protocol's retransmit
/// timers, not the transport, own end-to-end reliability.
pub trait TransportTx: Send {
    /// Queue/write one frame on the `(from, to)` link.
    fn send(&mut self, from: Pid, to: Pid, wire: Wire);
}

/// Endpoint handle: send to any peer, receive the traffic of every
/// locally hosted pid.
///
/// # Contract (what every implementation — and [`EpollTransport`] in
/// particular — must honor)
///
/// * **Ordering:** frames sent through one send half on one `(from,
///   to)` link arrive in send order (reliable FIFO per link) for as
///   long as the underlying connection lives; after a reconnect, the
///   retried frames continue in order. A receiver never observes a
///   reordering, only a (counted) gap.
/// * **Drop visibility:** any frame the transport locally knows it lost
///   — no route, connect failed after the retry, decode error on
///   receive, send backlog over its bound — increments
///   [`NetStats::dropped_frames`] and logs a warning. Losses the
///   transport *cannot* observe (bytes in a dead peer's kernel buffer)
///   are the protocol's retransmit timers' job.
/// * **Reconnect:** a send hitting a connection observed dead
///   re-establishes it and retries once
///   ([`NetStats::reconnects_attempted`]/`reconnects_succeeded`);
///   frames still pending when the retry fails are dropped visibly.
/// * **Shutdown:** dropping the transport stops its helper threads and
///   closes its connections; frames already accepted by `send` are
///   written if the sockets accept them promptly but are *not* awaited
///   (stopping never blocks on a dead peer). After shutdown,
///   [`Transport::recv_timeout`] reports [`Incoming::Closed`] to any
///   remaining receiver and further sends count as drops.
pub trait Transport: Send {
    /// An independent send half (own connection/encode state) for use on
    /// another thread. All of a runtime's outgoing traffic should flow
    /// through a single half so per-link FIFO order is preserved.
    fn sender(&self) -> Box<dyn TransportTx>;
    /// Convenience send from the receiving half (tests, single-threaded
    /// callers).
    fn send(&mut self, from: Pid, to: Pid, wire: Wire);
    /// Blocking receive with timeout; `None` on timeout.
    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming>;
    /// Shared transport counters (drops, probe outcomes, reconnects).
    /// The handle is also updated by every [`Transport::sender`] half,
    /// so cloning it before handing the transport to a runtime observes
    /// all traffic.
    fn net_stats(&self) -> Arc<NetStats>;
}

/// Forwarding impl so callers can pick a transport at runtime (the CLI's
/// `--transport tcp|epoll`) and still drive the generic runtimes.
impl Transport for Box<dyn Transport> {
    fn sender(&self) -> Box<dyn TransportTx> {
        (**self).sender()
    }

    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        (**self).send(from, to, wire)
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        (**self).recv_timeout(d)
    }

    fn net_stats(&self) -> Arc<NetStats> {
        (**self).net_stats()
    }
}

/// Receive-side cap: frames claiming more than this are rejected and
/// the stream abandoned (a corrupt length field would otherwise
/// allocate gigabytes). The send-side splitter
/// ([`crate::protocols::outbox::MAX_FRAME_BYTES`], 8 MiB) keeps honest
/// frames far below it.
pub const MAX_RX_FRAME_BYTES: usize = 64 << 20;

/// Encode one socket-transport frame into `enc` (cleared first):
/// `u32 len ++ u32 from ++ u32 to ++ codec bytes`, with `len` covering
/// everything after itself. The single definition of the wire framing —
/// [`TcpTransport`] and [`EpollTransport`] both send through it (which
/// is what makes them interoperable), and [`FrameAssembler`] /
/// `read_frame` are its receive-side inverses.
pub fn encode_frame(enc: &mut codec::Enc, from: Pid, to: Pid, wire: &Wire) {
    enc.buf.clear();
    enc.u32(0); // length placeholder
    enc.u32(from.0);
    enc.u32(to.0);
    codec::encode_into(enc, wire);
    let n = (enc.buf.len() - 4) as u32;
    enc.buf[..4].copy_from_slice(&n.to_le_bytes());
}

/// Incremental reassembly of the length-prefixed wire format
/// (`u32 len ++ u32 from ++ u32 to ++ codec bytes`) from an arbitrary
/// byte-chunk stream — the receive path of [`EpollTransport`], where
/// reads return whatever the socket has and frames routinely split
/// across read boundaries.
///
/// [`FrameAssembler::push`] buffers the chunk and emits every complete
/// frame, in order; bytes of a trailing partial frame stay buffered for
/// the next push. Any framing violation (oversized or runt frame,
/// undecodable payload) is an error: the caller must abandon the stream,
/// exactly like the threaded transport's reader thread does — trailing
/// frames die with the connection and the protocol's retransmit timers
/// recover them. Property-tested against arbitrary split points in
/// `tests/properties.rs`.
#[derive(Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
}

impl FrameAssembler {
    /// An empty assembler (fresh connection).
    pub fn new() -> Self {
        FrameAssembler { buf: Vec::new() }
    }

    /// Bytes buffered for a not-yet-complete frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Append `chunk`, emitting every frame it completes. On `Err` the
    /// stream is unrecoverable and must be abandoned (the caller counts
    /// the loss); frames completed before the violation are still
    /// emitted, in order.
    ///
    /// Zero-copy receive: the region of complete frames is frozen into
    /// one shared `Arc<[u8]>` and decoded with
    /// [`codec::decode_shared`], so message payloads come out as
    /// refcounted windows into that buffer — one allocation and one bulk
    /// copy per read burst, zero per message.
    pub fn push<F: FnMut(Pid, Pid, Wire)>(&mut self, chunk: &[u8], emit: &mut F) -> std::io::Result<()> {
        self.buf.extend_from_slice(chunk);
        // Pass 1: validate headers and measure the complete-frame region.
        let mut end = 0usize;
        let mut header_err = None;
        let mut pos = 0usize;
        while self.buf.len() - pos >= 4 {
            let n = u32::from_le_bytes(self.buf[pos..pos + 4].try_into().unwrap()) as usize;
            if n > MAX_RX_FRAME_BYTES {
                header_err = Some(std::io::Error::other("frame too large"));
                break;
            }
            if n < 8 {
                header_err = Some(std::io::Error::other(format!("runt frame ({n} bytes)")));
                break;
            }
            if self.buf.len() - pos < 4 + n {
                break; // partial frame: wait for more bytes
            }
            pos += 4 + n;
            end = pos;
        }
        // Pass 2: freeze the complete region and emit zero-copy decodes.
        if end > 0 {
            let frame: Arc<[u8]> = Arc::from(&self.buf[..end]);
            self.buf.drain(..end);
            let mut pos = 0usize;
            while pos < frame.len() {
                let n = u32::from_le_bytes(frame[pos..pos + 4].try_into().unwrap()) as usize;
                let from = Pid(u32::from_le_bytes(frame[pos + 4..pos + 8].try_into().unwrap()));
                let to = Pid(u32::from_le_bytes(frame[pos + 8..pos + 12].try_into().unwrap()));
                match codec::decode_shared(&frame, pos + 12, pos + 4 + n) {
                    Ok(wire) => emit(from, to, wire),
                    Err(e) => return Err(std::io::Error::other(format!("bad frame from {from:?}: {e}"))),
                }
                pos += 4 + n;
            }
        }
        match header_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

// ---------------- in-process mesh ----------------

/// Registry mapping pids to channel senders (shared by all endpoints).
/// Several pids may map to one endpoint's channel (shard hosting).
#[derive(Clone, Default)]
pub struct InProcMesh {
    inner: Arc<Mutex<HashMap<Pid, Sender<(Pid, Pid, Wire)>>>>,
    stats: Arc<NetStats>,
}

impl InProcMesh {
    /// A fresh, empty mesh (no endpoints registered yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// Mesh-wide transport counters (all endpoints and send halves).
    pub fn net_stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }

    /// Create the endpoint for a single `pid`.
    pub fn endpoint(&self, pid: Pid) -> InProcTransport {
        self.endpoint_hosting(&[pid])
    }

    /// Create one endpoint receiving the traffic of every pid in `pids`
    /// (the shards hosted by one machine).
    pub fn endpoint_hosting(&self, pids: &[Pid]) -> InProcTransport {
        let (tx, rx) = mpsc::channel();
        let mut guard = self.inner.lock().unwrap();
        for &p in pids {
            guard.insert(p, tx.clone());
        }
        drop(guard);
        InProcTransport { mesh: self.clone(), rx }
    }

    /// Disconnect `pid` (crash simulation: its queue drops once no alias
    /// remains registered).
    pub fn disconnect(&self, pid: Pid) {
        self.inner.lock().unwrap().remove(&pid);
    }
}

/// Send half of the mesh (just a registry handle).
pub struct InProcSender {
    mesh: InProcMesh,
}

impl InProcMesh {
    /// Deliver one frame, counting (and warning about) a destination
    /// that is not registered or whose endpoint is gone — a disconnected
    /// peer, never a healthy one.
    fn deliver(&self, from: Pid, to: Pid, wire: Wire) {
        let guard = self.inner.lock().unwrap();
        let delivered = match guard.get(&to) {
            // lock-ok: mpsc Sender::send, not InProcSender::send — the
            // channel never re-enters the mesh, so `inner` is not re-taken
            Some(tx) => tx.send((from, to, wire)).is_ok(),
            None => false,
        };
        if !delivered {
            drop(guard);
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("inproc: dropping frame {from:?}->{to:?}: destination disconnected");
        }
    }
}

impl TransportTx for InProcSender {
    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        self.mesh.deliver(from, to, wire);
    }
}

/// One endpoint of an [`InProcMesh`]: receives the traffic of every pid
/// it was registered for, sends to any registered peer.
pub struct InProcTransport {
    mesh: InProcMesh,
    rx: Receiver<(Pid, Pid, Wire)>,
}

impl Transport for InProcTransport {
    fn sender(&self) -> Box<dyn TransportTx> {
        Box::new(InProcSender { mesh: self.mesh.clone() })
    }

    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        self.mesh.deliver(from, to, wire);
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        match self.rx.recv_timeout(d) {
            Ok((from, to, wire)) => Some(Incoming::Wire(from, to, wire)),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Incoming::Closed),
        }
    }

    fn net_stats(&self) -> Arc<NetStats> {
        self.mesh.net_stats()
    }
}

// ---------------- TCP ----------------

/// Frame layout on the wire: `u32 len ++ u32 from ++ u32 to ++ codec
/// bytes`. `addrs` maps every addressable pid — including each shard
/// counterpart of a hosted endpoint — to the `SocketAddr` of the
/// endpoint hosting it; outgoing connections are cached per address so
/// all shard traffic to one machine shares a socket. Each accepted
/// connection gets a reader thread that forwards decoded frames into the
/// endpoint's queue.
pub struct TcpTransport {
    addrs: Arc<HashMap<Pid, SocketAddr>>,
    stats: Arc<NetStats>,
    tx_half: TcpSender,
    rx: Receiver<(Pid, Pid, Wire)>,
    _listener_thread: thread::JoinHandle<()>,
}

/// Read one whole `u32 len ++ body` frame from a blocking stream (the
/// threaded transport's reader threads; the epoll transport reassembles
/// through [`FrameAssembler`] instead).
fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_RX_FRAME_BYTES {
        return Err(std::io::Error::other("frame too large"));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

impl TcpTransport {
    /// Bind the endpoint for `pid` at `addrs[&pid]` (panics if absent)
    /// and start its listener thread. `addrs` must map every
    /// addressable pid — including shard counterparts aliased to their
    /// endpoint's address — to the address of the endpoint hosting it.
    pub fn bind(pid: Pid, addrs: HashMap<Pid, SocketAddr>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addrs[&pid])?;
        let (tx, rx) = mpsc::channel::<(Pid, Pid, Wire)>();
        let stats = Arc::new(NetStats::default());
        let accept_tx = tx.clone();
        let accept_stats = Arc::clone(&stats);
        let listener_thread = thread::Builder::new()
            .name(format!("wbam-listen-{}", pid.0))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    let tx = accept_tx.clone();
                    let stats = Arc::clone(&accept_stats);
                    thread::spawn(move || {
                        let mut r = BufReader::new(stream);
                        loop {
                            match read_frame(&mut r) {
                                Ok(bytes) => {
                                    if bytes.len() < 8 {
                                        // receive-side loss is a loss too:
                                        // count it, then abandon the stream
                                        // (framing is unrecoverable)
                                        stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                                        log::warn!("runt frame ({} bytes)", bytes.len());
                                        return;
                                    }
                                    let from = Pid(u32::from_le_bytes(bytes[0..4].try_into().unwrap()));
                                    let to = Pid(u32::from_le_bytes(bytes[4..8].try_into().unwrap()));
                                    // zero-copy decode: payloads become
                                    // windows into the frame body instead
                                    // of per-message Vec copies
                                    let body: Arc<[u8]> = bytes.into();
                                    match codec::decode_shared(&body, 8, body.len()) {
                                        Ok(wire) => {
                                            if tx.send((from, to, wire)).is_err() {
                                                return;
                                            }
                                        }
                                        Err(e) => {
                                            stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                                            log::warn!("bad frame from {from:?}: {e}");
                                            return;
                                        }
                                    }
                                }
                                Err(_) => return, // peer closed
                            }
                        }
                    });
                }
            })?;
        let addrs = Arc::new(addrs);
        Ok(TcpTransport {
            addrs: Arc::clone(&addrs),
            stats: Arc::clone(&stats),
            tx_half: TcpSender::new(addrs, stats),
            rx,
            _listener_thread: listener_thread,
        })
    }
}

impl Transport for TcpTransport {
    fn sender(&self) -> Box<dyn TransportTx> {
        Box::new(TcpSender::new(Arc::clone(&self.addrs), Arc::clone(&self.stats)))
    }

    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        self.tx_half.send(from, to, wire)
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        match self.rx.recv_timeout(d) {
            Ok((from, to, wire)) => Some(Incoming::Wire(from, to, wire)),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Incoming::Closed),
        }
    }

    fn net_stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }
}

/// How long a connection may sit idle before the next send probes it for
/// a peer close. Back-to-back frames skip the probe (keeping the hot
/// path at one write syscall per frame); a link that died during a lull
/// is detected before the first write that could silently vanish into
/// the dead socket.
const PROBE_AFTER_IDLE: Duration = Duration::from_millis(10);

struct Conn {
    w: BufWriter<TcpStream>,
    last_used: std::time::Instant,
}

/// RAII guard restoring a probed stream to blocking mode. The probe
/// toggles `set_nonblocking(true)`; restoring through a guard (instead
/// of a trailing call) means no early return or panic path can leave the
/// stream nonblocking — which would turn every subsequent buffered send
/// into a spurious `WouldBlock` failure and a warned "drop" on a
/// perfectly healthy connection.
struct BlockingGuard<'a>(&'a TcpStream);

impl Drop for BlockingGuard<'_> {
    fn drop(&mut self) {
        if let Err(e) = self.0.set_nonblocking(false) {
            // the stream is unusable either way; the caller's next write
            // fails and tears the connection down
            log::warn!("tcp: failed to restore blocking mode after probe: {e}");
        }
    }
}

/// TCP send half: per-address connection cache + a reused encode buffer
/// (`u32 length ++ from ++ to ++ codec bytes`, written with a single
/// `write_all` per frame — encode-once, one syscall per frame).
pub struct TcpSender {
    addrs: Arc<HashMap<Pid, SocketAddr>>,
    stats: Arc<NetStats>,
    conns: HashMap<SocketAddr, Conn>,
    /// addresses whose cached connection was observed dead (probe or
    /// write failure): the next establishment is a *reconnect* and is
    /// counted in [`NetStats::reconnects_attempted`]/`_succeeded`
    dead: HashSet<SocketAddr>,
    enc: codec::Enc,
}

impl TcpSender {
    fn new(addrs: Arc<HashMap<Pid, SocketAddr>>, stats: Arc<NetStats>) -> Self {
        TcpSender { addrs, stats, conns: HashMap::new(), dead: HashSet::new(), enc: codec::Enc::new() }
    }

    /// Eager liveness probe on a cached, write-only connection: a peer
    /// close shows up as readable-EOF long before a write fails, so
    /// checking here closes (most of) the window in which a frame could
    /// be buffered into a connection the peer has already torn down.
    /// Every outcome is counted in [`NetStats`].
    fn conn_is_dead(stream: &TcpStream, stats: &NetStats) -> bool {
        if stream.set_nonblocking(true).is_err() {
            stats.probes_dead.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        let _restore = BlockingGuard(stream);
        let mut probe = [0u8; 1];
        let mut r: &TcpStream = stream;
        count_syscalls(1); // the probe read
        let dead = match r.read(&mut probe) {
            Ok(0) => true,                                                   // EOF: peer closed
            Ok(_) => false,                                                  // stray inbound byte; still open
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,   // healthy and idle
            Err(_) => true,
        };
        if dead {
            stats.probes_dead.fetch_add(1, Ordering::Relaxed);
        } else {
            stats.probes_alive.fetch_add(1, Ordering::Relaxed);
        }
        dead
    }

    /// One attempt to put the encoded frame on the wire: (re)connect if
    /// needed, drop the connection on any failure so the next attempt
    /// starts fresh.
    fn try_write(&mut self, addr: SocketAddr, probe: bool) -> bool {
        if probe {
            if let Some(c) = self.conns.get(&addr) {
                if c.last_used.elapsed() >= PROBE_AFTER_IDLE && Self::conn_is_dead(c.w.get_ref(), &self.stats) {
                    self.conns.remove(&addr);
                    self.dead.insert(addr);
                }
            }
        }
        if !self.conns.contains_key(&addr) {
            // re-establishing after an observed death is a reconnect;
            // a first-ever connect to this address is not
            let reconnect = self.dead.contains(&addr);
            if reconnect {
                self.stats.reconnects_attempted.fetch_add(1, Ordering::Relaxed);
            }
            count_syscalls(1); // connect
            let Ok(stream) = TcpStream::connect(addr) else { return false };
            if reconnect {
                self.stats.reconnects_succeeded.fetch_add(1, Ordering::Relaxed);
                self.dead.remove(&addr);
            }
            stream.set_nodelay(true).ok();
            self.conns.insert(addr, Conn { w: BufWriter::new(stream), last_used: std::time::Instant::now() });
        }
        let c = self.conns.get_mut(&addr).expect("connection just ensured");
        count_syscalls(1); // one write per frame (BufWriter flushed whole)
        if c.w.write_all(&self.enc.buf).and_then(|()| c.w.flush()).is_ok() {
            c.last_used = std::time::Instant::now();
            true
        } else {
            self.conns.remove(&addr);
            self.dead.insert(addr);
            false
        }
    }
}

impl TransportTx for TcpSender {
    fn send(&mut self, from: Pid, to: Pid, wire: Wire) {
        let tag = wire.tag();
        // encode once into the reused buffer, length prefix in-band
        encode_frame(&mut self.enc, from, to, &wire);
        let Some(&addr) = self.addrs.get(&to) else {
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("tcp: dropping {tag} {from:?}->{to:?}: destination has no address");
            return;
        };
        // reliable-FIFO link repair: re-establish the connection and
        // retry the send once before declaring the frame lost
        if self.try_write(addr, true) || self.try_write(addr, false) {
            return;
        }
        self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
        log::warn!("tcp: dropping {tag} {from:?}->{to:?} ({addr}) after reconnect retry");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Ballot, GidSet, MsgId, MsgMeta};
    use std::sync::atomic::{AtomicU16, Ordering};

    fn mcast(id: u64) -> Wire {
        Wire::Multicast { meta: MsgMeta::new(MsgId(id), GidSet::single(crate::types::Gid(0)), vec![1, 2, 3]) }
    }

    /// Per-process unique localhost ports (tests run concurrently).
    fn next_port() -> u16 {
        static NEXT: AtomicU16 = AtomicU16::new(0);
        42000 + (std::process::id() % 400) as u16 * 32 + NEXT.fetch_add(1, Ordering::Relaxed)
    }

    #[test]
    fn inproc_roundtrip_and_fifo() {
        let mesh = InProcMesh::new();
        let mut a = mesh.endpoint(Pid(1));
        let mut b = mesh.endpoint(Pid(2));
        for i in 0..10 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        for i in 0..10 {
            match b.recv_timeout(Duration::from_secs(1)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!(from, Pid(1));
                    assert_eq!(to, Pid(2));
                    assert_eq!(meta.id, MsgId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(b.recv_timeout(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn inproc_send_to_unknown_is_dropped() {
        let mesh = InProcMesh::new();
        let mut a = mesh.endpoint(Pid(1));
        a.send(Pid(1), Pid(99), mcast(1)); // no panic
    }

    #[test]
    fn inproc_multi_pid_endpoint_demuxes_by_to() {
        let mesh = InProcMesh::new();
        let mut host = mesh.endpoint_hosting(&[Pid(1), Pid(4), Pid(7)]);
        let mut c = mesh.endpoint(Pid(9));
        // one endpoint receives for all hosted pids, tagged with `to`
        c.send(Pid(9), Pid(4), mcast(1));
        c.send(Pid(9), Pid(7), mcast(2));
        for expect in [(Pid(4), 1u64), (Pid(7), 2)] {
            match host.recv_timeout(Duration::from_secs(1)) {
                Some(Incoming::Wire(Pid(9), to, Wire::Multicast { meta })) => {
                    assert_eq!(to, expect.0);
                    assert_eq!(meta.id, MsgId(expect.1));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // the detached sender half works too
        let mut tx = host.sender();
        tx.send(Pid(1), Pid(9), mcast(3));
        match c.recv_timeout(Duration::from_secs(1)) {
            Some(Incoming::Wire(Pid(1), Pid(9), Wire::Multicast { .. })) => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tcp_roundtrip_and_fifo() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = TcpTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = TcpTransport::bind(Pid(2), addrs).unwrap();
        for i in 0..50 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        for i in 0..50 {
            match b.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!(from, Pid(1));
                    assert_eq!(to, Pid(2));
                    assert_eq!(meta.id, MsgId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // bidirectional: b replies
        b.send(Pid(2), Pid(1), Wire::Heartbeat { bal: Ballot::new(1, Pid(2)) });
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Pid(1), Wire::Heartbeat { .. })) => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tcp_carries_batch_frames_intact() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = TcpTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = TcpTransport::bind(Pid(2), addrs).unwrap();
        let frame = Wire::Batch((0..5).map(mcast).collect());
        a.send(Pid(1), Pid(2), frame.clone());
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), w)) => assert_eq!(w, frame),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tcp_shard_pids_share_one_connection_per_address() {
        // two shard pids (2, 12) live behind one endpoint address; both
        // receive through the same listener, demuxed by `to`
        let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
        let host_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), host_addr);
        addrs.insert(Pid(12), host_addr);
        let mut a = TcpTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut host = TcpTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(1));
        a.send(Pid(11), Pid(12), mcast(2)); // different source shard, same socket
        for expect in [(Pid(1), Pid(2), 1u64), (Pid(11), Pid(12), 2)] {
            match host.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, to, Wire::Multicast { meta })) => {
                    assert_eq!((from, to, meta.id.0), expect);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    /// Acceptance: frames sent across a dropped-then-reconnected link are
    /// either delivered in FIFO order or visibly counted as dropped in
    /// [`NetStats`] — never silently lost.
    #[test]
    fn tcp_dropped_link_reconnects_or_warns() {
        let a_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let b_addr: SocketAddr = format!("127.0.0.1:{}", next_port()).parse().unwrap();
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), a_addr);
        addrs.insert(Pid(2), b_addr);

        // raw receiver we can kill: accept one connection, read `n`
        // frames, then drop the socket mid-link
        let listener = TcpListener::bind(b_addr).unwrap();
        let server = std::thread::spawn(move || -> Vec<u64> {
            let mut got = Vec::new();
            // first connection: read 3 frames, then hard-close
            let (s1, _) = listener.accept().unwrap();
            let mut r1 = BufReader::new(s1);
            for _ in 0..3 {
                let bytes = read_frame(&mut r1).unwrap();
                let Wire::Multicast { meta } = codec::decode(&bytes[8..]).unwrap() else { panic!() };
                got.push(meta.id.0);
            }
            drop(r1);
            // the sender must reconnect; collect everything it resends
            let (s2, _) = listener.accept().unwrap();
            let mut r2 = BufReader::new(s2);
            while let Ok(bytes) = read_frame(&mut r2) {
                let Wire::Multicast { meta } = codec::decode(&bytes[8..]).unwrap() else { panic!() };
                got.push(meta.id.0);
            }
            got
        });

        let mut a = TcpTransport::bind(Pid(1), addrs).unwrap();
        let stats = a.net_stats();
        for i in 0..3 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        // let the server read + close, and the FIN reach our socket, so
        // the next send observes the dead link instead of racing it
        std::thread::sleep(Duration::from_millis(200));
        for i in 3..8 {
            a.send(Pid(1), Pid(2), mcast(i));
        }
        // close our side so the server's second read loop terminates
        drop(a);
        let got = server.join().unwrap();

        // every frame is accounted for: delivered (in FIFO order) or
        // visibly counted as dropped — never silently lost
        let dropped = stats.dropped_frames.load(Ordering::Relaxed) as usize;
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(got, sorted, "redelivered frames out of FIFO order: {got:?}");
        assert_eq!(got.len() + dropped, 8, "silently lost frames: delivered {got:?}, dropped {dropped}");
        // the happy path of the probe: everything made it
        assert!(got.len() >= 3, "first connection frames lost: {got:?}");
        // the idle probe observed the peer close before the first
        // post-close write could vanish into the dead socket
        assert!(stats.probes_dead.load(Ordering::Relaxed) >= 1, "peer close never probed");
        // ...and the link repair is counted, not just warn-logged
        assert!(stats.reconnects_attempted.load(Ordering::Relaxed) >= 1, "reconnect attempt not counted");
        assert!(stats.reconnects_succeeded.load(Ordering::Relaxed) >= 1, "successful reconnect not counted");
    }

    /// A first-ever connect is not a reconnect: only re-establishment
    /// after an observed death counts.
    #[test]
    fn tcp_first_connect_is_not_a_reconnect() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", next_port()).parse().unwrap());
        let mut a = TcpTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = TcpTransport::bind(Pid(2), addrs).unwrap();
        a.send(Pid(1), Pid(2), mcast(1));
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), Pid(2), Wire::Multicast { .. })) => {}
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(a.net_stats().reconnects_attempted.load(Ordering::Relaxed), 0);
        assert_eq!(a.net_stats().reconnects_succeeded.load(Ordering::Relaxed), 0);
    }

    /// The assembler emits exactly the frames of the stream no matter
    /// how the bytes are chunked (the epoll read path's contract; the
    /// arbitrary-boundary property test lives in tests/properties.rs).
    #[test]
    fn frame_assembler_reassembles_split_frames() {
        // build a byte stream of three framed wires
        let wires: Vec<Wire> = (0..3).map(mcast).collect();
        let mut stream = Vec::new();
        let mut e = codec::Enc::new();
        for (i, w) in wires.iter().enumerate() {
            encode_frame(&mut e, Pid(10 + i as u32), Pid(20 + i as u32), w);
            stream.extend_from_slice(&e.buf);
        }
        // feed it one byte at a time: every frame still comes out whole
        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        for &b in &stream {
            asm.push(&[b], &mut |from, to, wire| got.push((from, to, wire))).expect("valid stream");
        }
        assert_eq!(asm.pending(), 0);
        assert_eq!(got.len(), 3);
        for (i, (from, to, wire)) in got.iter().enumerate() {
            assert_eq!(*from, Pid(10 + i as u32));
            assert_eq!(*to, Pid(20 + i as u32));
            assert_eq!(*wire, wires[i]);
        }
        // a runt frame poisons the stream
        let mut bad = FrameAssembler::new();
        assert!(bad.push(&3u32.to_le_bytes(), &mut |_, _, _| {}).is_err());
    }

    /// The assembler's receive path is zero-copy: every frame of one
    /// read burst decodes its payloads out of a single shared buffer
    /// (no per-message allocation or copy).
    #[test]
    fn frame_assembler_decodes_zero_copy() {
        let mut e = codec::Enc::new();
        let mut stream = Vec::new();
        for i in 0..2 {
            encode_frame(&mut e, Pid(1), Pid(2), &mcast(i));
            stream.extend_from_slice(&e.buf);
        }
        let mut asm = FrameAssembler::new();
        let mut payloads = Vec::new();
        asm.push(&stream, &mut |_, _, wire| {
            let Wire::Multicast { meta } = wire else { panic!() };
            payloads.push(meta.payload);
        })
        .expect("valid stream");
        assert_eq!(payloads.len(), 2);
        assert!(
            payloads[0].shares_buffer_with(&payloads[1]),
            "burst frames must decode out of one shared buffer"
        );
        assert_eq!(payloads[0].backing_len(), stream.len());
        assert_eq!(&payloads[0][..], &[1, 2, 3]);
    }

    /// Frames completed before a framing violation in the same burst are
    /// still emitted in order (emit-then-error, matching the one-frame-
    /// at-a-time semantics the assembler had before zero-copy batching).
    #[test]
    fn frame_assembler_emits_good_frames_before_error() {
        let mut e = codec::Enc::new();
        encode_frame(&mut e, Pid(1), Pid(2), &mcast(7));
        let mut stream = e.buf.clone();
        stream.extend_from_slice(&3u32.to_le_bytes()); // runt header after a good frame
        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        let res = asm.push(&stream, &mut |_, _, wire| got.push(wire));
        assert!(res.is_err(), "runt header must poison the stream");
        assert_eq!(got.len(), 1, "the preceding complete frame is still emitted");
    }

    /// A destination that never accepts is counted as a drop, not
    /// ignored.
    #[test]
    fn tcp_unreachable_destination_is_counted_dropped() {
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", next_port()).parse::<SocketAddr>().unwrap());
        addrs.insert(Pid(7), format!("127.0.0.1:{}", next_port()).parse::<SocketAddr>().unwrap());
        let mut a = TcpTransport::bind(Pid(1), addrs).unwrap();
        let stats = a.net_stats();
        a.send(Pid(1), Pid(7), mcast(99)); // nothing listens on p7's port
        assert_eq!(stats.dropped_frames.load(Ordering::Relaxed), 1, "unreachable send not counted");
        // and a pid with no address at all counts too
        a.send(Pid(1), Pid(42), mcast(100));
        assert_eq!(stats.dropped_frames.load(Ordering::Relaxed), 2, "address-less send not counted");
    }

    /// The idle probe must leave the stream in blocking mode on every
    /// path (RAII guard) and count its verdicts.
    #[test]
    fn idle_probe_restores_blocking_mode_and_counts_outcomes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // live peer: accept and hold the connection open
        let hold = std::thread::spawn(move || listener.accept().map(|(s, _)| s));
        let stream = TcpStream::connect(addr).unwrap();
        let held = hold.join().unwrap().unwrap();

        let stats = NetStats::default();
        assert!(!TcpSender::conn_is_dead(&stream, &stats), "open connection probed dead");
        assert_eq!(stats.probes_alive.load(Ordering::Relaxed), 1);
        assert_eq!(stats.probes_dead.load(Ordering::Relaxed), 0);

        // blocking mode restored: a read with a timeout must actually
        // block for the timeout instead of failing instantly with
        // WouldBlock (which is what a leaked nonblocking flag causes)
        stream.set_read_timeout(Some(Duration::from_millis(60))).unwrap();
        let t0 = std::time::Instant::now();
        let mut buf = [0u8; 1];
        let mut r: &TcpStream = &stream;
        assert!(r.read(&mut buf).is_err(), "nothing was sent; the read must time out");
        assert!(
            t0.elapsed() >= Duration::from_millis(40),
            "read returned instantly: the probe left the stream nonblocking"
        );

        // peer closes: the next probe reports dead (and still restores)
        drop(held);
        std::thread::sleep(Duration::from_millis(50)); // let the FIN land
        assert!(TcpSender::conn_is_dead(&stream, &stats), "closed connection probed alive");
        assert_eq!(stats.probes_dead.load(Ordering::Relaxed), 1);
    }

    /// The InProc mesh counts sends to unregistered/disconnected pids.
    #[test]
    fn inproc_drops_are_counted() {
        let mesh = InProcMesh::new();
        let mut a = mesh.endpoint(Pid(1));
        let b = mesh.endpoint(Pid(2));
        a.send(Pid(1), Pid(99), mcast(1)); // never registered
        assert_eq!(mesh.net_stats().dropped_frames.load(Ordering::Relaxed), 1);
        mesh.disconnect(Pid(2));
        drop(b);
        a.send(Pid(1), Pid(2), mcast(2)); // disconnected
        assert_eq!(mesh.net_stats().dropped_frames.load(Ordering::Relaxed), 2);
        // a healthy registered pid still counts nothing
        let _ = a.net_stats();
    }
}

/// Exhaustive interleaving tests for the transport counters, run under
/// the in-tree model checker:
/// `RUSTFLAGS="--cfg loom" cargo test --release loom_`.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use crate::sync::model;

    /// [`NetStats`] counters are shared by the flusher, reader threads
    /// and the event loop; no interleaving of concurrent senders may
    /// under-count an observed drop or reconnect.
    #[test]
    fn loom_net_stats_never_under_count() {
        model(|| {
            let stats = Arc::new(NetStats::default());
            let s1 = stats.clone();
            let s2 = stats.clone();
            let t1 = thread::spawn(move || {
                s1.dropped_frames.fetch_add(1, Ordering::Relaxed);
                s1.reconnects_attempted.fetch_add(1, Ordering::Relaxed);
            });
            let t2 = thread::spawn(move || {
                s2.dropped_frames.fetch_add(1, Ordering::Relaxed);
                s2.reconnects_succeeded.fetch_add(1, Ordering::Relaxed);
            });
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(stats.dropped_frames.load(Ordering::Relaxed), 2, "lost a drop count");
            assert_eq!(stats.reconnects_attempted.load(Ordering::Relaxed), 1);
            assert_eq!(stats.reconnects_succeeded.load(Ordering::Relaxed), 1);
        });
    }

    /// The process-wide syscall gauge takes concurrent increments from
    /// every transport thread; none may be lost.
    #[test]
    fn loom_syscall_gauge_counts_concurrent_increments() {
        model(|| {
            let before = syscalls_observed();
            let a = thread::spawn(|| count_syscalls(2));
            let b = thread::spawn(|| count_syscalls(3));
            a.join().unwrap();
            b.join().unwrap();
            assert_eq!(syscalls_observed() - before, 5, "syscall gauge lost increments");
        });
    }
}
