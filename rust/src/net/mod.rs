//! Real transports for the coordinator runtime (the request path never
//! touches Python): an in-process channel mesh for single-machine
//! deployments and tests, and a TCP transport (std::net; the offline
//! image has no tokio — one reader thread per peer connection).
//!
//! Both preserve the protocol's channel assumptions: reliable FIFO
//! per-link delivery.

use crate::codec;
use crate::types::{Pid, Wire};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Incoming event at a node.
#[derive(Debug)]
pub enum Incoming {
    Wire(Pid, Wire),
    /// transport shut down
    Closed,
}

/// Node-side handle: send to any peer, receive own traffic. `send` takes
/// the wire by value: the coordinator flush hands each per-destination
/// frame over exactly once, so the in-process mesh forwards it without a
/// clone and TCP encodes it once into a reused buffer.
pub trait Transport: Send {
    fn send(&mut self, to: Pid, wire: Wire);
    /// Blocking receive with timeout; `None` on timeout.
    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming>;
}

// ---------------- in-process mesh ----------------

/// Registry mapping pids to channel senders (shared by all endpoints).
#[derive(Clone, Default)]
pub struct InProcMesh {
    inner: Arc<Mutex<HashMap<Pid, Sender<(Pid, Wire)>>>>,
}

impl InProcMesh {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create the endpoint for `pid`.
    pub fn endpoint(&self, pid: Pid) -> InProcTransport {
        let (tx, rx) = mpsc::channel();
        self.inner.lock().unwrap().insert(pid, tx);
        InProcTransport { pid, mesh: self.clone(), rx }
    }

    /// Disconnect `pid` (crash simulation: its queue drops).
    pub fn disconnect(&self, pid: Pid) {
        self.inner.lock().unwrap().remove(&pid);
    }
}

pub struct InProcTransport {
    pid: Pid,
    mesh: InProcMesh,
    rx: Receiver<(Pid, Wire)>,
}

impl Transport for InProcTransport {
    fn send(&mut self, to: Pid, wire: Wire) {
        let guard = self.mesh.inner.lock().unwrap();
        if let Some(tx) = guard.get(&to) {
            let _ = tx.send((self.pid, wire)); // dead peer: drop
        }
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        match self.rx.recv_timeout(d) {
            Ok((from, wire)) => Some(Incoming::Wire(from, wire)),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Incoming::Closed),
        }
    }
}

// ---------------- TCP ----------------

/// TCP transport: every node listens on `addrs[pid]`; outgoing
/// connections are cached; each accepted connection gets a reader thread
/// that forwards framed messages (u32-LE length ++ codec bytes) into the
/// node's queue. The first frame on a connection is a hello carrying the
/// sender pid.
pub struct TcpTransport {
    pid: Pid,
    addrs: Arc<HashMap<Pid, SocketAddr>>,
    conns: HashMap<Pid, BufWriter<TcpStream>>,
    rx: Receiver<(Pid, Wire)>,
    /// reused encode buffer: `u32 length ++ codec bytes`, written with a
    /// single `write_all` per frame (encode-once, one syscall per flush
    /// per destination)
    enc: codec::Enc,
    _listener_thread: std::thread::JoinHandle<()>,
}

fn write_frame(w: &mut impl Write, bytes: &[u8]) -> std::io::Result<()> {
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 64 << 20 {
        return Err(std::io::Error::other("frame too large"));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

impl TcpTransport {
    pub fn bind(pid: Pid, addrs: HashMap<Pid, SocketAddr>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addrs[&pid])?;
        let (tx, rx) = mpsc::channel::<(Pid, Wire)>();
        let accept_tx = tx.clone();
        let listener_thread = std::thread::Builder::new()
            .name(format!("wbam-listen-{}", pid.0))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    let tx = accept_tx.clone();
                    std::thread::spawn(move || {
                        let mut r = BufReader::new(stream);
                        // hello frame: 4-byte sender pid
                        let Ok(hello) = read_frame(&mut r) else { return };
                        if hello.len() != 4 {
                            return;
                        }
                        let from = Pid(u32::from_le_bytes(hello.try_into().unwrap()));
                        loop {
                            match read_frame(&mut r) {
                                Ok(bytes) => match codec::decode(&bytes) {
                                    Ok(wire) => {
                                        if tx.send((from, wire)).is_err() {
                                            return;
                                        }
                                    }
                                    Err(e) => {
                                        log::warn!("bad frame from {from:?}: {e}");
                                        return;
                                    }
                                },
                                Err(_) => return, // peer closed
                            }
                        }
                    });
                }
            })?;
        Ok(TcpTransport {
            pid,
            addrs: Arc::new(addrs),
            conns: HashMap::new(),
            rx,
            enc: codec::Enc::new(),
            _listener_thread: listener_thread,
        })
    }

    /// Borrow-splitting helper: the returned writer borrows only `conns`,
    /// leaving the encode buffer free for the caller.
    fn conn<'a>(
        conns: &'a mut HashMap<Pid, BufWriter<TcpStream>>,
        addrs: &HashMap<Pid, SocketAddr>,
        me: Pid,
        to: Pid,
    ) -> Option<&'a mut BufWriter<TcpStream>> {
        if !conns.contains_key(&to) {
            let addr = *addrs.get(&to)?;
            let stream = TcpStream::connect(addr).ok()?;
            stream.set_nodelay(true).ok();
            let mut w = BufWriter::new(stream);
            write_frame(&mut w, &me.0.to_le_bytes()).ok()?;
            conns.insert(to, w);
        }
        conns.get_mut(&to)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, to: Pid, wire: Wire) {
        // encode once into the reused buffer, length prefix in-band, and
        // put the frame on the socket with a single write
        self.enc.buf.clear();
        self.enc.u32(0); // length placeholder
        codec::encode_into(&mut self.enc, &wire);
        let n = (self.enc.buf.len() - 4) as u32;
        self.enc.buf[..4].copy_from_slice(&n.to_le_bytes());
        let ok = match Self::conn(&mut self.conns, &self.addrs, self.pid, to) {
            Some(w) => w.write_all(&self.enc.buf).and_then(|()| w.flush()).is_ok(),
            None => false,
        };
        if !ok {
            self.conns.remove(&to); // reconnect next time
        }
    }

    fn recv_timeout(&mut self, d: Duration) -> Option<Incoming> {
        match self.rx.recv_timeout(d) {
            Ok((from, wire)) => Some(Incoming::Wire(from, wire)),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Incoming::Closed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Ballot, GidSet, MsgId, MsgMeta};

    fn mcast(id: u64) -> Wire {
        Wire::Multicast { meta: MsgMeta::new(MsgId(id), GidSet::single(crate::types::Gid(0)), vec![1, 2, 3]) }
    }

    #[test]
    fn inproc_roundtrip_and_fifo() {
        let mesh = InProcMesh::new();
        let mut a = mesh.endpoint(Pid(1));
        let mut b = mesh.endpoint(Pid(2));
        for i in 0..10 {
            a.send(Pid(2), mcast(i));
        }
        for i in 0..10 {
            match b.recv_timeout(Duration::from_secs(1)) {
                Some(Incoming::Wire(from, Wire::Multicast { meta })) => {
                    assert_eq!(from, Pid(1));
                    assert_eq!(meta.id, MsgId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(b.recv_timeout(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn inproc_send_to_unknown_is_dropped() {
        let mesh = InProcMesh::new();
        let mut a = mesh.endpoint(Pid(1));
        a.send(Pid(99), mcast(1)); // no panic
    }

    #[test]
    fn tcp_roundtrip_and_fifo() {
        let base = 42000 + (std::process::id() % 1000) as u16;
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", base).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", base + 1).parse().unwrap());
        let mut a = TcpTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = TcpTransport::bind(Pid(2), addrs).unwrap();
        for i in 0..50 {
            a.send(Pid(2), mcast(i));
        }
        for i in 0..50 {
            match b.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(from, Wire::Multicast { meta })) => {
                    assert_eq!(from, Pid(1));
                    assert_eq!(meta.id, MsgId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // bidirectional: b replies
        b.send(Pid(1), Wire::Heartbeat { bal: Ballot::new(1, Pid(2)) });
        match a.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(2), Wire::Heartbeat { .. })) => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tcp_carries_batch_frames_intact() {
        let base = 44000 + (std::process::id() % 1000) as u16;
        let mut addrs = HashMap::new();
        addrs.insert(Pid(1), format!("127.0.0.1:{}", base + 4).parse().unwrap());
        addrs.insert(Pid(2), format!("127.0.0.1:{}", base + 5).parse().unwrap());
        let mut a = TcpTransport::bind(Pid(1), addrs.clone()).unwrap();
        let mut b = TcpTransport::bind(Pid(2), addrs).unwrap();
        let frame = Wire::Batch((0..5).map(mcast).collect());
        a.send(Pid(2), frame.clone());
        match b.recv_timeout(Duration::from_secs(5)) {
            Some(Incoming::Wire(Pid(1), w)) => assert_eq!(w, frame),
            other => panic!("unexpected {other:?}"),
        }
    }
}
