//! Run observability: online latency/throughput accounting plus an
//! optional full event trace for correctness checking.

use crate::types::{Gid, GidSet, MsgId, Pid, ShardMap, Topology, Ts};
use std::collections::HashMap;

/// A delivery observed at a process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeliveryEv {
    pub time: u64,
    pub pid: Pid,
    pub m: MsgId,
    pub gts: Ts,
}

/// Latency bookkeeping for one in-flight multicast.
#[derive(Clone, Debug)]
struct Inflight {
    sent_at: u64,
    dest: GidSet,
    /// groups in which some process has already delivered
    first_delivered: GidSet,
}

/// Aggregated + optional full-resolution record of a run.
///
/// For sharded runs ([`Trace::new_sharded`]) deliveries are attributed
/// to their *local* (per-shard) group, so latency/completion accounting
/// works across all shards at once; correctness checking happens per
/// shard on the projections returned by [`Trace::shard_view`].
pub struct Trace {
    topo: Topology,
    map: ShardMap,
    /// Record every delivery event (needed by the correctness checkers;
    /// disable for long throughput runs).
    pub record_full: bool,
    pub multicasts: HashMap<MsgId, (u64, GidSet)>,
    pub deliveries: Vec<DeliveryEv>,
    pub crashes: Vec<(u64, Pid)>,
    /// processes that crashed and later restarted from durable storage:
    /// they are *correct* again, so [`Trace::on_restart`] removes them
    /// from `crashes` — the termination checker then holds them to the
    /// full quorum obligations (the strictest possible restart check)
    pub restarts: Vec<(u64, Pid)>,
    /// first-delivery latency samples (ns), one per (message, dest group)
    pub latencies: Vec<u64>,
    /// completion times of fully (partially-per-§II) delivered multicasts
    pub completions: Vec<u64>,
    inflight: HashMap<MsgId, Inflight>,
    pub sends: u64,
    pub send_bytes: u64,
    pub delivered_count: u64,
}

impl Trace {
    pub fn new(topo: Topology, record_full: bool) -> Self {
        let map = ShardMap::solo(&topo);
        Self::with_map(topo, map, record_full)
    }

    /// Trace for a sharded deployment.
    pub fn new_sharded(map: ShardMap, record_full: bool) -> Self {
        Self::with_map(map.topo(0), map, record_full)
    }

    fn with_map(topo: Topology, map: ShardMap, record_full: bool) -> Self {
        Trace {
            topo,
            map,
            record_full,
            multicasts: HashMap::new(),
            deliveries: Vec::new(),
            crashes: Vec::new(),
            restarts: Vec::new(),
            latencies: Vec::new(),
            completions: Vec::new(),
            inflight: HashMap::new(),
            sends: 0,
            send_bytes: 0,
            delivered_count: 0,
        }
    }

    /// Record the (first) multicast of `m`.
    pub fn on_multicast(&mut self, time: u64, m: MsgId, dest: GidSet) {
        if self.multicasts.contains_key(&m) {
            return; // client retransmission
        }
        self.multicasts.insert(m, (time, dest));
        self.inflight.insert(m, Inflight { sent_at: time, dest, first_delivered: GidSet::EMPTY });
    }

    /// The (per-shard local) group of a member pid, across all shards.
    fn member_group(&self, pid: Pid) -> Option<Gid> {
        if self.map.shards > 1 {
            self.map.local_group_of(pid)
        } else {
            self.topo.group_of(pid)
        }
    }

    /// Record a local delivery at `pid`.
    pub fn on_deliver(&mut self, time: u64, pid: Pid, m: MsgId, gts: Ts) {
        self.delivered_count += 1;
        if self.record_full {
            self.deliveries.push(DeliveryEv { time, pid, m, gts });
        }
        let Some(g) = self.member_group(pid) else { return };
        if let Some(fl) = self.inflight.get_mut(&m) {
            if !fl.first_delivered.contains(g) {
                fl.first_delivered.insert(g);
                self.latencies.push(time.saturating_sub(fl.sent_at));
                if fl.first_delivered == fl.dest {
                    self.completions.push(time);
                    self.inflight.remove(&m);
                }
            }
        }
    }

    pub fn on_crash(&mut self, time: u64, pid: Pid) {
        self.crashes.push((time, pid));
    }

    /// `pid` restarted from durable storage and is correct again: its
    /// crash entries are withdrawn, so every checker treats it exactly
    /// like a process that never failed (it must catch up on everything
    /// it missed — the recovery protocol's job).
    pub fn on_restart(&mut self, time: u64, pid: Pid) {
        self.crashes.retain(|&(_, p)| p != pid);
        self.restarts.push((time, pid));
    }

    /// Messages multicast but not yet delivered in all destination groups.
    pub fn incomplete(&self) -> usize {
        self.inflight.len()
    }

    /// Mean first-delivery latency (ns) over all (message, group) samples.
    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return f64::NAN;
        }
        self.latencies.iter().map(|&x| x as f64).sum::<f64>() / self.latencies.len() as f64
    }

    pub fn max_latency(&self) -> u64 {
        self.latencies.iter().copied().max().unwrap_or(0)
    }

    /// Completed multicasts per second over `[from, to)` (ns).
    pub fn throughput(&self, from: u64, to: u64) -> f64 {
        let n = self.completions.iter().filter(|&&t| t >= from && t < to).count();
        n as f64 / ((to - from) as f64 / 1e9)
    }

    /// Bin completions into `bin_ns` buckets over `[0, horizon)` —
    /// used by the Fig. 11 recovery timeline.
    pub fn throughput_bins(&self, bin_ns: u64, horizon: u64) -> Vec<f64> {
        let n = horizon.div_ceil(bin_ns) as usize;
        let mut bins = vec![0f64; n];
        for &t in &self.completions {
            if t < horizon {
                bins[((t / bin_ns) as usize).min(n - 1)] += 1.0;
            }
        }
        let scale = 1e9 / bin_ns as f64;
        for b in &mut bins {
            *b *= scale;
        }
        bins
    }

    /// FNV-1a digest over a canonical encoding of everything the trace
    /// observed: multicasts (sorted by id), deliveries (in delivery
    /// order), crashes, restarts, latency samples, completions and the
    /// aggregate counters. Two runs with identical digests saw the same
    /// events at the same virtual instants — the determinism pin the
    /// swarm's campaign summary hash is built from.
    pub fn digest(&self) -> u64 {
        fn fnv(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut ms: Vec<(MsgId, u64, GidSet)> =
            self.multicasts.iter().map(|(&m, &(t, d))| (m, t, d)).collect();
        ms.sort_unstable();
        for (m, t, d) in ms {
            fnv(&mut h, m.0);
            fnv(&mut h, t);
            fnv(&mut h, d.0);
        }
        for d in &self.deliveries {
            fnv(&mut h, d.time);
            fnv(&mut h, d.pid.0 as u64);
            fnv(&mut h, d.m.0);
            fnv(&mut h, d.gts.t);
            fnv(&mut h, d.gts.g.0 as u64);
        }
        for &(t, p) in self.crashes.iter().chain(&self.restarts) {
            fnv(&mut h, t);
            fnv(&mut h, p.0 as u64);
        }
        for &x in self.latencies.iter().chain(&self.completions) {
            fnv(&mut h, x);
        }
        fnv(&mut h, self.sends);
        fnv(&mut h, self.send_bytes);
        fnv(&mut h, self.delivered_count);
        h
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Number of shards this trace spans (1 for plain runs).
    pub fn shards(&self) -> usize {
        self.map.shards
    }

    /// Project the trace onto shard `s`: only that shard's multicasts,
    /// deliveries and crashes, against the shard's own topology. The
    /// per-shard projection is what the correctness checkers
    /// ([`crate::invariants`]) run on — shards are independent ordering
    /// domains, so e.g. gts uniqueness only holds within one. Requires
    /// `record_full`. Aggregate counters (`sends`, `send_bytes`) are not
    /// attributable per shard and stay zero in the projection.
    pub fn shard_view(&self, s: usize) -> Trace {
        assert!(self.record_full, "shard_view needs record_full = true");
        assert!(s < self.map.shards, "shard {s} out of range");
        let mut t = Trace::new(self.map.topo(s), true);
        for (&m, &(time, dest)) in &self.multicasts {
            if self.map.client_shard(Pid(m.client())) == s {
                t.on_multicast(time, m, dest);
            }
        }
        for d in &self.deliveries {
            if self.map.shard_of(d.pid) == Some(s) {
                t.on_deliver(d.time, d.pid, d.m, d.gts);
            }
        }
        for &(time, pid) in &self.crashes {
            if self.map.shard_of(pid) == Some(s) {
                t.on_crash(time, pid);
            }
        }
        for &(time, pid) in &self.restarts {
            if self.map.shard_of(pid) == Some(s) {
                t.restarts.push((time, pid));
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Gid;

    #[test]
    fn latency_and_completion_accounting() {
        let topo = Topology::new(2, 1);
        let mut tr = Trace::new(topo, true);
        let m = MsgId::new(9, 1);
        let dest = GidSet::from_iter([Gid(0), Gid(1)]);
        tr.on_multicast(100, m, dest);
        // duplicate multicast ignored
        tr.on_multicast(150, m, dest);
        tr.on_deliver(300, Pid(0), m, Ts::new(1, Gid(0))); // g0 first
        tr.on_deliver(350, Pid(1), m, Ts::new(1, Gid(0))); // g0 again: no sample
        assert_eq!(tr.latencies, vec![200]);
        assert_eq!(tr.completions.len(), 0);
        assert_eq!(tr.incomplete(), 1);
        tr.on_deliver(400, Pid(3), m, Ts::new(1, Gid(0))); // g1
        assert_eq!(tr.latencies, vec![200, 300]);
        assert_eq!(tr.completions, vec![400]);
        assert_eq!(tr.incomplete(), 0);
        assert_eq!(tr.delivered_count, 3);
    }

    #[test]
    fn client_deliveries_ignored_for_latency() {
        let topo = Topology::new(1, 1);
        let mut tr = Trace::new(topo, false);
        let m = MsgId::new(1, 1);
        tr.on_multicast(0, m, GidSet::single(Gid(0)));
        tr.on_deliver(10, Pid(99), m, Ts::BOT); // client pid: not a member
        assert!(tr.latencies.is_empty());
    }

    #[test]
    fn sharded_trace_attribution_and_projection() {
        let map = ShardMap::new(2, 1, 2); // 2 groups x 3 members x 2 shards; clients from 12
        let mut tr = Trace::new_sharded(map, true);
        let m0 = MsgId::new(12, 1); // shard-0 client
        let m1 = MsgId::new(13, 1); // shard-1 client
        tr.on_multicast(0, m0, GidSet::from_iter([Gid(0), Gid(1)]));
        tr.on_multicast(0, m1, GidSet::single(Gid(0)));
        tr.on_deliver(100, Pid(3), m0, Ts::new(1, Gid(1))); // shard 0, local g1
        tr.on_deliver(150, Pid(0), m0, Ts::new(1, Gid(1))); // shard 0, local g0
        tr.on_deliver(120, Pid(6), m1, Ts::new(1, Gid(0))); // shard 1, local g0
        // local-group attribution: both messages complete
        assert_eq!(tr.latencies, vec![100, 150, 120]);
        assert_eq!(tr.completions, vec![150, 120]);
        assert_eq!(tr.incomplete(), 0);

        // per-shard projections split the record cleanly
        let v0 = tr.shard_view(0);
        assert_eq!(v0.multicasts.len(), 1);
        assert_eq!(v0.deliveries.len(), 2);
        assert_eq!(v0.completions, vec![150]);
        let v1 = tr.shard_view(1);
        assert_eq!(v1.deliveries.len(), 1);
        assert_eq!(v1.completions, vec![120]);
        assert_eq!(v1.topo().group_of(Pid(6)), Some(Gid(0)));
    }

    #[test]
    fn throughput_bins_scale() {
        let topo = Topology::new(1, 1);
        let mut tr = Trace::new(topo, false);
        // 4 completions in the first second, 2 in the second
        for (i, t) in [100, 200, 300, 400, 1_300_000_000u64, 1_600_000_000].iter().enumerate() {
            let m = MsgId::new(1, i as u32);
            tr.on_multicast(0, m, GidSet::single(Gid(0)));
            tr.on_deliver(*t, Pid(0), m, Ts::new(i as u64 + 1, Gid(0)));
        }
        let bins = tr.throughput_bins(1_000_000_000, 2_000_000_000);
        assert_eq!(bins, vec![4.0, 2.0]);
        assert!((tr.throughput(0, 2_000_000_000) - 3.0).abs() < 1e-9);
    }
}
