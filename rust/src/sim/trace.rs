//! Run observability: online latency/throughput accounting plus an
//! optional full event trace for correctness checking.

use crate::types::{GidSet, MsgId, Pid, Topology, Ts};
use std::collections::HashMap;

/// A delivery observed at a process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeliveryEv {
    pub time: u64,
    pub pid: Pid,
    pub m: MsgId,
    pub gts: Ts,
}

/// Latency bookkeeping for one in-flight multicast.
#[derive(Clone, Debug)]
struct Inflight {
    sent_at: u64,
    dest: GidSet,
    /// groups in which some process has already delivered
    first_delivered: GidSet,
}

/// Aggregated + optional full-resolution record of a run.
pub struct Trace {
    topo: Topology,
    /// Record every delivery event (needed by the correctness checkers;
    /// disable for long throughput runs).
    pub record_full: bool,
    pub multicasts: HashMap<MsgId, (u64, GidSet)>,
    pub deliveries: Vec<DeliveryEv>,
    pub crashes: Vec<(u64, Pid)>,
    /// first-delivery latency samples (ns), one per (message, dest group)
    pub latencies: Vec<u64>,
    /// completion times of fully (partially-per-§II) delivered multicasts
    pub completions: Vec<u64>,
    inflight: HashMap<MsgId, Inflight>,
    pub sends: u64,
    pub send_bytes: u64,
    pub delivered_count: u64,
}

impl Trace {
    pub fn new(topo: Topology, record_full: bool) -> Self {
        Trace {
            topo,
            record_full,
            multicasts: HashMap::new(),
            deliveries: Vec::new(),
            crashes: Vec::new(),
            latencies: Vec::new(),
            completions: Vec::new(),
            inflight: HashMap::new(),
            sends: 0,
            send_bytes: 0,
            delivered_count: 0,
        }
    }

    /// Record the (first) multicast of `m`.
    pub fn on_multicast(&mut self, time: u64, m: MsgId, dest: GidSet) {
        if self.multicasts.contains_key(&m) {
            return; // client retransmission
        }
        self.multicasts.insert(m, (time, dest));
        self.inflight.insert(m, Inflight { sent_at: time, dest, first_delivered: GidSet::EMPTY });
    }

    /// Record a local delivery at `pid`.
    pub fn on_deliver(&mut self, time: u64, pid: Pid, m: MsgId, gts: Ts) {
        self.delivered_count += 1;
        if self.record_full {
            self.deliveries.push(DeliveryEv { time, pid, m, gts });
        }
        let Some(g) = self.topo.group_of(pid) else { return };
        if let Some(fl) = self.inflight.get_mut(&m) {
            if !fl.first_delivered.contains(g) {
                fl.first_delivered.insert(g);
                self.latencies.push(time.saturating_sub(fl.sent_at));
                if fl.first_delivered == fl.dest {
                    self.completions.push(time);
                    self.inflight.remove(&m);
                }
            }
        }
    }

    pub fn on_crash(&mut self, time: u64, pid: Pid) {
        self.crashes.push((time, pid));
    }

    /// Messages multicast but not yet delivered in all destination groups.
    pub fn incomplete(&self) -> usize {
        self.inflight.len()
    }

    /// Mean first-delivery latency (ns) over all (message, group) samples.
    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return f64::NAN;
        }
        self.latencies.iter().map(|&x| x as f64).sum::<f64>() / self.latencies.len() as f64
    }

    pub fn max_latency(&self) -> u64 {
        self.latencies.iter().copied().max().unwrap_or(0)
    }

    /// Completed multicasts per second over `[from, to)` (ns).
    pub fn throughput(&self, from: u64, to: u64) -> f64 {
        let n = self.completions.iter().filter(|&&t| t >= from && t < to).count();
        n as f64 / ((to - from) as f64 / 1e9)
    }

    /// Bin completions into `bin_ns` buckets over `[0, horizon)` —
    /// used by the Fig. 11 recovery timeline.
    pub fn throughput_bins(&self, bin_ns: u64, horizon: u64) -> Vec<f64> {
        let n = horizon.div_ceil(bin_ns) as usize;
        let mut bins = vec![0f64; n];
        for &t in &self.completions {
            if t < horizon {
                bins[((t / bin_ns) as usize).min(n - 1)] += 1.0;
            }
        }
        let scale = 1e9 / bin_ns as f64;
        for b in &mut bins {
            *b *= scale;
        }
        bins
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Gid;

    #[test]
    fn latency_and_completion_accounting() {
        let topo = Topology::new(2, 1);
        let mut tr = Trace::new(topo, true);
        let m = MsgId::new(9, 1);
        let dest = GidSet::from_iter([Gid(0), Gid(1)]);
        tr.on_multicast(100, m, dest);
        // duplicate multicast ignored
        tr.on_multicast(150, m, dest);
        tr.on_deliver(300, Pid(0), m, Ts::new(1, Gid(0))); // g0 first
        tr.on_deliver(350, Pid(1), m, Ts::new(1, Gid(0))); // g0 again: no sample
        assert_eq!(tr.latencies, vec![200]);
        assert_eq!(tr.completions.len(), 0);
        assert_eq!(tr.incomplete(), 1);
        tr.on_deliver(400, Pid(3), m, Ts::new(1, Gid(0))); // g1
        assert_eq!(tr.latencies, vec![200, 300]);
        assert_eq!(tr.completions, vec![400]);
        assert_eq!(tr.incomplete(), 0);
        assert_eq!(tr.delivered_count, 3);
    }

    #[test]
    fn client_deliveries_ignored_for_latency() {
        let topo = Topology::new(1, 1);
        let mut tr = Trace::new(topo, false);
        let m = MsgId::new(1, 1);
        tr.on_multicast(0, m, GidSet::single(Gid(0)));
        tr.on_deliver(10, Pid(99), m, Ts::BOT); // client pid: not a member
        assert!(tr.latencies.is_empty());
    }

    #[test]
    fn throughput_bins_scale() {
        let topo = Topology::new(1, 1);
        let mut tr = Trace::new(topo, false);
        // 4 completions in the first second, 2 in the second
        for (i, t) in [100, 200, 300, 400, 1_300_000_000u64, 1_600_000_000].iter().enumerate() {
            let m = MsgId::new(1, i as u32);
            tr.on_multicast(0, m, GidSet::single(Gid(0)));
            tr.on_deliver(*t, Pid(0), m, Ts::new(i as u64 + 1, Gid(0)));
        }
        let bins = tr.throughput_bins(1_000_000_000, 2_000_000_000);
        assert_eq!(bins, vec![4.0, 2.0]);
        assert!((tr.throughput(0, 2_000_000_000) - 3.0).abs() < 1e-9);
    }
}
