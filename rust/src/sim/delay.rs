//! Network delay models for the discrete-event simulator.
//!
//! All delays are one-way, in nanoseconds. Models are calibrated to the
//! paper's two testbeds:
//!
//! * LAN (CloudLab): ~0.1 ms RTT → 50 µs one-way, small exponential jitter.
//! * WAN (GCP, 3 data centres): RTTs Oregon↔Virginia 60 ms,
//!   Virginia↔England 75 ms, Oregon↔England 130 ms.

use crate::types::Pid;
use crate::util::Rng;

pub const MS: u64 = 1_000_000;
pub const US: u64 = 1_000;

/// One-way message delay between two processes.
pub trait DelayModel: Send {
    fn sample(&self, rng: &mut Rng, from: Pid, to: Pid) -> u64;
    /// Upper bound δ on failure-free delay (for theory checks / LSS
    /// timeouts). Jittered models return their ~p99.9 bound.
    fn delta(&self) -> u64;
}

/// Constant delay δ for every link — the §V theory setting.
#[derive(Clone, Copy, Debug)]
pub struct ConstDelay(pub u64);

impl DelayModel for ConstDelay {
    fn sample(&self, _rng: &mut Rng, _from: Pid, _to: Pid) -> u64 {
        self.0
    }
    fn delta(&self) -> u64 {
        self.0
    }
}

/// LAN: base one-way delay + exponential jitter.
#[derive(Clone, Copy, Debug)]
pub struct LanDelay {
    pub base: u64,
    pub jitter_mean: u64,
}

impl LanDelay {
    /// Paper's CloudLab network: ~0.1 ms RTT.
    pub fn cloudlab() -> Self {
        LanDelay { base: 50 * US, jitter_mean: 5 * US }
    }
}

impl DelayModel for LanDelay {
    fn sample(&self, rng: &mut Rng, _from: Pid, _to: Pid) -> u64 {
        self.base + rng.exp(self.jitter_mean as f64) as u64
    }
    fn delta(&self) -> u64 {
        self.base + 7 * self.jitter_mean // ~p99.9 of exp jitter
    }
}

/// WAN over `k` sites with an explicit one-way delay matrix.
/// `site_of` maps a process to its data centre.
#[derive(Clone)]
pub struct WanDelay {
    /// one-way delays between sites, ns; `oneway[a][b]`.
    pub oneway: Vec<Vec<u64>>,
    pub site_of: std::sync::Arc<dyn Fn(Pid) -> usize + Send + Sync>,
    pub jitter_mean: u64,
}

impl WanDelay {
    /// Paper's GCP deployment: R1=Oregon, R2=N.Virginia, R3=England;
    /// RTTs 60/75/130 ms. Same-site delay ~0.25 ms one-way.
    pub fn gcp3(site_of: impl Fn(Pid) -> usize + Send + Sync + 'static) -> Self {
        let same = 250 * US;
        let ow = |rtt_ms: u64| rtt_ms * MS / 2;
        WanDelay {
            oneway: vec![
                vec![same, ow(60), ow(130)],
                vec![ow(60), same, ow(75)],
                vec![ow(130), ow(75), same],
            ],
            site_of: std::sync::Arc::new(site_of),
            jitter_mean: 500 * US,
        }
    }
}

impl DelayModel for WanDelay {
    fn sample(&self, rng: &mut Rng, from: Pid, to: Pid) -> u64 {
        let a = (self.site_of)(from);
        let b = (self.site_of)(to);
        self.oneway[a][b] + rng.exp(self.jitter_mean as f64) as u64
    }
    fn delta(&self) -> u64 {
        let max = self.oneway.iter().flatten().copied().max().unwrap_or(0);
        max + 7 * self.jitter_mean
    }
}

/// Constant δ with per-link overrides — used to construct the
/// adversarial worst-case timings of the §V failure-free-latency
/// analysis (e.g. Fig. 2's convoy scenario, where one MULTICAST travels
/// in ~0 while the others take exactly δ).
pub struct AdversarialDelay {
    pub base: u64,
    pub overrides: std::collections::HashMap<(Pid, Pid), u64>,
}

impl AdversarialDelay {
    pub fn new(base: u64) -> Self {
        AdversarialDelay { base, overrides: Default::default() }
    }
    pub fn set(mut self, from: Pid, to: Pid, d: u64) -> Self {
        self.overrides.insert((from, to), d);
        self
    }
}

impl DelayModel for AdversarialDelay {
    fn sample(&self, _rng: &mut Rng, from: Pid, to: Pid) -> u64 {
        self.overrides.get(&(from, to)).copied().unwrap_or(self.base)
    }
    fn delta(&self) -> u64 {
        self.base.max(self.overrides.values().copied().max().unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversarial_overrides_links() {
        let d = AdversarialDelay::new(1000).set(Pid(5), Pid(0), 1);
        let mut r = Rng::new(0);
        assert_eq!(d.sample(&mut r, Pid(5), Pid(0)), 1);
        assert_eq!(d.sample(&mut r, Pid(0), Pid(5)), 1000);
        assert_eq!(d.delta(), 1000);
    }

    #[test]
    fn const_delay_is_constant() {
        let d = ConstDelay(10 * MS);
        let mut r = Rng::new(1);
        assert_eq!(d.sample(&mut r, Pid(0), Pid(1)), 10 * MS);
        assert_eq!(d.delta(), 10 * MS);
    }

    #[test]
    fn lan_jitter_bounded_below_by_base() {
        let d = LanDelay::cloudlab();
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(d.sample(&mut r, Pid(0), Pid(1)) >= d.base);
        }
    }

    #[test]
    fn wan_matrix_symmetric_sites() {
        let d = WanDelay::gcp3(|p| p.0 as usize % 3);
        let mut r = Rng::new(3);
        // Oregon -> England one-way is at least 65ms
        let s = d.sample(&mut r, Pid(0), Pid(2));
        assert!(s >= 65 * MS, "{s}");
        // same site is sub-ms plus jitter
        let s = d.sample(&mut r, Pid(0), Pid(3));
        assert!(s < 10 * MS);
    }
}
