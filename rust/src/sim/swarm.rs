//! Swarm runner: execute [`NemesisSchedule`]s under the strict
//! invariant suite, campaign over thousands of seeds, and minimize
//! failing schedules with delta debugging.
//!
//! Everything here is deterministic: a schedule (itself a pure function
//! of its seed) builds a [`World`] whose run is a pure function of the
//! schedule, so [`run`] always returns the same [`Outcome`] — including
//! the trace digest — and [`campaign`]'s summary hash is reproducible
//! bit-for-bit across machines. That determinism is what makes a saved
//! JSON schedule a *reproducer* rather than a hint, and what lets
//! [`minimize`]'s ddmin loop trust every probe it makes.
//!
//! Used by `rust/tests/swarm.rs` (the in-tree entry point) and by
//! `cargo xtask swarm` (the campaign CLI with JSON/flight artifacts).

use super::nemesis::{NemesisSchedule, Shim};
use super::World;
use crate::harness::{build_world, enable_wb_storage, Net, Proto, RunCfg};
use crate::protocols::wbcast::WbConfig;
use crate::protocols::{Node, Outbox, TimerKind};
use crate::types::{Pid, Topology, Wire};

/// Result of one schedule run.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Strict-check violations plus liveness/panic findings; empty =
    /// the schedule passed.
    pub violations: Vec<String>,
    /// [`super::Trace::digest`] of the run (0 if the run panicked).
    pub digest: u64,
    /// Rendered flight-recorder tail (only on failure; empty otherwise).
    pub flight: String,
}

impl Outcome {
    pub fn failed(&self) -> bool {
        !self.violations.is_empty()
    }
}

/// One failing schedule inside a [`Campaign`].
#[derive(Clone, Debug)]
pub struct Failure {
    /// Campaign index (the schedule's seed is derived from it).
    pub index: u64,
    pub schedule: NemesisSchedule,
    pub outcome: Outcome,
}

/// Result of a [`campaign`] over `schedules` seeds.
#[derive(Debug)]
pub struct Campaign {
    pub schedules: u64,
    pub failures: Vec<Failure>,
    /// FNV fold of every run's (index, digest, violation count): equal
    /// summaries ⇔ the whole campaign behaved identically.
    pub summary: u64,
}

/// Build the simulated deployment a schedule describes: a WbCast world
/// with durability + per-member storage/rebuilders, the flight recorder
/// armed, the optional violation shim installed, and every nemesis
/// event applied. The world has not started yet.
pub fn build(s: &NemesisSchedule) -> World {
    let delta = s.delta;
    let mut cfg = RunCfg::new(Proto::WbCast, s.groups, s.clients, s.dest_groups, Net::Theory { delta });
    cfg.seed = s.seed;
    cfg.max_requests = Some(s.reqs);
    cfg.record_full = true;
    cfg.resend_after = 40 * delta;
    let mut wb = WbConfig::with_failures(delta);
    wb.durability = true; // journaled: restarts recover through the WAL
    cfg.wb = wb;
    let mut w = build_world(&cfg);
    enable_wb_storage(&mut w, &Topology::new(s.groups, 1), wb);
    w.enable_flight(4096);
    if let Some(Shim::DoubleDeliver { pid, nth }) = &s.shim {
        let n = *nth;
        w.wrap_node(*pid, move |inner| Box::new(DoubleDeliverShim { inner, remaining: n }));
    }
    for e in &s.events {
        super::nemesis::apply(&mut w, e);
    }
    w
}

/// Run one schedule to its horizon and check it: strict safety +
/// termination ([`crate::invariants::check_correct`]) plus the no-stuck-
/// messages liveness the crash property tests assert. Panics inside the
/// run (livelock guards, protocol assertions) are caught and reported
/// as violations so a campaign never dies mid-flight.
pub fn run(s: &NemesisSchedule) -> Outcome {
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut w = build(s);
        w.run_until(s.horizon);
        let mut violations: Vec<String> =
            crate::invariants::check_correct(&w.trace).iter().map(|v| v.to_string()).collect();
        if w.trace.incomplete() > 0 {
            violations
                .push(format!("[liveness] {} multicasts incomplete at horizon", w.trace.incomplete()));
        }
        let flight = if violations.is_empty() {
            String::new()
        } else {
            w.flight().map(|f| f.render()).unwrap_or_default()
        };
        Outcome { violations, digest: w.trace.digest(), flight }
    }));
    out.unwrap_or_else(|e| Outcome {
        violations: vec![format!("[panic] {}", panic_msg(&*e))],
        digest: 0,
        flight: String::new(),
    })
}

/// Derive the schedule seed for campaign index `i` (splitmix-style, so
/// neighbouring indices explore unrelated schedules).
pub fn schedule_seed(campaign_seed: u64, i: u64) -> u64 {
    let mut z = campaign_seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Run `schedules` generated schedules derived from `seed`, calling
/// `each` after every run (progress reporting; pass `|_, _|()` to skip).
pub fn campaign_with<F: FnMut(u64, &Outcome)>(schedules: u64, seed: u64, mut each: F) -> Campaign {
    let mut failures = Vec::new();
    let mut summary = 0xcbf2_9ce4_8422_2325u64;
    let mut fold = |h: &mut u64, x: u64| {
        for b in x.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for i in 0..schedules {
        let s = NemesisSchedule::generate(schedule_seed(seed, i));
        let o = run(&s);
        fold(&mut summary, i);
        fold(&mut summary, o.digest);
        fold(&mut summary, o.violations.len() as u64);
        each(i, &o);
        if o.failed() {
            failures.push(Failure { index: i, schedule: s, outcome: o });
        }
    }
    Campaign { schedules, failures, summary }
}

/// [`campaign_with`] without a progress callback.
pub fn campaign(schedules: u64, seed: u64) -> Campaign {
    campaign_with(schedules, seed, |_, _| ())
}

/// Delta-debug a failing schedule down to a minimal reproducing event
/// list (classic ddmin: try subsets, then complements, doubling
/// granularity). The workload shape, seed and shim are preserved —
/// only `events` shrinks. Returns the input unchanged if it does not
/// actually fail.
pub fn minimize(s: &NemesisSchedule) -> NemesisSchedule {
    let with = |events: &[super::nemesis::NemesisEvent]| {
        let mut t = s.clone();
        t.events = events.to_vec();
        t
    };
    let fails = |events: &[super::nemesis::NemesisEvent]| run(&with(events)).failed();
    if !fails(&s.events) {
        return s.clone();
    }
    // fast path: shim-only failures reproduce with no faults at all
    if fails(&[]) {
        return with(&[]);
    }
    let mut events = s.events.clone();
    let mut n = 2usize;
    while events.len() >= 2 {
        let len = events.len();
        let chunk = len.div_ceil(n);
        let mut reduced = false;
        // try each subset chunk alone
        let mut subset = None;
        for st in (0..len).step_by(chunk) {
            let c = &events[st..(st + chunk).min(len)];
            if c.len() < len && fails(c) {
                subset = Some(c.to_vec());
                break;
            }
        }
        if let Some(sub) = subset {
            events = sub;
            n = 2;
            reduced = true;
        }
        if !reduced {
            // try each complement (all but one chunk)
            let starts: Vec<usize> = (0..len).step_by(chunk).collect();
            for &st in &starts {
                let end = (st + chunk).min(len);
                let comp: Vec<_> =
                    events[..st].iter().chain(&events[end..]).cloned().collect();
                if !comp.is_empty() && comp.len() < len && fails(&comp) {
                    events = comp;
                    n = n.saturating_sub(1).max(2);
                    reduced = true;
                    break;
                }
            }
        }
        if !reduced {
            if n >= len {
                break; // single-event granularity reached: 1-minimal
            }
            n = (n * 2).min(len);
        }
    }
    with(&events)
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic".to_string()
    }
}

/// Test-only wrapper seeding a known integrity violation: delegates
/// every handler to the wrapped node and duplicates its `nth` delivery
/// (1-based) — the swarm must catch it, and the minimizer must shrink
/// the surrounding schedule. Installed via [`World::wrap_node`] when a
/// schedule carries [`Shim::DoubleDeliver`].
struct DoubleDeliverShim {
    inner: Box<dyn Node>,
    /// deliveries left until the duplicate fires (0 = already fired)
    remaining: u32,
}

impl DoubleDeliverShim {
    fn tamper(&mut self, before: usize, out: &mut Outbox) {
        if self.remaining == 0 {
            return;
        }
        for i in before..out.delivers.len() {
            self.remaining -= 1;
            if self.remaining == 0 {
                let dup = out.delivers[i]; // DeliverEffect is Copy
                out.delivers.push(dup);
                return;
            }
        }
    }
}

impl Node for DoubleDeliverShim {
    fn pid(&self) -> Pid {
        self.inner.pid()
    }
    fn on_start(&mut self, now: u64, out: &mut Outbox) {
        let before = out.delivers.len();
        self.inner.on_start(now, out);
        self.tamper(before, out);
    }
    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
        let before = out.delivers.len();
        self.inner.on_wire(from, wire, now, out);
        self.tamper(before, out);
    }
    fn on_timer(&mut self, timer: TimerKind, now: u64, out: &mut Outbox) {
        let before = out.delivers.len();
        self.inner.on_timer(timer, now, out);
        self.tamper(before, out);
    }
    fn on_crash(&mut self, now: u64) {
        self.inner.on_crash(now);
    }
}
