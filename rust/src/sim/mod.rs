//! Deterministic discrete-event simulator.
//!
//! Runs a set of [`Node`] state machines over a virtual network with a
//! pluggable [`DelayModel`], a per-process CPU cost model (single-threaded
//! servers with a busy-until queue, which produces the saturation knees of
//! the paper's throughput figures), FIFO reliable channels, and crash
//! injection. Every run is a pure function of `(nodes, config, seed)`.

pub mod delay;
pub mod nemesis;
pub mod swarm;
pub mod trace;

pub use delay::{ConstDelay, DelayModel, LanDelay, WanDelay, MS, US};
pub use nemesis::{NemesisEvent, NemesisSchedule};
pub use trace::{DeliveryEv, Trace};

use crate::protocols::{LinkCoalescer, Node, Outbox, TimerKind};
use crate::types::{FlushPolicy, Pid, ShardMap, Topology, Wire};
use crate::util::{FxHashMap, Rng};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Per-event CPU cost model. `zero()` gives the idealised §V setting where
/// local steps are instantaneous.
#[derive(Clone, Copy, Debug)]
pub struct CpuCost {
    /// fixed cost of handling any message/timer
    pub recv_ns: u64,
    /// additional cost per payload byte
    pub per_byte_ns: u64,
    /// cost per emitted message
    pub send_ns: u64,
    /// extra cost for handling a black-box consensus message (log slot
    /// bookkeeping, command (de)serialisation, RSM apply machinery) —
    /// the "overhead introduced by its parallel execution paths" the
    /// paper measures for FastCast/FT-Skeen in the CPU-bound LAN runs
    /// (§VI); calibrated in EXPERIMENTS.md §Calibration
    pub paxos_extra_ns: u64,
}

impl CpuCost {
    pub fn zero() -> Self {
        CpuCost { recv_ns: 0, per_byte_ns: 0, send_ns: 0, paxos_extra_ns: 0 }
    }
    /// Calibrated to a libevent-style C server on a 10-core Xeon:
    /// a few µs of syscall + protocol work per message, with consensus
    /// messages paying the black-box machinery on top (see
    /// EXPERIMENTS.md §Calibration).
    pub fn lan_server() -> Self {
        CpuCost { recv_ns: 1_500, per_byte_ns: 2, send_ns: 1_000, paxos_extra_ns: 12_000 }
    }
}

#[derive(Clone, Debug)]
enum EventKind {
    Arrival { from: Pid, wire: Wire },
    Timer(TimerKind),
    Crash,
    /// restart a crashed process from its simulated durable storage
    /// ([`World::enable_storage`]): the node is rebuilt from the
    /// [`crate::storage::MemWal`] fold — state round-trips through the
    /// on-disk record codec — and rejoins via `on_start`
    Restart,
    /// wake a busy process to work through its backlog queue
    Drain,
    /// a held link's [`FlushPolicy`] delay window expired — emit what is
    /// due (the virtual-time analogue of the real runtimes' bounded
    /// sleep on the coalescer deadline)
    FlushDue,
}

/// Rebuilds a node from its recovered storage image at restart
/// (registered per pid via [`World::enable_storage`]).
pub type RestartFn = Box<dyn FnMut(crate::storage::Snapshot) -> Box<dyn Node>>;

/// Active nemesis fault windows (see [`nemesis`] for the schedule layer).
///
/// All collections default to empty, and every hook below consults them
/// with plain scans that consume **no randomness** when nothing matches —
/// a zero-fault world is therefore event-for-event identical to a world
/// without the machinery (pinned by `tests/swarm.rs`). Schedules are
/// small (tens of windows), so linear scans beat map overhead here.
#[derive(Default)]
struct Faults {
    /// one-way link blocks `(from, to, start, heal)`: frames shipped on
    /// the link while `start ≤ now < heal` are held and arrive no
    /// earlier than the heal instant (partitions delay, never drop —
    /// the asynchronous reliable-link model stays intact, so the strict
    /// invariant checks remain exact)
    blocked: Vec<(Pid, Pid, u64, u64)>,
    /// delay jitter `(from, to, start, end, extra_max)`: frames shipped
    /// in the window pick up a seeded extra delay in `[0, extra_max]`
    jitter: Vec<(Pid, Pid, u64, u64, u64)>,
    /// duplication windows `(from, to, start, end)`: each frame shipped
    /// in the window arrives twice (FIFO-respecting second copy)
    dup: Vec<(Pid, Pid, u64, u64)>,
    /// reorder windows `(from, to, start, end)`: the FIFO clamp is
    /// bypassed for frames shipped in the window — deliberately outside
    /// the protocols' reliable-FIFO assumption (targeted tests only)
    reorder: Vec<(Pid, Pid, u64, u64)>,
    /// per-node timer-wheel skew `(pid, from_t, ppm)`: timers armed
    /// from `from_t` on stretch (+ppm) or shrink (−ppm) by parts-per-million
    skew: Vec<(Pid, u64, i64)>,
    /// gray failure `(pid, start, end, extra_ns)`: the node stays alive
    /// but every event it handles costs `extra_ns` more CPU
    slow: Vec<(Pid, u64, u64, u64)>,
    /// slow disk `(pid, start, end, extra_ns)`: each journaled record
    /// costs `extra_ns` extra inside the window
    disk_slow: Vec<(Pid, u64, u64, u64)>,
    /// one-shot disk faults `(pid, at, fault, cut_bp)`: armed into the
    /// pid's [`crate::storage::MemWal`] at its first journaling event
    /// at or after `at`
    disk_fault: Vec<(Pid, u64, crate::storage::WalFault, u32)>,
}

impl Faults {
    /// Latest heal instant among blocks covering `(from, to)` at `now`.
    fn block_until(&self, from: Pid, to: Pid, now: u64) -> Option<u64> {
        self.blocked
            .iter()
            .filter(|&&(f, t, s, h)| f == from && t == to && s <= now && now < h)
            .map(|&(_, _, _, h)| h)
            .max()
    }

    /// Largest jitter bound active on `(from, to)` at `now`.
    fn jitter_max(&self, from: Pid, to: Pid, now: u64) -> Option<u64> {
        self.jitter
            .iter()
            .filter(|&&(f, t, s, e, _)| f == from && t == to && s <= now && now < e)
            .map(|&(_, _, _, _, x)| x)
            .max()
    }

    fn dup_active(&self, from: Pid, to: Pid, now: u64) -> bool {
        self.dup.iter().any(|&(f, t, s, e)| f == from && t == to && s <= now && now < e)
    }

    fn reorder_active(&self, from: Pid, to: Pid, now: u64) -> bool {
        self.reorder.iter().any(|&(f, t, s, e)| f == from && t == to && s <= now && now < e)
    }

    /// Apply `pid`'s timer skew to a delay of `after` ns (last-set wins).
    fn skewed(&self, pid: Pid, after: u64, now: u64) -> u64 {
        let ppm = self
            .skew
            .iter()
            .rev()
            .find(|&&(p, from_t, _)| p == pid && from_t <= now)
            .map(|&(_, _, ppm)| ppm)
            .unwrap_or(0);
        if ppm == 0 {
            return after;
        }
        let skewed = after as i128 + (after as i128 * ppm as i128) / 1_000_000;
        skewed.max(0) as u64
    }

    /// Extra per-event CPU cost of a gray-slow window at `pid`.
    fn slow_extra(&self, pid: Pid, now: u64) -> u64 {
        self.slow
            .iter()
            .filter(|&&(p, s, e, _)| p == pid && s <= now && now < e)
            .map(|&(_, _, _, x)| x)
            .max()
            .unwrap_or(0)
    }

    /// Extra per-record journaling cost of a slow-disk window at `pid`.
    fn disk_extra(&self, pid: Pid, now: u64) -> u64 {
        self.disk_slow
            .iter()
            .filter(|&&(p, s, e, _)| p == pid && s <= now && now < e)
            .map(|&(_, _, _, x)| x)
            .max()
            .unwrap_or(0)
    }

    /// Remove and return a disk fault due for `pid` at `now`.
    fn take_disk_fault(&mut self, pid: Pid, now: u64) -> Option<(crate::storage::WalFault, u32)> {
        let i = self.disk_fault.iter().position(|&(p, at, _, _)| p == pid && at <= now)?;
        let (_, _, fault, cut) = self.disk_fault.remove(i);
        Some((fault, cut))
    }
}

#[derive(Clone, Debug)]
struct Event {
    time: u64,
    seq: u64,
    to: Pid,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        (self.time, self.seq) == (other.time, other.seq)
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Simulator configuration.
pub struct SimConfig {
    pub delay: Box<dyn DelayModel>,
    pub cpu: CpuCost,
    pub seed: u64,
    /// record full delivery trace (correctness checks)
    pub record_full: bool,
    /// coalesce same-destination sends into [`Wire::Batch`] arrivals
    /// (one frame = one arrival event, one `recv_ns` + `send_ns`
    /// charge). Off models the seed's message-at-a-time server.
    pub coalesce: bool,
    /// per-link flush policy applied when coalescing (the same
    /// [`LinkCoalescer`] semantics the real runtimes use; the default
    /// flushes every event's sends immediately)
    pub flush: FlushPolicy,
}

impl SimConfig {
    pub fn theory(delta: u64) -> Self {
        SimConfig {
            delay: Box::new(ConstDelay(delta)),
            cpu: CpuCost::zero(),
            seed: 0,
            record_full: true,
            coalesce: true,
            flush: FlushPolicy::default(),
        }
    }
}

/// The virtual world: nodes + network + clock.
pub struct World {
    nodes: Vec<Box<dyn Node>>,
    pid_index: FxHashMap<Pid, usize>,
    heap: BinaryHeap<Reverse<Event>>,
    now: u64,
    seq: u64,
    rng: Rng,
    delay: Box<dyn DelayModel>,
    cpu: CpuCost,
    busy_until: Vec<u64>,
    crashed: Vec<bool>,
    /// per-process backlog of events that arrived while busy (FIFO);
    /// drained one per `Drain` wake-up — keeps saturation O(1) per event
    backlog: Vec<std::collections::VecDeque<EventKind>>,
    drain_scheduled: Vec<bool>,
    /// last scheduled arrival per (from, to): reliable FIFO channels
    fifo_last: FxHashMap<(Pid, Pid), u64>,
    /// per-node count of received protocol messages (genuineness checks;
    /// batch frames count once per inner message)
    pub arrivals: FxHashMap<Pid, u64>,
    pub trace: Trace,
    started: bool,
    /// reusable effects sink shared by all node handlers (one event runs
    /// at a time, so a single outbox suffices — zero per-event allocs)
    outbox: Outbox,
    /// per-node link coalescers enforcing the flush policy (under the
    /// default immediate policy they drain fully at every event, exactly
    /// the old one-frame-per-cycle behaviour)
    links: Vec<LinkCoalescer<Pid>>,
    /// earliest outstanding [`EventKind::FlushDue`] per node (dedup)
    flush_scheduled: Vec<Option<u64>>,
    /// reusable per-event frame buffer (coalesced sends awaiting emission)
    frames: Vec<(Pid, Wire)>,
    /// wire batching on/off (SimConfig::coalesce)
    coalesce: bool,
    /// per-pid simulated durable storage (journal records persist here
    /// at the end of the event that produced them — the sim's events
    /// are atomic, so this matches the runtimes' commit-before-send)
    stores: FxHashMap<Pid, crate::storage::MemWal>,
    /// per-pid node factories consulted by [`EventKind::Restart`]
    rebuilders: FxHashMap<Pid, RestartFn>,
    /// opt-in protocol flight recorder ([`World::enable_flight`]): a
    /// bounded ring of recent wire/journal/delivery events the harness
    /// dumps when an invariant fails
    flight: Option<std::sync::Arc<crate::obs::FlightRecorder>>,
    /// nemesis fault windows (all empty unless a schedule armed them)
    faults: Faults,
    /// debug: print every handled event (env `WBAM_SIM_LOG=1`)
    pub log_events: bool,
}

impl World {
    pub fn new(topo: Topology, nodes: Vec<Box<dyn Node>>, cfg: SimConfig) -> Self {
        Self::with_trace(Trace::new(topo, cfg.record_full), nodes, cfg)
    }

    /// A sharded deployment: `nodes` holds every shard's members plus the
    /// clients; the trace attributes deliveries per shard via `map`.
    pub fn new_sharded(map: ShardMap, nodes: Vec<Box<dyn Node>>, cfg: SimConfig) -> Self {
        Self::with_trace(Trace::new_sharded(map, cfg.record_full), nodes, cfg)
    }

    fn with_trace(trace: Trace, nodes: Vec<Box<dyn Node>>, cfg: SimConfig) -> Self {
        let pid_index = nodes.iter().enumerate().map(|(i, n)| (n.pid(), i)).collect();
        let n = nodes.len();
        World {
            pid_index,
            nodes,
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
            rng: Rng::new(cfg.seed),
            delay: cfg.delay,
            cpu: cfg.cpu,
            busy_until: vec![0; n],
            crashed: vec![false; n],
            backlog: vec![Default::default(); n],
            drain_scheduled: vec![false; n],
            fifo_last: Default::default(),
            arrivals: Default::default(),
            trace,
            started: false,
            outbox: Outbox::new(),
            links: (0..n).map(|_| LinkCoalescer::new(cfg.flush)).collect(),
            flush_scheduled: vec![None; n],
            frames: Vec::new(),
            coalesce: cfg.coalesce,
            stores: FxHashMap::default(),
            rebuilders: FxHashMap::default(),
            flight: None,
            faults: Faults::default(),
            log_events: std::env::var("WBAM_SIM_LOG").is_ok(),
        }
    }

    // ---------- nemesis knobs (see [`nemesis`]) ----------
    //
    // Each knob records a window or one-shot fault consulted by the
    // scheduling hooks; none is reachable from production code paths —
    // the repo gate (`cargo xtask lint`, rule `nemesis-reach`) keeps it
    // that way.

    /// Partition pid sets `a` and `b` from `start` until `heal`: frames
    /// between the sets are held and arrive no earlier than `heal`
    /// (delayed, never dropped — reliable asynchronous links). With
    /// `oneway`, only a→b traffic is blocked (asymmetric link failure).
    pub fn net_partition(&mut self, a: &[Pid], b: &[Pid], start: u64, heal: u64, oneway: bool) {
        for &x in a {
            for &y in b {
                self.faults.blocked.push((x, y, start, heal));
                if !oneway {
                    self.faults.blocked.push((y, x, start, heal));
                }
            }
        }
    }

    /// Bounded delay jitter on `(from, to)`: frames shipped in
    /// `[start, end)` pick up a seeded extra delay in `[0, extra_max]`.
    pub fn link_jitter(&mut self, from: Pid, to: Pid, start: u64, end: u64, extra_max: u64) {
        self.faults.jitter.push((from, to, start, end, extra_max));
    }

    /// Duplicate frames shipped on `(from, to)` during `[start, end)`
    /// (the second copy respects the link's FIFO order).
    pub fn link_dup(&mut self, from: Pid, to: Pid, start: u64, end: u64) {
        self.faults.dup.push((from, to, start, end));
    }

    /// Let frames shipped on `(from, to)` during `[start, end)` overtake
    /// earlier traffic (FIFO clamp bypassed). This steps *outside* the
    /// protocols' reliable-FIFO channel assumption (§II) — an explicit
    /// knob for targeted tests, not part of the default swarm
    /// distribution (see [`nemesis::NemesisSchedule::generate`]).
    pub fn link_reorder(&mut self, from: Pid, to: Pid, start: u64, end: u64) {
        self.faults.reorder.push((from, to, start, end));
    }

    /// Skew `pid`'s timer wheel by `ppm` parts-per-million from `from_t`
    /// on: every timer it arms stretches (+) or shrinks (−) by that
    /// factor — bounded clock drift between per-node timer wheels.
    pub fn clock_skew(&mut self, pid: Pid, from_t: u64, ppm: i64) {
        self.faults.skew.push((pid, from_t, ppm));
    }

    /// Gray failure: `pid` stays alive but every event it handles during
    /// `[start, end)` costs `extra_ns` more — slow-but-alive, the
    /// failure detectors' hardest case.
    pub fn gray_slow(&mut self, pid: Pid, start: u64, end: u64, extra_ns: u64) {
        self.faults.slow.push((pid, start, end, extra_ns));
    }

    /// Slow disk: each record `pid` journals during `[start, end)` costs
    /// `extra_ns` extra before the event's sends can ship.
    pub fn disk_slow(&mut self, pid: Pid, start: u64, end: u64, extra_ns: u64) {
        self.faults.disk_slow.push((pid, start, end, extra_ns));
    }

    /// Arm a one-shot disk fault: `pid`'s first journal append at or
    /// after `at` is torn ([`crate::storage::WalFault::Torn`], cut at
    /// `cut_bp`/10000 of the frame) or fails outright
    /// ([`crate::storage::WalFault::Failed`], poisoning the WAL). Either
    /// way the process crashes inside that same atomic event, before any
    /// of its sends ship — no post-failure acknowledgement ever leaves.
    pub fn disk_fault_at(&mut self, pid: Pid, at: u64, fault: crate::storage::WalFault, cut_bp: u32) {
        self.faults.disk_fault.push((pid, at, fault, cut_bp));
    }

    /// Attach a bounded flight recorder keeping the last `cap` protocol
    /// events (wire arrivals with their ballot-carrying tags, journal
    /// appends, deliveries). The harness dumps its tail when a run fails
    /// an invariant check, turning the assert into a replayable event
    /// tail. Off by default: the hot loop pays nothing.
    pub fn enable_flight(&mut self, cap: usize) -> std::sync::Arc<crate::obs::FlightRecorder> {
        let fl = std::sync::Arc::new(crate::obs::FlightRecorder::new(cap));
        self.flight = Some(fl.clone());
        fl
    }

    /// The attached flight recorder, if [`World::enable_flight`] ran.
    pub fn flight(&self) -> Option<&std::sync::Arc<crate::obs::FlightRecorder>> {
        self.flight.as_ref()
    }

    /// Run the paper's correctness invariants over the recorded trace
    /// (shard by shard for sharded worlds). When a flight recorder is
    /// attached, a violation dumps its tail first — see
    /// [`crate::invariants::assert_correct_with_flight`].
    pub fn check_invariants(&self) {
        crate::invariants::assert_correct_with_flight(&self.trace, self.flight.as_deref());
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn delta(&self) -> u64 {
        self.delay.delta()
    }

    fn push(&mut self, time: u64, to: Pid, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { time, seq: self.seq, to, kind }));
    }

    /// Schedule a crash of `pid` at virtual time `time`.
    pub fn crash_at(&mut self, pid: Pid, time: u64) {
        self.push(time, pid, EventKind::Crash);
    }

    /// Give `pid` simulated durable storage: its journal records
    /// ([`crate::protocols::Outbox::record`]) persist into a
    /// [`crate::storage::MemWal`] — the identical record framing the
    /// file-backed WAL uses — and a later [`World::restart_at`] rebuilds
    /// the node from the decoded fold via `rebuild`.
    pub fn enable_storage(&mut self, pid: Pid, rebuild: RestartFn) {
        self.stores.insert(pid, crate::storage::MemWal::new());
        self.rebuilders.insert(pid, rebuild);
    }

    /// Schedule a restart of `pid` at virtual time `time`. Only takes
    /// effect if the pid has crashed by then and
    /// [`World::enable_storage`] registered a rebuilder; the node is
    /// reconstructed from its storage fold and `on_start` runs (a
    /// restored `WbNode` rejoins through the recovery protocol).
    pub fn restart_at(&mut self, pid: Pid, time: u64) {
        self.push(time, pid, EventKind::Restart);
    }

    /// Inspect a pid's simulated storage (tests).
    pub fn store(&self, pid: Pid) -> Option<&crate::storage::MemWal> {
        self.stores.get(&pid)
    }

    fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        for i in 0..self.nodes.len() {
            let pid = self.nodes[i].pid();
            self.nodes[i].on_start(0, &mut self.outbox);
            // start-of-world kicks are free of CPU charges (as before)
            self.finish_event(i, pid, 0, 0, false);
        }
    }

    /// Settle the shared outbox after node `idx`'s handler ran at `time`
    /// with input-side cost `cost_in`: feed the sends through the node's
    /// [`LinkCoalescer`] (per-destination frames, policy-held links stay
    /// pending), charge `send_ns` per emitted *frame* (the
    /// syscall/framing amortisation batching buys), then emit
    /// deliveries/timers/arrivals stamped with the completion time.
    /// Outbox and frame buffers are retained for reuse.
    fn finish_event(&mut self, idx: usize, pid: Pid, time: u64, cost_in: u64, charge_sends: bool) {
        // a slow disk stretches the commit point by extra_ns per record
        let disk_cost = if self.outbox.records.is_empty() {
            0
        } else {
            self.faults.disk_extra(pid, time) * self.outbox.records.len() as u64
        };
        let t0 = time + cost_in + disk_cost;
        // persist journal records before the event's sends ship: events
        // are atomic in the sim, so this is the virtual-time analogue of
        // the runtimes' commit-before-flush group-commit point
        if !self.outbox.records.is_empty() {
            if let Some(store) = self.stores.get_mut(&pid) {
                if let Some((fault, cut_bp)) = self.faults.take_disk_fault(pid, time) {
                    store.arm_fault(fault, cut_bp); // nemesis-ok: sim injection site
                }
                for rec in &self.outbox.records {
                    store.append(rec);
                }
                if store.take_fired().is_some() {
                    // the journal append tore or failed: the process dies
                    // here, inside this same atomic event. None of the
                    // event's sends, deliveries or timers leave — the
                    // journal-before-ack contract means no post-failure
                    // acknowledgement is ever observable
                    self.outbox.sends.clear();
                    self.outbox.delivers.clear();
                    self.outbox.timers.clear();
                    self.outbox.records.clear();
                    self.crash_now(idx, pid, t0);
                    return;
                }
            }
            if let Some(fl) = &self.flight {
                for _ in &self.outbox.records {
                    fl.push(crate::obs::FlightEvent::journal(t0, pid));
                }
            }
            self.outbox.records.clear();
        }
        let mut frames = std::mem::take(&mut self.frames);
        if self.coalesce {
            // "quiet" mirrors the real event loops: no more input is
            // immediately pending for this process
            let quiet = self.backlog[idx].is_empty();
            let links = &mut self.links[idx];
            let mut sends = std::mem::take(&mut self.outbox.sends);
            for (to, wire) in sends.drain(..) {
                links.push(t0, to, wire, &mut |to, frame| frames.push((to, frame)));
            }
            self.outbox.sends = sends; // drained, capacity retained
            links.flush_cycle(t0, quiet, &mut |to, frame| frames.push((to, frame)));
        } else {
            // message-at-a-time server: every send is its own frame
            for (to, wire) in self.outbox.sends.drain(..) {
                frames.push((to, wire));
            }
        }

        let send_cost = if charge_sends { self.cpu.send_ns * frames.len() as u64 } else { 0 };
        let done_at = t0 + send_cost;
        self.busy_until[idx] = done_at;

        for i in 0..self.outbox.delivers.len() {
            let d = self.outbox.delivers[i];
            self.trace.on_deliver(done_at, pid, d.m, d.gts);
            if let Some(fl) = &self.flight {
                fl.push(crate::obs::FlightEvent::deliver(done_at, pid, d.m, d.gts, d.path));
            }
        }
        self.outbox.delivers.clear();
        for i in 0..self.outbox.timers.len() {
            let (kind, after) = self.outbox.timers[i];
            // bounded clock skew: this node's timer wheel runs fast/slow
            let after = self.faults.skewed(pid, after, done_at);
            self.push(done_at + after, pid, EventKind::Timer(kind));
        }
        self.outbox.timers.clear();

        self.ship(pid, done_at, &mut frames);
        self.frames = frames;
        self.schedule_flush_due(idx, pid, done_at);
    }

    /// Account and schedule the emitted frames' arrivals from `done_at`.
    fn ship(&mut self, pid: Pid, done_at: u64, frames: &mut Vec<(Pid, Wire)>) {
        for (to, frame) in frames.drain(..) {
            // per-wire accounting: a batch frame still carries n messages
            match &frame {
                Wire::Batch(inner) => {
                    for w in inner {
                        self.account_wire(done_at, w);
                        if let Some(fl) = &self.flight {
                            fl.push(crate::obs::FlightEvent::wire_out(done_at, pid, to, w));
                        }
                    }
                }
                w => {
                    self.account_wire(done_at, w);
                    if let Some(fl) = &self.flight {
                        fl.push(crate::obs::FlightEvent::wire_out(done_at, pid, to, w));
                    }
                }
            }
            self.trace.send_bytes += frame.size() as u64;
            let arr = if to == pid {
                done_at // self-sends are local, faults never apply
            } else {
                let mut arr = done_at + self.delay.sample(&mut self.rng, pid, to);
                // partition: hold the frame until the link heals (delayed,
                // never dropped — the links stay reliable, just slow)
                if let Some(heal) = self.faults.block_until(pid, to, done_at) {
                    arr = arr.max(heal);
                }
                // bounded jitter: seeded extra delay (rng consulted only
                // inside an active window, so zero-fault runs stay
                // event-for-event identical to the plain sim)
                if let Some(extra) = self.faults.jitter_max(pid, to, done_at) {
                    arr += self.rng.below(extra + 1);
                }
                arr
            };
            let key = (pid, to);
            if to != pid && self.faults.reorder_active(pid, to, done_at) {
                // reorder window: bypass the FIFO clamp so this frame may
                // overtake in-flight traffic; the watermark is left
                // untouched so later frames are not dragged forward
                self.push(arr, to, EventKind::Arrival { from: pid, wire: frame });
                continue;
            }
            let dup =
                if to != pid && self.faults.dup_active(pid, to, done_at) { Some(frame.clone()) } else { None };
            // reliable FIFO channel: never reorder within a link
            let last = self.fifo_last.get(&key).copied().unwrap_or(0);
            let arr = arr.max(last);
            self.fifo_last.insert(key, arr);
            self.push(arr, to, EventKind::Arrival { from: pid, wire: frame });
            if let Some(w) = dup {
                // duplicate copy trails the original within FIFO order (a
                // link-level retransmission, not a protocol send — it is
                // deliberately absent from the send accounting)
                let arr2 = arr + self.rng.below(self.delay.delta().max(1));
                self.fifo_last.insert(key, arr2);
                self.push(arr2, to, EventKind::Arrival { from: pid, wire: w });
            }
        }
    }

    /// Emit node `idx`'s links whose policy deadline has passed, charging
    /// `send_ns` per frame from the later of `now` and the node's busy
    /// time (the flush point the real runtimes reach via their bounded
    /// sleep on the coalescer deadline).
    fn flush_due(&mut self, idx: usize, pid: Pid, now: u64) {
        let mut frames = std::mem::take(&mut self.frames);
        self.links[idx].flush_cycle(now, false, &mut |to, frame| frames.push((to, frame)));
        if !frames.is_empty() {
            let done_at = now.max(self.busy_until[idx]) + self.cpu.send_ns * frames.len() as u64;
            self.busy_until[idx] = done_at;
            self.ship(pid, done_at, &mut frames);
        }
        self.frames = frames;
        self.schedule_flush_due(idx, pid, now);
    }

    /// Make sure a [`EventKind::FlushDue`] wake-up exists no later than
    /// the node's earliest pending-link deadline.
    fn schedule_flush_due(&mut self, idx: usize, pid: Pid, now: u64) {
        let Some(d) = self.links[idx].next_deadline() else { return };
        let d = d.max(now);
        match self.flush_scheduled[idx] {
            Some(t) if t <= d => {} // an earlier wake-up already covers it
            _ => {
                self.flush_scheduled[idx] = Some(d);
                self.push(d, pid, EventKind::FlushDue);
            }
        }
    }

    fn account_wire(&mut self, at: u64, w: &Wire) {
        self.trace.sends += 1;
        if let Wire::Multicast { meta } = w {
            self.trace.on_multicast(at, meta.id, meta.dest);
        }
    }

    /// Process one event. Returns `false` when the event queue is empty.
    pub fn step(&mut self) -> bool {
        self.start();
        let Some(Reverse(ev)) = self.heap.pop() else { return false };
        self.now = ev.time;
        let Some(&idx) = self.pid_index.get(&ev.to) else { return true };
        if let EventKind::Restart = ev.kind {
            // the only event a crashed process reacts to
            self.do_restart(idx, ev.to, ev.time);
            return true;
        }
        if self.crashed[idx] {
            return true; // drop events to crashed processes
        }
        match ev.kind {
            EventKind::Crash => self.crash_now(idx, ev.to, ev.time),
            EventKind::FlushDue => {
                if self.flush_scheduled[idx] == Some(ev.time) {
                    self.flush_scheduled[idx] = None;
                }
                self.flush_due(idx, ev.to, ev.time);
            }
            EventKind::Drain => {
                self.drain_scheduled[idx] = false;
                if let Some(kind) = self.backlog[idx].pop_front() {
                    // a FlushDue may have pushed busy_until past this
                    // wake-up's scheduled time; never start work (or
                    // rewind busy_until) before the flush charge ends
                    let t = ev.time.max(self.busy_until[idx]);
                    self.process(idx, ev.to, t, kind);
                }
                if !self.backlog[idx].is_empty() {
                    self.drain_scheduled[idx] = true;
                    self.push(self.busy_until[idx], ev.to, EventKind::Drain);
                }
            }
            EventKind::Restart => unreachable!("restarts are handled before the crash filter"),
            EventKind::Arrival { .. } | EventKind::Timer(_) => {
                // single-threaded server: queue behind in-progress work
                // (FIFO backlog + one Drain wake-up keeps this O(1) per
                // event even at saturation)
                if self.drain_scheduled[idx] || self.busy_until[idx] > ev.time {
                    self.backlog[idx].push_back(ev.kind);
                    if !self.drain_scheduled[idx] {
                        self.drain_scheduled[idx] = true;
                        self.push(self.busy_until[idx], ev.to, EventKind::Drain);
                    }
                    return true;
                }
                self.process(idx, ev.to, ev.time, ev.kind);
            }
        }
        true
    }

    /// Kill process `idx` immediately: used by the [`EventKind::Crash`]
    /// event and by disk faults that fire mid-event (the process dies
    /// inside the failing event, before any of its sends ship).
    fn crash_now(&mut self, idx: usize, pid: Pid, time: u64) {
        self.crashed[idx] = true;
        self.backlog[idx].clear();
        // the pending Drain wake-up (if any) will be dropped by
        // the crashed-process filter: clear the flag too, or a
        // later Restart could never schedule another drain and
        // the reborn node would backlog events forever
        self.drain_scheduled[idx] = false;
        // unflushed coalescing wires die with the process
        self.links[idx].clear();
        self.flush_scheduled[idx] = None;
        // a crashed pid's links can never be consulted again:
        // prune its FIFO watermarks and arrival count, or long
        // crash-injection runs grow these maps without bound
        self.fifo_last.retain(|&(a, b), _| a != pid && b != pid);
        self.arrivals.remove(&pid);
        self.trace.on_crash(time, pid);
        self.nodes[idx].on_crash(time);
    }

    /// Rebuild a crashed process from its simulated storage: decode the
    /// [`crate::storage::MemWal`] fold (the exact on-disk codec path),
    /// hand it to the registered rebuilder, and start the reborn node —
    /// a restored `WbNode` immediately rejoins via the recovery
    /// protocol. No-op if the pid never crashed or has no storage.
    fn do_restart(&mut self, idx: usize, pid: Pid, time: u64) {
        if !self.crashed[idx] {
            return;
        }
        let Some(store) = self.stores.get(&pid) else { return };
        if store.is_poisoned() {
            // file-backed Storage parity: a poisoned WAL (fsync failure)
            // refuses recovery — the process stays dead
            return;
        }
        let snap = store.recover();
        let Some(rebuild) = self.rebuilders.get_mut(&pid) else { return };
        let node = rebuild(snap);
        assert_eq!(node.pid(), pid, "rebuilder returned a different pid");
        self.crashed[idx] = false;
        self.busy_until[idx] = time;
        self.nodes[idx] = node;
        self.trace.on_restart(time, pid);
        self.nodes[idx].on_start(time, &mut self.outbox);
        self.finish_event(idx, pid, time, 0, false);
    }

    /// Execute one node event at `time`, charging the CPU cost model.
    /// Batch frames are unpacked here: one `recv_ns` + per-byte charge for
    /// the whole frame, per-message costs (`paxos_extra_ns`) still per
    /// inner message — the amortisation that batching buys.
    fn process(&mut self, idx: usize, to: Pid, time: u64, kind: EventKind) {
        debug_assert!(self.outbox.is_empty());
        let cost_in = match kind {
            EventKind::Arrival { from, wire } => {
                let bytes = wire.size() as u64;
                if self.log_events {
                    // opt-in trace (WBAM_SIM_LOG), deliberately on stderr
                    #[allow(clippy::print_stderr)]
                    {
                        eprintln!("[{:>12}] {:?} -> {:?}: {:?}", time, from, to, wire);
                    }
                }
                let mut extra = 0;
                match wire {
                    Wire::Batch(inner) => {
                        *self.arrivals.entry(to).or_insert(0) += inner.len() as u64;
                        for w in inner {
                            if matches!(w, Wire::Paxos { .. }) {
                                extra += self.cpu.paxos_extra_ns;
                            }
                            if let Some(fl) = &self.flight {
                                fl.push(crate::obs::FlightEvent::wire_in(time, to, from, &w));
                            }
                            self.nodes[idx].on_wire(from, w, time, &mut self.outbox);
                        }
                    }
                    w => {
                        *self.arrivals.entry(to).or_insert(0) += 1;
                        if matches!(w, Wire::Paxos { .. }) {
                            extra = self.cpu.paxos_extra_ns;
                        }
                        if let Some(fl) = &self.flight {
                            fl.push(crate::obs::FlightEvent::wire_in(time, to, from, &w));
                        }
                        self.nodes[idx].on_wire(from, w, time, &mut self.outbox);
                    }
                }
                self.cpu.recv_ns + self.cpu.per_byte_ns * bytes + extra
            }
            EventKind::Timer(k) => {
                self.nodes[idx].on_timer(k, time, &mut self.outbox);
                self.cpu.recv_ns
            }
            _ => unreachable!(),
        };
        // gray failure: a slow-but-alive node pays extra for every event
        let slow = self.faults.slow_extra(to, time);
        self.finish_event(idx, to, time, cost_in + slow, true);
    }

    /// Run until the virtual clock reaches `t` (or the queue drains).
    pub fn run_until(&mut self, t: u64) {
        self.start();
        while let Some(Reverse(ev)) = self.heap.peek() {
            if ev.time > t {
                break;
            }
            self.step();
        }
        self.now = self.now.max(t);
    }

    /// Run until the event queue is empty (quiescence). Panics after
    /// `max_events` to catch livelock in tests.
    pub fn run_to_quiescence(&mut self, max_events: u64) {
        self.start();
        let mut n = 0u64;
        while self.step() {
            n += 1;
            assert!(n < max_events, "no quiescence after {max_events} events");
        }
    }

    /// Access a node (for test inspection). Panics on unknown pid.
    pub fn node(&self, pid: Pid) -> &dyn Node {
        &*self.nodes[self.pid_index[&pid]]
    }
    pub fn node_mut(&mut self, pid: Pid) -> &mut (dyn Node + 'static) {
        &mut *self.nodes[self.pid_index[&pid]]
    }
    /// Typed access to a node (dyn upcast to `Any`, then downcast).
    pub fn node_as<T: 'static>(&self, pid: Pid) -> &T {
        let n: &dyn Node = &*self.nodes[self.pid_index[&pid]];
        (n as &dyn std::any::Any).downcast_ref::<T>().expect("node type mismatch")
    }
    pub fn is_crashed(&self, pid: Pid) -> bool {
        self.crashed[self.pid_index[&pid]]
    }

    /// Replace `pid`'s node with `wrap(old)` — used by the swarm to
    /// install test-only protocol shims (e.g. a double-delivering
    /// wrapper that seeds a known safety violation) without the
    /// protocols knowing. Must run before the world starts.
    pub fn wrap_node(&mut self, pid: Pid, wrap: impl FnOnce(Box<dyn Node>) -> Box<dyn Node>) {
        assert!(!self.started, "wrap_node must run before the world starts");
        let idx = self.pid_index[&pid];
        let old = std::mem::replace(&mut self.nodes[idx], Box::new(NullNode(pid)));
        let new = wrap(old);
        assert_eq!(new.pid(), pid, "wrapper changed the node's pid");
        self.nodes[idx] = new;
    }
}

/// Placeholder for [`World::wrap_node`]'s `mem::replace`; never runs.
struct NullNode(Pid);
impl Node for NullNode {
    fn pid(&self) -> Pid {
        self.0
    }
    fn on_start(&mut self, _now: u64, _out: &mut Outbox) {}
    fn on_wire(&mut self, _from: Pid, _wire: Wire, _now: u64, _out: &mut Outbox) {}
    fn on_timer(&mut self, _timer: TimerKind, _now: u64, _out: &mut Outbox) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Ballot, Gid, GidSet, MsgId, MsgMeta, Ts};

    /// A node that echoes every MULTICAST back as DELIVERED after
    /// re-sending it to a peer once.
    struct Echo {
        pid: Pid,
        peer: Pid,
        got: Vec<(u64, MsgId)>,
    }
    impl Node for Echo {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _now: u64, _out: &mut Outbox) {}
        fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
            match wire {
                Wire::Multicast { meta } => {
                    self.got.push((now, meta.id));
                    out.send(self.peer, Wire::Delivered { m: meta.id, g: Gid(0), gts: Ts::BOT });
                }
                Wire::Delivered { m, .. } => {
                    self.got.push((now, m));
                    let _ = from;
                }
                _ => {}
            }
        }
        fn on_timer(&mut self, _t: TimerKind, _now: u64, _out: &mut Outbox) {}
    }

    struct Kick {
        pid: Pid,
        to: Pid,
        n: u32,
    }
    impl Node for Kick {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _now: u64, out: &mut Outbox) {
            for i in 0..self.n {
                out.send(
                    self.to,
                    Wire::Multicast { meta: MsgMeta::new(MsgId::new(self.pid.0, i), GidSet::single(Gid(0)), vec![]) },
                );
            }
        }
        fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, _out: &mut Outbox) {}
        fn on_timer(&mut self, _t: TimerKind, _n: u64, _out: &mut Outbox) {}
    }

    #[test]
    fn const_delay_and_fifo() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 5 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let mut w = World::new(topo, nodes, SimConfig::theory(1000));
        w.run_to_quiescence(1000);
        // All 5 arrive at t=1000 in FIFO order.
        let echo = w.node_as::<Echo>(Pid(0));
        assert_eq!(echo.got.len(), 5);
        assert!(echo.got.iter().all(|&(t, _)| t == 1000));
        let seqs: Vec<u32> = echo.got.iter().map(|&(_, m)| m.seq()).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cpu_cost_serialises_processing() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 3 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        // coalescing off: this test pins down the unbatched
        // message-at-a-time serialisation behaviour
        let cfg = SimConfig {
            delay: Box::new(ConstDelay(1000)),
            cpu: CpuCost { recv_ns: 100, per_byte_ns: 0, send_ns: 0, paxos_extra_ns: 0 },
            seed: 0,
            record_full: true,
            coalesce: false,
            flush: FlushPolicy::default(),
        };
        let mut w = World::new(topo, nodes, cfg);
        w.run_to_quiescence(1000);
        let echo = w.node_as::<Echo>(Pid(0));
        // arrivals at 1000; processing serialises at 1000, 1100, 1200
        let times: Vec<u64> = echo.got.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![1000, 1100, 1200]);
    }

    #[test]
    fn coalesced_batch_is_one_arrival_with_one_recv_charge() {
        // same workload as cpu_cost_serialises_processing, but with
        // coalescing ON: the 3 same-destination sends of Kick's start
        // event arrive as one Batch frame, processed as one event — all
        // inner messages handled at t=1000 with a single recv_ns charge.
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 3 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let cfg = SimConfig {
            delay: Box::new(ConstDelay(1000)),
            cpu: CpuCost { recv_ns: 100, per_byte_ns: 0, send_ns: 0, paxos_extra_ns: 0 },
            seed: 0,
            record_full: true,
            coalesce: true,
            flush: FlushPolicy::default(),
        };
        let mut w = World::new(topo, nodes, cfg);
        w.run_to_quiescence(1000);
        let echo = w.node_as::<Echo>(Pid(0));
        let times: Vec<u64> = echo.got.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![1000, 1000, 1000]);
        // FIFO within the batch preserved
        let seqs: Vec<u32> = echo.got.iter().map(|&(_, m)| m.seq()).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
        // protocol-message accounting is per inner message, not per frame
        assert_eq!(w.arrivals[&Pid(0)], 3);
        assert!(w.trace.sends >= 3);
    }

    /// A kick at t=0 and another at t=200µs toward the same destination,
    /// under a 500µs delay window with quiet-flush off: both wires ride
    /// one Batch frame emitted at the deadline, FIFO preserved.
    #[test]
    fn adaptive_flush_coalesces_across_events_until_the_deadline() {
        struct Stagger {
            pid: Pid,
            to: Pid,
        }
        impl Node for Stagger {
            fn pid(&self) -> Pid {
                self.pid
            }
            fn on_start(&mut self, _n: u64, out: &mut Outbox) {
                out.send(
                    self.to,
                    Wire::Multicast { meta: MsgMeta::new(MsgId::new(1, 0), GidSet::single(Gid(0)), vec![]) },
                );
                out.timer(TimerKind::ClientNext, 200_000);
            }
            fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, _o: &mut Outbox) {}
            fn on_timer(&mut self, _t: TimerKind, _n: u64, out: &mut Outbox) {
                out.send(
                    self.to,
                    Wire::Multicast { meta: MsgMeta::new(MsgId::new(1, 1), GidSet::single(Gid(0)), vec![]) },
                );
            }
        }
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Stagger { pid: Pid(1), to: Pid(0) }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let cfg = SimConfig {
            delay: Box::new(ConstDelay(1000)),
            cpu: CpuCost::zero(),
            seed: 0,
            record_full: true,
            coalesce: true,
            flush: FlushPolicy { max_delay_us: 500, max_bytes: usize::MAX, flush_on_quiet: false },
        };
        let mut w = World::new(topo, nodes, cfg);
        w.run_to_quiescence(1000);
        let echo = w.node_as::<Echo>(Pid(0));
        // one frame at the 500µs deadline + 1µs link delay, both inner
        // messages processed together, FIFO within the batch
        let times: Vec<u64> = echo.got.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![501_000, 501_000]);
        let seqs: Vec<u32> = echo.got.iter().map(|&(_, m)| m.seq()).collect();
        assert_eq!(seqs, vec![0, 1]);
        assert_eq!(w.arrivals[&Pid(0)], 2);
    }

    /// With quiet-flush on and zero CPU cost, every event's loop goes
    /// quiet immediately, so ANY delay window produces schedules
    /// identical to the immediate policy.
    #[test]
    fn quiet_flush_matches_immediate_below_saturation() {
        let run_one = |flush: FlushPolicy| {
            let topo = Topology::new(1, 0);
            let nodes: Vec<Box<dyn Node>> = vec![
                Box::new(Kick { pid: Pid(1), to: Pid(0), n: 5 }),
                Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
            ];
            let cfg = SimConfig {
                delay: Box::new(ConstDelay(1000)),
                cpu: CpuCost::zero(),
                seed: 0,
                record_full: true,
                coalesce: true,
                flush,
            };
            let mut w = World::new(topo, nodes, cfg);
            w.run_to_quiescence(1000);
            w.node_as::<Echo>(Pid(0)).got.clone()
        };
        let immediate = run_one(FlushPolicy::immediate());
        let adaptive = run_one(FlushPolicy::adaptive(10_000));
        assert_eq!(immediate, adaptive, "quiet-flush must reproduce the immediate schedule when idle");
    }

    #[test]
    fn crashed_node_receives_nothing() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 1 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let mut w = World::new(topo, nodes, SimConfig::theory(1000));
        w.crash_at(Pid(0), 500);
        w.run_to_quiescence(1000);
        let echo = w.node_as::<Echo>(Pid(0));
        assert!(echo.got.is_empty());
        assert!(w.is_crashed(Pid(0)));
        assert_eq!(w.trace.crashes, vec![(500, Pid(0))]);
    }

    #[test]
    fn crash_prunes_link_state() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 3 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let mut w = World::new(topo, nodes, SimConfig::theory(1000));
        w.run_to_quiescence(1000);
        assert!(w.arrivals.contains_key(&Pid(0)));
        assert!(w.fifo_last.keys().any(|&(a, b)| a == Pid(0) || b == Pid(0)));
        let t = w.now() + 10;
        w.crash_at(Pid(0), t);
        w.run_to_quiescence(1000);
        // the crashed pid's link watermarks and arrival count are gone
        assert!(w.fifo_last.keys().all(|&(a, b)| a != Pid(0) && b != Pid(0)));
        assert!(!w.arrivals.contains_key(&Pid(0)));
        // the surviving pid's state is untouched
        assert!(w.arrivals.contains_key(&Pid(1)));
    }

    #[test]
    fn timers_fire_in_order() {
        struct T {
            pid: Pid,
            fired: Vec<(u64, TimerKind)>,
        }
        impl Node for T {
            fn pid(&self) -> Pid {
                self.pid
            }
            fn on_start(&mut self, _n: u64, out: &mut Outbox) {
                out.timer(TimerKind::LssTick, 500);
                out.timer(TimerKind::ClientNext, 200);
                out.timer(TimerKind::BatchFlush, 900);
            }
            fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, _out: &mut Outbox) {}
            fn on_timer(&mut self, t: TimerKind, now: u64, _out: &mut Outbox) {
                self.fired.push((now, t));
            }
        }
        let topo = Topology::new(1, 0);
        let mut w = World::new(topo, vec![Box::new(T { pid: Pid(0), fired: vec![] })], SimConfig::theory(10));
        w.run_to_quiescence(100);
        let t = w.node_as::<T>(Pid(0));
        assert_eq!(
            t.fired,
            vec![(200, TimerKind::ClientNext), (500, TimerKind::LssTick), (900, TimerKind::BatchFlush)]
        );
    }

    #[test]
    fn ballot_unused_silence_compiler() {
        let _ = Ballot::BOT;
    }
}
