//! Deterministic discrete-event simulator.
//!
//! Runs a set of [`Node`] state machines over a virtual network with a
//! pluggable [`DelayModel`], a per-process CPU cost model (single-threaded
//! servers with a busy-until queue, which produces the saturation knees of
//! the paper's throughput figures), FIFO reliable channels, and crash
//! injection. Every run is a pure function of `(nodes, config, seed)`.

pub mod delay;
pub mod trace;

pub use delay::{ConstDelay, DelayModel, LanDelay, WanDelay, MS, US};
pub use trace::{DeliveryEv, Trace};

use crate::protocols::{Action, Node, TimerKind};
use crate::types::{Pid, Topology, Wire};
use crate::util::{FxHashMap, Rng};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Per-event CPU cost model. `zero()` gives the idealised §V setting where
/// local steps are instantaneous.
#[derive(Clone, Copy, Debug)]
pub struct CpuCost {
    /// fixed cost of handling any message/timer
    pub recv_ns: u64,
    /// additional cost per payload byte
    pub per_byte_ns: u64,
    /// cost per emitted message
    pub send_ns: u64,
    /// extra cost for handling a black-box consensus message (log slot
    /// bookkeeping, command (de)serialisation, RSM apply machinery) —
    /// the "overhead introduced by its parallel execution paths" the
    /// paper measures for FastCast/FT-Skeen in the CPU-bound LAN runs
    /// (§VI); calibrated in EXPERIMENTS.md §Calibration
    pub paxos_extra_ns: u64,
}

impl CpuCost {
    pub fn zero() -> Self {
        CpuCost { recv_ns: 0, per_byte_ns: 0, send_ns: 0, paxos_extra_ns: 0 }
    }
    /// Calibrated to a libevent-style C server on a 10-core Xeon:
    /// a few µs of syscall + protocol work per message, with consensus
    /// messages paying the black-box machinery on top (see
    /// EXPERIMENTS.md §Calibration).
    pub fn lan_server() -> Self {
        CpuCost { recv_ns: 1_500, per_byte_ns: 2, send_ns: 1_000, paxos_extra_ns: 12_000 }
    }
}

#[derive(Clone, Debug)]
enum EventKind {
    Arrival { from: Pid, wire: Wire },
    Timer(TimerKind),
    Crash,
    /// wake a busy process to work through its backlog queue
    Drain,
}

#[derive(Clone, Debug)]
struct Event {
    time: u64,
    seq: u64,
    to: Pid,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        (self.time, self.seq) == (other.time, other.seq)
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Simulator configuration.
pub struct SimConfig {
    pub delay: Box<dyn DelayModel>,
    pub cpu: CpuCost,
    pub seed: u64,
    /// record full delivery trace (correctness checks)
    pub record_full: bool,
}

impl SimConfig {
    pub fn theory(delta: u64) -> Self {
        SimConfig { delay: Box::new(ConstDelay(delta)), cpu: CpuCost::zero(), seed: 0, record_full: true }
    }
}

/// The virtual world: nodes + network + clock.
pub struct World {
    nodes: Vec<Box<dyn Node>>,
    pid_index: FxHashMap<Pid, usize>,
    heap: BinaryHeap<Reverse<Event>>,
    now: u64,
    seq: u64,
    rng: Rng,
    delay: Box<dyn DelayModel>,
    cpu: CpuCost,
    busy_until: Vec<u64>,
    crashed: Vec<bool>,
    /// per-process backlog of events that arrived while busy (FIFO);
    /// drained one per `Drain` wake-up — keeps saturation O(1) per event
    backlog: Vec<std::collections::VecDeque<EventKind>>,
    drain_scheduled: Vec<bool>,
    /// last scheduled arrival per (from, to): reliable FIFO channels
    fifo_last: FxHashMap<(Pid, Pid), u64>,
    /// per-node count of received protocol messages (genuineness checks)
    pub arrivals: FxHashMap<Pid, u64>,
    pub trace: Trace,
    started: bool,
    /// debug: print every handled event (env `WBAM_SIM_LOG=1`)
    pub log_events: bool,
}

impl World {
    pub fn new(topo: Topology, nodes: Vec<Box<dyn Node>>, cfg: SimConfig) -> Self {
        let pid_index = nodes.iter().enumerate().map(|(i, n)| (n.pid(), i)).collect();
        let n = nodes.len();
        World {
            pid_index,
            nodes,
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
            rng: Rng::new(cfg.seed),
            delay: cfg.delay,
            cpu: cfg.cpu,
            busy_until: vec![0; n],
            crashed: vec![false; n],
            backlog: vec![Default::default(); n],
            drain_scheduled: vec![false; n],
            fifo_last: Default::default(),
            arrivals: Default::default(),
            trace: Trace::new(topo, cfg.record_full),
            started: false,
            log_events: std::env::var("WBAM_SIM_LOG").is_ok(),
        }
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn delta(&self) -> u64 {
        self.delay.delta()
    }

    fn push(&mut self, time: u64, to: Pid, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { time, seq: self.seq, to, kind }));
    }

    /// Schedule a crash of `pid` at virtual time `time`.
    pub fn crash_at(&mut self, pid: Pid, time: u64) {
        self.push(time, pid, EventKind::Crash);
    }

    fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        for i in 0..self.nodes.len() {
            let pid = self.nodes[i].pid();
            let acts = self.nodes[i].on_start(0);
            self.apply(pid, 0, acts);
        }
    }

    fn apply(&mut self, pid: Pid, done_at: u64, acts: Vec<Action>) {
        for a in acts {
            match a {
                Action::Send(to, wire) => {
                    self.trace.sends += 1;
                    self.trace.send_bytes += wire.size() as u64;
                    if let Wire::Multicast { meta } = &wire {
                        self.trace.on_multicast(done_at, meta.id, meta.dest);
                    }
                    let arr = if to == pid {
                        done_at // self-sends are local
                    } else {
                        done_at + self.delay.sample(&mut self.rng, pid, to)
                    };
                    // reliable FIFO channel: never reorder within a link
                    let key = (pid, to);
                    let last = self.fifo_last.get(&key).copied().unwrap_or(0);
                    let arr = arr.max(last);
                    self.fifo_last.insert(key, arr);
                    self.push(arr, to, EventKind::Arrival { from: pid, wire });
                }
                Action::Deliver(m, gts) => {
                    self.trace.on_deliver(done_at, pid, m, gts);
                }
                Action::Timer(kind, after) => {
                    self.push(done_at + after, pid, EventKind::Timer(kind));
                }
            }
        }
    }

    /// Process one event. Returns `false` when the event queue is empty.
    pub fn step(&mut self) -> bool {
        self.start();
        let Some(Reverse(ev)) = self.heap.pop() else { return false };
        self.now = ev.time;
        let Some(&idx) = self.pid_index.get(&ev.to) else { return true };
        if self.crashed[idx] {
            return true; // drop events to crashed processes
        }
        match ev.kind {
            EventKind::Crash => {
                self.crashed[idx] = true;
                self.backlog[idx].clear();
                self.trace.on_crash(ev.time, ev.to);
                self.nodes[idx].on_crash(ev.time);
            }
            EventKind::Drain => {
                self.drain_scheduled[idx] = false;
                if let Some(kind) = self.backlog[idx].pop_front() {
                    self.process(idx, ev.to, ev.time, kind);
                }
                if !self.backlog[idx].is_empty() {
                    self.drain_scheduled[idx] = true;
                    self.push(self.busy_until[idx], ev.to, EventKind::Drain);
                }
            }
            EventKind::Arrival { .. } | EventKind::Timer(_) => {
                // single-threaded server: queue behind in-progress work
                // (FIFO backlog + one Drain wake-up keeps this O(1) per
                // event even at saturation)
                if self.drain_scheduled[idx] || self.busy_until[idx] > ev.time {
                    self.backlog[idx].push_back(ev.kind);
                    if !self.drain_scheduled[idx] {
                        self.drain_scheduled[idx] = true;
                        self.push(self.busy_until[idx], ev.to, EventKind::Drain);
                    }
                    return true;
                }
                self.process(idx, ev.to, ev.time, ev.kind);
            }
        }
        true
    }

    /// Execute one node event at `time`, charging the CPU cost model.
    fn process(&mut self, idx: usize, to: Pid, time: u64, kind: EventKind) {
        let (cost_in, acts) = match kind {
            EventKind::Arrival { from, wire } => {
                *self.arrivals.entry(to).or_insert(0) += 1;
                let bytes = wire.size() as u64;
                let extra = if matches!(wire, Wire::Paxos { .. }) { self.cpu.paxos_extra_ns } else { 0 };
                if self.log_events {
                    eprintln!("[{:>12}] {:?} -> {:?}: {:?}", time, from, to, wire);
                }
                let acts = self.nodes[idx].on_wire(from, wire, time);
                (self.cpu.recv_ns + self.cpu.per_byte_ns * bytes + extra, acts)
            }
            EventKind::Timer(k) => {
                let acts = self.nodes[idx].on_timer(k, time);
                (self.cpu.recv_ns, acts)
            }
            _ => unreachable!(),
        };
        let sends = acts.iter().filter(|a| matches!(a, Action::Send(..))).count() as u64;
        let cost = cost_in + self.cpu.send_ns * sends;
        let done_at = time + cost;
        self.busy_until[idx] = done_at;
        self.apply(to, done_at, acts);
    }

    /// Run until the virtual clock reaches `t` (or the queue drains).
    pub fn run_until(&mut self, t: u64) {
        self.start();
        while let Some(Reverse(ev)) = self.heap.peek() {
            if ev.time > t {
                break;
            }
            self.step();
        }
        self.now = self.now.max(t);
    }

    /// Run until the event queue is empty (quiescence). Panics after
    /// `max_events` to catch livelock in tests.
    pub fn run_to_quiescence(&mut self, max_events: u64) {
        self.start();
        let mut n = 0u64;
        while self.step() {
            n += 1;
            assert!(n < max_events, "no quiescence after {max_events} events");
        }
    }

    /// Access a node (for test inspection). Panics on unknown pid.
    pub fn node(&self, pid: Pid) -> &dyn Node {
        &*self.nodes[self.pid_index[&pid]]
    }
    pub fn node_mut(&mut self, pid: Pid) -> &mut (dyn Node + 'static) {
        &mut *self.nodes[self.pid_index[&pid]]
    }
    /// Typed access to a node (dyn upcast to `Any`, then downcast).
    pub fn node_as<T: 'static>(&self, pid: Pid) -> &T {
        let n: &dyn Node = &*self.nodes[self.pid_index[&pid]];
        (n as &dyn std::any::Any).downcast_ref::<T>().expect("node type mismatch")
    }
    pub fn is_crashed(&self, pid: Pid) -> bool {
        self.crashed[self.pid_index[&pid]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Ballot, Gid, GidSet, MsgId, MsgMeta, Ts};

    /// A node that echoes every MULTICAST back as DELIVERED after
    /// re-sending it to a peer once.
    struct Echo {
        pid: Pid,
        peer: Pid,
        got: Vec<(u64, MsgId)>,
    }
    impl Node for Echo {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _now: u64) -> Vec<Action> {
            vec![]
        }
        fn on_wire(&mut self, from: Pid, wire: Wire, now: u64) -> Vec<Action> {
            match wire {
                Wire::Multicast { meta } => {
                    self.got.push((now, meta.id));
                    vec![Action::Send(self.peer, Wire::Delivered { m: meta.id, g: Gid(0), gts: Ts::BOT })]
                }
                Wire::Delivered { m, .. } => {
                    self.got.push((now, m));
                    let _ = from;
                    vec![]
                }
                _ => vec![],
            }
        }
        fn on_timer(&mut self, _t: TimerKind, _now: u64) -> Vec<Action> {
            vec![]
        }
    }

    struct Kick {
        pid: Pid,
        to: Pid,
        n: u32,
    }
    impl Node for Kick {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _now: u64) -> Vec<Action> {
            (0..self.n)
                .map(|i| {
                    Action::Send(
                        self.to,
                        Wire::Multicast { meta: MsgMeta::new(MsgId::new(self.pid.0, i), GidSet::single(Gid(0)), vec![]) },
                    )
                })
                .collect()
        }
        fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64) -> Vec<Action> {
            vec![]
        }
        fn on_timer(&mut self, _t: TimerKind, _n: u64) -> Vec<Action> {
            vec![]
        }
    }

    #[test]
    fn const_delay_and_fifo() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 5 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let mut w = World::new(topo, nodes, SimConfig::theory(1000));
        w.run_to_quiescence(1000);
        // All 5 arrive at t=1000 in FIFO order.
        let echo = w.node_as::<Echo>(Pid(0));
        assert_eq!(echo.got.len(), 5);
        assert!(echo.got.iter().all(|&(t, _)| t == 1000));
        let seqs: Vec<u32> = echo.got.iter().map(|&(_, m)| m.seq()).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cpu_cost_serialises_processing() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 3 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let cfg = SimConfig {
            delay: Box::new(ConstDelay(1000)),
            cpu: CpuCost { recv_ns: 100, per_byte_ns: 0, send_ns: 0, paxos_extra_ns: 0 },
            seed: 0,
            record_full: true,
        };
        let mut w = World::new(topo, nodes, cfg);
        w.run_to_quiescence(1000);
        let echo = w.node_as::<Echo>(Pid(0));
        // arrivals at 1000; processing serialises at 1000, 1100, 1200
        let times: Vec<u64> = echo.got.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![1000, 1100, 1200]);
    }

    #[test]
    fn crashed_node_receives_nothing() {
        let topo = Topology::new(1, 0);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Kick { pid: Pid(1), to: Pid(0), n: 1 }),
            Box::new(Echo { pid: Pid(0), peer: Pid(1), got: vec![] }),
        ];
        let mut w = World::new(topo, nodes, SimConfig::theory(1000));
        w.crash_at(Pid(0), 500);
        w.run_to_quiescence(1000);
        let echo = w.node_as::<Echo>(Pid(0));
        assert!(echo.got.is_empty());
        assert!(w.is_crashed(Pid(0)));
        assert_eq!(w.trace.crashes, vec![(500, Pid(0))]);
    }

    #[test]
    fn timers_fire_in_order() {
        struct T {
            pid: Pid,
            fired: Vec<(u64, TimerKind)>,
        }
        impl Node for T {
            fn pid(&self) -> Pid {
                self.pid
            }
            fn on_start(&mut self, _n: u64) -> Vec<Action> {
                vec![
                    Action::Timer(TimerKind::LssTick, 500),
                    Action::Timer(TimerKind::ClientNext, 200),
                    Action::Timer(TimerKind::BatchFlush, 900),
                ]
            }
            fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64) -> Vec<Action> {
                vec![]
            }
            fn on_timer(&mut self, t: TimerKind, now: u64) -> Vec<Action> {
                self.fired.push((now, t));
                vec![]
            }
        }
        let topo = Topology::new(1, 0);
        let mut w = World::new(topo, vec![Box::new(T { pid: Pid(0), fired: vec![] })], SimConfig::theory(10));
        w.run_to_quiescence(100);
        let t = w.node_as::<T>(Pid(0));
        assert_eq!(
            t.fired,
            vec![(200, TimerKind::ClientNext), (500, TimerKind::LssTick), (900, TimerKind::BatchFlush)]
        );
    }

    #[test]
    fn ballot_unused_silence_compiler() {
        let _ = Ballot::BOT;
    }
}
