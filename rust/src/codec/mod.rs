//! Hand-rolled binary wire codec (the offline build has no serde).
//!
//! Layout: little-endian fixed-width integers, length-prefixed sequences.
//! Every [`Wire`] value round-trips through [`encode`] / [`decode`]; the
//! TCP transport frames each message as `u32 length ++ bytes`.

use crate::types::wire::{MsgState, PaxosMsg, RsmCmd};
use crate::types::{Ballot, DeliveryPath, Gid, GidSet, MsgId, MsgMeta, Payload, Phase, Pid, Ts, Wire};
use std::sync::Arc;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum CodecError {
    #[error("unexpected end of buffer at offset {0}")]
    Eof(usize),
    #[error("bad discriminant {value} for {what}")]
    BadTag { what: &'static str, value: u8 },
    #[error("trailing {0} bytes after message")]
    Trailing(usize),
    #[error("batch frame nested inside a batch frame")]
    NestedBatch,
    #[error("empty batch frame")]
    EmptyBatch,
}

pub type Result<T> = std::result::Result<T, CodecError>;

/// Byte-buffer writer.
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::with_capacity(64) }
    }
    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    #[inline]
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
}

impl Default for Enc {
    fn default() -> Self {
        Self::new()
    }
}

/// Byte-buffer reader. When constructed over a shared frame buffer
/// ([`decode_shared`]) it additionally remembers the backing `Arc` so
/// payload fields can be handed out as zero-copy [`Payload`] windows
/// instead of `Vec` copies.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
    /// `(backing buffer, offset of buf[0] within it)` — present only on
    /// the shared-frame decode path.
    backing: Option<(&'a Arc<[u8]>, usize)>,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0, backing: None }
    }
    /// Reader over `frame[start..end]` that remembers `frame` as the
    /// shared backing buffer, enabling zero-copy [`Self::payload`].
    /// Errors (rather than panics) on an out-of-range window so transport
    /// code can feed it unvalidated frame headers.
    pub fn with_backing(frame: &'a Arc<[u8]>, start: usize, end: usize) -> Result<Self> {
        let buf = frame.get(start..end).ok_or(CodecError::Eof(frame.len()))?;
        Ok(Dec { buf, pos: 0, backing: Some((frame, start)) })
    }
    #[inline]
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(CodecError::Eof(self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    #[inline]
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    #[inline]
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    #[inline]
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    #[inline]
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    /// Length-prefixed payload. On the shared-frame path this is a
    /// refcounted window into the backing buffer (zero bytes copied);
    /// otherwise it copies like [`Self::bytes`].
    #[inline]
    pub fn payload(&mut self) -> Result<Payload> {
        let n = self.u32()? as usize;
        let start = self.pos;
        let b = self.take(n)?;
        Ok(match self.backing {
            Some((frame, base)) => Payload::view(frame.clone(), base + start, n),
            None => Payload::from(b),
        })
    }
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            Err(CodecError::Trailing(self.buf.len() - self.pos))
        } else {
            Ok(())
        }
    }
}

// ---------- field codecs (shared with the storage record codec) ----------

pub(crate) fn put_ts(e: &mut Enc, ts: Ts) {
    e.u64(ts.t);
    e.u32(ts.g.0);
}
pub(crate) fn get_ts(d: &mut Dec) -> Result<Ts> {
    Ok(Ts { t: d.u64()?, g: Gid(d.u32()?) })
}
pub(crate) fn put_ballot(e: &mut Enc, b: Ballot) {
    e.u32(b.n);
    e.u32(b.p.0);
}
pub(crate) fn get_ballot(d: &mut Dec) -> Result<Ballot> {
    Ok(Ballot { n: d.u32()?, p: Pid(d.u32()?) })
}
fn put_meta(e: &mut Enc, m: &MsgMeta) {
    e.u64(m.id.0);
    e.u64(m.dest.0);
    e.u64(m.submit_ns);
    e.bytes(&m.payload);
}
fn get_meta(d: &mut Dec) -> Result<MsgMeta> {
    Ok(MsgMeta { id: MsgId(d.u64()?), dest: GidSet(d.u64()?), submit_ns: d.u64()?, payload: d.payload()? })
}
fn put_phase(e: &mut Enc, p: Phase) {
    e.u8(match p {
        Phase::Start => 0,
        Phase::Proposed => 1,
        Phase::Accepted => 2,
        Phase::Committed => 3,
    });
}
fn get_phase(d: &mut Dec) -> Result<Phase> {
    Ok(match d.u8()? {
        0 => Phase::Start,
        1 => Phase::Proposed,
        2 => Phase::Accepted,
        3 => Phase::Committed,
        v => return Err(CodecError::BadTag { what: "Phase", value: v }),
    })
}
pub(crate) fn put_state(e: &mut Enc, s: &MsgState) {
    put_meta(e, &s.meta);
    put_phase(e, s.phase);
    put_ts(e, s.lts);
    put_ts(e, s.gts);
}
pub(crate) fn get_state(d: &mut Dec) -> Result<MsgState> {
    Ok(MsgState { meta: get_meta(d)?, phase: get_phase(d)?, lts: get_ts(d)?, gts: get_ts(d)? })
}
fn put_cmd(e: &mut Enc, c: &RsmCmd) {
    match c {
        RsmCmd::AssignLts { meta, lts } => {
            e.u8(0);
            put_meta(e, meta);
            put_ts(e, *lts);
        }
        RsmCmd::Commit { m, gts } => {
            e.u8(1);
            e.u64(m.0);
            put_ts(e, *gts);
        }
    }
}
fn get_cmd(d: &mut Dec) -> Result<RsmCmd> {
    Ok(match d.u8()? {
        0 => RsmCmd::AssignLts { meta: get_meta(d)?, lts: get_ts(d)? },
        1 => RsmCmd::Commit { m: MsgId(d.u64()?), gts: get_ts(d)? },
        v => return Err(CodecError::BadTag { what: "RsmCmd", value: v }),
    })
}
fn put_paxos(e: &mut Enc, m: &PaxosMsg) {
    match m {
        PaxosMsg::P1a { bal } => {
            e.u8(0);
            put_ballot(e, *bal);
        }
        PaxosMsg::P1b { bal, log } => {
            e.u8(1);
            put_ballot(e, *bal);
            e.u32(log.len() as u32);
            for (slot, b, cmd) in log {
                e.u64(*slot);
                put_ballot(e, *b);
                put_cmd(e, cmd);
            }
        }
        PaxosMsg::P2a { bal, slot, cmd } => {
            e.u8(2);
            put_ballot(e, *bal);
            e.u64(*slot);
            put_cmd(e, cmd);
        }
        PaxosMsg::P2b { bal, slot } => {
            e.u8(3);
            put_ballot(e, *bal);
            e.u64(*slot);
        }
        PaxosMsg::Learn { slot, cmd } => {
            e.u8(4);
            e.u64(*slot);
            put_cmd(e, cmd);
        }
    }
}
fn get_paxos(d: &mut Dec) -> Result<PaxosMsg> {
    Ok(match d.u8()? {
        0 => PaxosMsg::P1a { bal: get_ballot(d)? },
        1 => {
            let bal = get_ballot(d)?;
            let n = d.u32()? as usize;
            let mut log = Vec::with_capacity(n);
            for _ in 0..n {
                log.push((d.u64()?, get_ballot(d)?, get_cmd(d)?));
            }
            PaxosMsg::P1b { bal, log }
        }
        2 => PaxosMsg::P2a { bal: get_ballot(d)?, slot: d.u64()?, cmd: get_cmd(d)? },
        3 => PaxosMsg::P2b { bal: get_ballot(d)?, slot: d.u64()? },
        4 => PaxosMsg::Learn { slot: d.u64()?, cmd: get_cmd(d)? },
        v => return Err(CodecError::BadTag { what: "PaxosMsg", value: v }),
    })
}

// ---------- top-level ----------

/// Serialize a wire message to bytes (fresh buffer). The transports use
/// [`encode_into`] with a reused [`Enc`] to avoid the per-message
/// allocation.
pub fn encode(w: &Wire) -> Vec<u8> {
    let mut e = Enc::new();
    encode_into(&mut e, w);
    e.buf
}

/// Serialize a wire message, appending to `e`'s buffer (encode-once hot
/// path: the caller clears and reuses the buffer across messages).
pub fn encode_into(e: &mut Enc, w: &Wire) {
    match w {
        Wire::Multicast { meta } => {
            e.u8(0);
            put_meta(e, meta);
        }
        Wire::Delivered { m, g, gts } => {
            e.u8(1);
            e.u64(m.0);
            e.u32(g.0);
            put_ts(e, *gts);
        }
        Wire::Propose { m, g, lts } => {
            e.u8(2);
            e.u64(m.0);
            e.u32(g.0);
            put_ts(e, *lts);
        }
        Wire::Accept { meta, g, bal, lts } => {
            e.u8(3);
            put_meta(e, meta);
            e.u32(g.0);
            put_ballot(e, *bal);
            put_ts(e, *lts);
        }
        Wire::AcceptAck { m, g, bals } => {
            e.u8(4);
            e.u64(m.0);
            e.u32(g.0);
            e.u32(bals.len() as u32);
            for (g, b) in bals {
                e.u32(g.0);
                put_ballot(e, *b);
            }
        }
        Wire::Deliver { m, bal, lts, gts, path } => {
            e.u8(5);
            e.u64(m.0);
            put_ballot(e, *bal);
            put_ts(e, *lts);
            put_ts(e, *gts);
            e.u8(*path as u8);
        }
        Wire::NewLeader { bal } => {
            e.u8(6);
            put_ballot(e, *bal);
        }
        Wire::NewLeaderAck { bal, cbal, clock, state } => {
            e.u8(7);
            put_ballot(e, *bal);
            put_ballot(e, *cbal);
            e.u64(*clock);
            e.u32(state.len() as u32);
            for s in state {
                put_state(e, s);
            }
        }
        Wire::NewState { bal, clock, state } => {
            e.u8(8);
            put_ballot(e, *bal);
            e.u64(*clock);
            e.u32(state.len() as u32);
            for s in state {
                put_state(e, s);
            }
        }
        Wire::NewStateAck { bal } => {
            e.u8(9);
            put_ballot(e, *bal);
        }
        Wire::Confirm { m, g } => {
            e.u8(10);
            e.u64(m.0);
            e.u32(g.0);
        }
        Wire::Paxos { g, msg } => {
            e.u8(11);
            e.u32(g.0);
            put_paxos(e, msg);
        }
        Wire::Heartbeat { bal } => {
            e.u8(12);
            put_ballot(e, *bal);
        }
        Wire::GcReport { max_gts } => {
            e.u8(13);
            put_ts(e, *max_gts);
        }
        Wire::Batch(inner) => {
            debug_assert!(!inner.is_empty(), "encoding empty batch");
            e.u8(14);
            e.u32(inner.len() as u32);
            for w in inner {
                debug_assert!(!matches!(w, Wire::Batch(_)), "encoding nested batch");
                encode_into(e, w);
            }
        }
    }
}

/// Deserialize a wire message; checks the buffer is fully consumed.
/// Batch frames are accepted at the top level only — nested and empty
/// batches are rejected.
pub fn decode(buf: &[u8]) -> Result<Wire> {
    let mut d = Dec::new(buf);
    let w = get_wire(&mut d, true)?;
    d.finish()?;
    Ok(w)
}

/// Deserialize a wire message from `frame[start..end]`, where `frame` is
/// a shared receive buffer. Identical accepted language and results to
/// [`decode`] (a property test pins this), but message payloads come out
/// as refcounted [`Payload`] windows into `frame` instead of owned
/// copies — the zero-copy receive path used by every transport.
///
/// The trade-off is lifetime, not correctness: a payload window keeps the
/// whole frame buffer alive until the message is dropped. Frames are
/// bounded (64 MiB receive cap) and payloads are consumed promptly by the
/// protocol layer, so this is an easy win over two allocations plus two
/// copies per message.
pub fn decode_shared(frame: &Arc<[u8]>, start: usize, end: usize) -> Result<Wire> {
    let mut d = Dec::with_backing(frame, start, end)?;
    let w = get_wire(&mut d, true)?;
    d.finish()?;
    Ok(w)
}

fn get_wire(d: &mut Dec, allow_batch: bool) -> Result<Wire> {
    Ok(match d.u8()? {
        0 => Wire::Multicast { meta: get_meta(d)? },
        1 => Wire::Delivered { m: MsgId(d.u64()?), g: Gid(d.u32()?), gts: get_ts(d)? },
        2 => Wire::Propose { m: MsgId(d.u64()?), g: Gid(d.u32()?), lts: get_ts(d)? },
        3 => Wire::Accept {
            meta: get_meta(d)?,
            g: Gid(d.u32()?),
            bal: get_ballot(d)?,
            lts: get_ts(d)?,
        },
        4 => {
            let m = MsgId(d.u64()?);
            let g = Gid(d.u32()?);
            let n = d.u32()? as usize;
            let mut bals = Vec::with_capacity(n);
            for _ in 0..n {
                bals.push((Gid(d.u32()?), get_ballot(d)?));
            }
            Wire::AcceptAck { m, g, bals }
        }
        5 => Wire::Deliver {
            m: MsgId(d.u64()?),
            bal: get_ballot(d)?,
            lts: get_ts(d)?,
            gts: get_ts(d)?,
            path: DeliveryPath::from_u8(d.u8()?),
        },
        6 => Wire::NewLeader { bal: get_ballot(d)? },
        7 => {
            let bal = get_ballot(d)?;
            let cbal = get_ballot(d)?;
            let clock = d.u64()?;
            let n = d.u32()? as usize;
            let mut state = Vec::with_capacity(n);
            for _ in 0..n {
                state.push(get_state(d)?);
            }
            Wire::NewLeaderAck { bal, cbal, clock, state }
        }
        8 => {
            let bal = get_ballot(d)?;
            let clock = d.u64()?;
            let n = d.u32()? as usize;
            let mut state = Vec::with_capacity(n);
            for _ in 0..n {
                state.push(get_state(d)?);
            }
            Wire::NewState { bal, clock, state }
        }
        9 => Wire::NewStateAck { bal: get_ballot(d)? },
        10 => Wire::Confirm { m: MsgId(d.u64()?), g: Gid(d.u32()?) },
        11 => Wire::Paxos { g: Gid(d.u32()?), msg: get_paxos(d)? },
        12 => Wire::Heartbeat { bal: get_ballot(d)? },
        13 => Wire::GcReport { max_gts: get_ts(d)? },
        14 => {
            if !allow_batch {
                return Err(CodecError::NestedBatch);
            }
            let n = d.u32()? as usize;
            if n == 0 {
                return Err(CodecError::EmptyBatch);
            }
            let mut inner = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                inner.push(get_wire(d, false)?);
            }
            Wire::Batch(inner)
        }
        v => return Err(CodecError::BadTag { what: "Wire", value: v }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn rand_ts(r: &mut Rng) -> Ts {
        if r.chance(0.1) {
            Ts::BOT
        } else {
            Ts::new(r.range(1, 1 << 40), Gid(r.below(64) as u32))
        }
    }
    fn rand_ballot(r: &mut Rng) -> Ballot {
        if r.chance(0.1) {
            Ballot::BOT
        } else {
            Ballot::new(r.range(1, 1000) as u32, Pid(r.below(100) as u32))
        }
    }
    fn rand_meta(r: &mut Rng) -> MsgMeta {
        let n = r.below(40) as usize;
        MsgMeta {
            id: MsgId(r.next_u64()),
            dest: GidSet(r.next_u64() & 0x3FF),
            payload: (0..n).map(|_| r.below(256) as u8).collect::<Vec<u8>>().into(),
            submit_ns: r.next_u64(),
        }
    }
    fn rand_state(r: &mut Rng) -> MsgState {
        MsgState {
            meta: rand_meta(r),
            phase: *r.choose(&[Phase::Start, Phase::Proposed, Phase::Accepted, Phase::Committed]),
            lts: rand_ts(r),
            gts: rand_ts(r),
        }
    }
    fn rand_cmd(r: &mut Rng) -> RsmCmd {
        if r.chance(0.5) {
            RsmCmd::AssignLts { meta: rand_meta(r), lts: rand_ts(r) }
        } else {
            RsmCmd::Commit { m: MsgId(r.next_u64()), gts: rand_ts(r) }
        }
    }

    fn rand_wire(r: &mut Rng) -> Wire {
        match r.below(14) {
            0 => Wire::Multicast { meta: rand_meta(r) },
            1 => Wire::Delivered { m: MsgId(r.next_u64()), g: Gid(r.below(64) as u32), gts: rand_ts(r) },
            2 => Wire::Propose { m: MsgId(r.next_u64()), g: Gid(r.below(64) as u32), lts: rand_ts(r) },
            3 => Wire::Accept { meta: rand_meta(r), g: Gid(r.below(64) as u32), bal: rand_ballot(r), lts: rand_ts(r) },
            4 => {
                let n = r.below(8) as usize;
                Wire::AcceptAck {
                    m: MsgId(r.next_u64()),
                    g: Gid(r.below(64) as u32),
                    bals: (0..n).map(|i| (Gid(i as u32), rand_ballot(r))).collect(),
                }
            }
            5 => Wire::Deliver {
                m: MsgId(r.next_u64()),
                bal: rand_ballot(r),
                lts: rand_ts(r),
                gts: rand_ts(r),
                path: DeliveryPath::from_u8(r.below(4) as u8),
            },
            6 => Wire::NewLeader { bal: rand_ballot(r) },
            7 => {
                let n = r.below(5) as usize;
                Wire::NewLeaderAck {
                    bal: rand_ballot(r),
                    cbal: rand_ballot(r),
                    clock: r.next_u64(),
                    state: (0..n).map(|_| rand_state(r)).collect(),
                }
            }
            8 => {
                let n = r.below(5) as usize;
                Wire::NewState { bal: rand_ballot(r), clock: r.next_u64(), state: (0..n).map(|_| rand_state(r)).collect() }
            }
            9 => Wire::NewStateAck { bal: rand_ballot(r) },
            10 => Wire::Confirm { m: MsgId(r.next_u64()), g: Gid(r.below(64) as u32) },
            11 => {
                let msg = match r.below(5) {
                    0 => PaxosMsg::P1a { bal: rand_ballot(r) },
                    1 => {
                        let n = r.below(4) as usize;
                        PaxosMsg::P1b {
                            bal: rand_ballot(r),
                            log: (0..n).map(|i| (i as u64, rand_ballot(r), rand_cmd(r))).collect(),
                        }
                    }
                    2 => PaxosMsg::P2a { bal: rand_ballot(r), slot: r.next_u64(), cmd: rand_cmd(r) },
                    3 => PaxosMsg::P2b { bal: rand_ballot(r), slot: r.next_u64() },
                    _ => PaxosMsg::Learn { slot: r.next_u64(), cmd: rand_cmd(r) },
                };
                Wire::Paxos { g: Gid(r.below(64) as u32), msg }
            }
            12 => Wire::Heartbeat { bal: rand_ballot(r) },
            _ => Wire::GcReport { max_gts: rand_ts(r) },
        }
    }

    #[test]
    fn roundtrip_random_messages() {
        prop::check(500, |r| {
            let w = rand_wire(r);
            let bytes = encode(&w);
            let w2 = decode(&bytes).expect("decode");
            assert_eq!(w, w2);
        });
    }

    #[test]
    fn decode_rejects_truncated() {
        prop::check(200, |r| {
            let w = rand_wire(r);
            let bytes = encode(&w);
            if bytes.len() > 1 {
                let cut = r.range(1, bytes.len() as u64 - 1) as usize;
                // Truncation must never panic; it may error or (rarely for
                // length-prefixed payloads) still parse a prefix — but the
                // full-consumption check makes that impossible here.
                assert!(decode(&bytes[..cut]).is_err());
            }
        });
    }

    #[test]
    fn decode_rejects_bad_tag() {
        assert!(decode(&[200]).is_err());
        assert!(decode(&[]).is_err());
    }

    #[test]
    fn decode_rejects_trailing_garbage() {
        let w = Wire::NewStateAck { bal: Ballot::new(3, Pid(1)) };
        let mut bytes = encode(&w);
        bytes.push(0);
        assert!(matches!(decode(&bytes), Err(CodecError::Trailing(1))));
    }

    // ---------- Wire::Batch framing ----------

    fn rand_batch(r: &mut Rng) -> Wire {
        let n = r.range(1, 8) as usize;
        Wire::Batch((0..n).map(|_| rand_wire(r)).collect())
    }

    #[test]
    fn roundtrip_random_batches() {
        prop::check(200, |r| {
            let w = rand_batch(r);
            let bytes = encode(&w);
            let w2 = decode(&bytes).expect("decode batch");
            assert_eq!(w, w2);
        });
    }

    #[test]
    fn batch_rejects_nested() {
        // hand-assemble Batch[Batch[Heartbeat]] — the encoder debug-asserts
        // against this, so splice raw bytes
        let inner = encode(&Wire::Batch(vec![Wire::Heartbeat { bal: Ballot::new(1, Pid(0)) }]));
        let mut e = Enc::new();
        e.u8(14);
        e.u32(1);
        e.buf.extend_from_slice(&inner);
        assert!(matches!(decode(&e.buf), Err(CodecError::NestedBatch)));
    }

    #[test]
    fn batch_rejects_empty() {
        let mut e = Enc::new();
        e.u8(14);
        e.u32(0);
        assert!(matches!(decode(&e.buf), Err(CodecError::EmptyBatch)));
    }

    #[test]
    fn batch_rejects_truncated_inner_list() {
        // claims 3 inner messages, carries 1
        let mut e = Enc::new();
        e.u8(14);
        e.u32(3);
        encode_into(&mut e, &Wire::Heartbeat { bal: Ballot::new(1, Pid(0)) });
        assert!(decode(&e.buf).is_err());
    }

    #[test]
    fn batch_size_matches_encoded_framing_overhead() {
        // size() and the codec agree on the 5-byte frame header: the
        // batch's encoded length (and size estimate) is exactly header +
        // sum of the inner messages'.
        prop::check(100, |r| {
            let w = rand_batch(r);
            let Wire::Batch(inner) = &w else { unreachable!() };
            let inner_encoded: usize = inner.iter().map(|i| encode(i).len()).sum();
            assert_eq!(encode(&w).len(), 5 + inner_encoded);
            let inner_size: usize = inner.iter().map(|i| i.size()).sum();
            assert_eq!(w.size(), 5 + inner_size);
        });
    }

    // ---------- zero-copy shared-frame decoding ----------

    #[test]
    fn shared_decode_equals_copying_decode() {
        prop::check(300, |r| {
            let w = if r.chance(0.3) { rand_batch(r) } else { rand_wire(r) };
            let bytes = encode(&w);
            let frame: Arc<[u8]> = bytes.clone().into();
            let shared = decode_shared(&frame, 0, frame.len()).expect("decode_shared");
            assert_eq!(shared, w);
            assert_eq!(shared, decode(&bytes).expect("decode"));
        });
    }

    #[test]
    fn shared_decode_payloads_point_into_the_frame() {
        let meta = MsgMeta::new(MsgId::new(1, 7), GidSet(0b11), vec![9u8; 100]);
        let frame: Arc<[u8]> = encode(&Wire::Multicast { meta }).into();
        let Wire::Multicast { meta } = decode_shared(&frame, 0, frame.len()).unwrap() else {
            unreachable!()
        };
        // The payload is a window into the frame itself, not a copy.
        assert_eq!(meta.payload.backing_len(), frame.len());
        assert_eq!(&meta.payload[..], &[9u8; 100][..]);
        // By contrast the copying decoder re-allocates exactly the payload.
        let Wire::Multicast { meta: copied } = decode(&frame).unwrap() else { unreachable!() };
        assert_eq!(copied.payload.backing_len(), 100);
        assert!(!copied.payload.shares_buffer_with(&meta.payload));
    }

    #[test]
    fn shared_decode_rejects_out_of_range_window() {
        let frame: Arc<[u8]> = encode(&Wire::Heartbeat { bal: Ballot::new(1, Pid(0)) }).into();
        assert!(decode_shared(&frame, 0, frame.len() + 1).is_err());
        assert!(decode_shared(&frame, frame.len() + 1, frame.len() + 2).is_err());
        assert!(decode_shared(&frame, 2, 1).is_err());
    }
}
