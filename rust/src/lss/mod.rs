//! Leader-selection service (LSS, §IV).
//!
//! The paper assumes each group is equipped with an LSS that eventually
//! nominates the same correct member as leader to the whole group
//! (Invariant 6) — implementable in a partially-synchronous system from
//! heartbeat timeouts [Aguilera+ DISC'01, Larrea+ SRDS'00].
//!
//! This module provides the Ω-style detector used by the runtimes: the
//! leader emits heartbeats; followers suspect it after a *rank-staggered*
//! timeout, which makes the lowest-ranked correct member the first to
//! nominate itself and prevents duelling candidates. The same logic is
//! embedded in [`crate::protocols::wbcast`]'s `LssTick` handling; this
//! standalone version serves the coordinator runtime and the tests.

use crate::types::Pid;

/// Failure-detector state for one group member.
#[derive(Clone, Debug)]
pub struct FailureDetector {
    /// position of this process within its group (0 = initial leader)
    rank: u64,
    /// base heartbeat interval (ns)
    hb_interval: u64,
    /// multiplier: suspicion after `hb_interval * mult * (1 + rank)`
    mult: u64,
    last_heard: u64,
    suspects: bool,
}

impl FailureDetector {
    pub fn new(rank: u64, hb_interval: u64, mult: u64) -> Self {
        FailureDetector { rank, hb_interval, mult, last_heard: 0, suspects: false }
    }

    /// Record life-sign from the current leader (heartbeat or any
    /// protocol message it sent).
    pub fn heard(&mut self, now: u64) {
        self.last_heard = now;
        self.suspects = false;
    }

    /// The suspicion timeout for this member.
    pub fn timeout(&self) -> u64 {
        self.hb_interval * self.mult * (1 + self.rank)
    }

    /// Check the leader's health at `now`; returns true on the *edge*
    /// where this member starts suspecting (nomination trigger).
    pub fn check(&mut self, now: u64) -> bool {
        if self.suspects {
            return false;
        }
        if now.saturating_sub(self.last_heard) > self.timeout() {
            self.suspects = true;
            return true;
        }
        false
    }

    pub fn suspects(&self) -> bool {
        self.suspects
    }

    /// Deterministic next-candidate rule: the member ranked immediately
    /// after the failed leader in the group ring.
    pub fn next_candidate(members: &[Pid], failed: Pid) -> Pid {
        let i = members.iter().position(|&p| p == failed).unwrap_or(0);
        members[(i + 1) % members.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suspects_only_after_timeout() {
        let mut fd = FailureDetector::new(0, 100, 4);
        fd.heard(1000);
        assert!(!fd.check(1000 + 400));
        assert!(fd.check(1000 + 401));
        // edge-triggered: only fires once
        assert!(!fd.check(1000 + 500));
        assert!(fd.suspects());
    }

    #[test]
    fn heartbeat_resets_suspicion() {
        let mut fd = FailureDetector::new(0, 100, 4);
        fd.heard(0);
        assert!(fd.check(401));
        fd.heard(500);
        assert!(!fd.suspects());
        assert!(!fd.check(700));
        assert!(fd.check(902));
    }

    #[test]
    fn ranks_stagger_timeouts() {
        let fd0 = FailureDetector::new(0, 100, 4);
        let fd1 = FailureDetector::new(1, 100, 4);
        let fd2 = FailureDetector::new(2, 100, 4);
        assert!(fd0.timeout() < fd1.timeout());
        assert!(fd1.timeout() < fd2.timeout());
    }

    #[test]
    fn ring_candidate_selection() {
        let members = [Pid(3), Pid(4), Pid(5)];
        assert_eq!(FailureDetector::next_candidate(&members, Pid(3)), Pid(4));
        assert_eq!(FailureDetector::next_candidate(&members, Pid(5)), Pid(3));
    }
}
