//! Runtime bridge: load and execute the AOT-compiled JAX/Pallas
//! computations from the Rust hot path via the PJRT C API (`xla` crate).
//!
//! * [`engine`] — the XLA batch commit engine (`commit_batch_b*.hlo.txt`)
//!   and the latency-quantile computation (`quantiles.hlo.txt`).
//! * [`native`] — a bit-exact pure-Rust fallback, used for single-message
//!   operation and as the differential-testing oracle for the engine.
//!
//! Python never runs at request time: `make artifacts` lowers the L2
//! graph once; everything here consumes HLO *text* (the interchange
//! format that survives the jax≥0.5 ↔ xla_extension 0.5.1 proto
//! mismatch — see `python/compile/aot.py`).

pub mod engine;
pub mod native;
pub mod service;

pub use engine::{CommitBatchEngine, QuantileEngine};
pub use native::commit_batch_native;
pub use service::{spawn_engine, CommitBackend, EngineHandle, NativeBackend, XlaBackend};

use crate::types::{MsgId, Ts};

/// One message in a commit batch: its per-destination-group local
/// timestamps (already collected from ACCEPT_ACK quorums).
#[derive(Clone, Debug)]
pub struct BatchReq {
    pub m: MsgId,
    pub lts: Vec<Ts>,
}

/// Engine verdict for one message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchOut {
    pub m: MsgId,
    /// final global timestamp (max of local timestamps)
    pub gts: Ts,
    /// `gts < min(pending)` — deliverable once prior committed messages
    /// are delivered (the coordinator enforces gts order)
    pub deliverable: bool,
}
