//! Pure-Rust fallback for the batch commit computation — bit-exact with
//! the XLA engine (differential-tested in `rust/tests/engine.rs`) and
//! used when batches are tiny or the artifacts are absent.

use super::{BatchOut, BatchReq};
use crate::types::Ts;

/// Compute global timestamps + deliverability for a batch, given the
/// current pending (PROPOSED/ACCEPTED) local timestamps.
pub fn commit_batch_native(reqs: &[BatchReq], pending: &[Ts]) -> Vec<BatchOut> {
    let pmin = pending.iter().copied().min();
    reqs.iter()
        .map(|r| {
            let gts = r.lts.iter().copied().max().expect("empty lts set");
            let deliverable = match pmin {
                None => true,
                Some(p) => gts < p,
            };
            BatchOut { m: r.m, gts, deliverable }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Gid, MsgId};

    fn ts(t: u64, g: u32) -> Ts {
        Ts::new(t, Gid(g))
    }

    #[test]
    fn gts_is_lex_max() {
        let out = commit_batch_native(
            &[BatchReq { m: MsgId::new(1, 1), lts: vec![ts(5, 0), ts(3, 1)] }],
            &[],
        );
        assert_eq!(out[0].gts, ts(5, 0));
        assert!(out[0].deliverable);
    }

    #[test]
    fn pending_blocks_delivery() {
        let out = commit_batch_native(
            &[
                BatchReq { m: MsgId::new(1, 1), lts: vec![ts(5, 0)] },
                BatchReq { m: MsgId::new(1, 2), lts: vec![ts(9, 0)] },
            ],
            &[ts(7, 1), ts(8, 0)],
        );
        assert!(out[0].deliverable, "5 < 7");
        assert!(!out[1].deliverable, "9 > 7");
    }

    #[test]
    fn lex_order_tiebreak_on_group() {
        let out = commit_batch_native(
            &[BatchReq { m: MsgId::new(1, 1), lts: vec![ts(5, 0), ts(5, 3)] }],
            &[ts(5, 4)],
        );
        assert_eq!(out[0].gts, ts(5, 3));
        assert!(out[0].deliverable, "(5,3) < (5,4)");
    }
}
