//! XLA/PJRT execution engines for the AOT artifacts.
//!
//! `CommitBatchEngine` compiles the `commit_batch_b{16,64,256}.hlo.txt`
//! variants once at startup and, per call, picks the smallest variant
//! that fits the batch, pads the int64 lane buffers and executes on the
//! CPU PJRT client. `QuantileEngine` does the same for the latency
//! quantile sketch.
//!
//! The PJRT bindings (the `xla` crate) are optional: the offline build
//! cannot fetch them, so they sit behind the `xla` cargo feature. With
//! the feature off, the engines still type-check but `load` fails and
//! every caller falls back to the bit-exact native path
//! ([`super::native`]) — the default deployment.

use super::{BatchOut, BatchReq};
use crate::types::Ts;
use anyhow::Result;
use std::path::Path;

/// Must match `python/compile/aot.py`.
pub const G_LANES: usize = 16;
pub const P_SLOTS: usize = 256;
pub const BATCH_SIZES: [usize; 3] = [16, 64, 256];
pub const N_SAMPLES: usize = 1024;

#[cfg(feature = "xla")]
mod imp {
    use super::*;
    use anyhow::{bail, Context};
    use std::collections::BTreeMap;

    const NEG_INF: i64 = -(1 << 62);
    const POS_INF: i64 = 1 << 62;

    /// Loads and runs the batched commit computation (L2 `commit_batch`).
    pub struct CommitBatchEngine {
        client: xla::PjRtClient,
        exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
        /// executions performed (stats)
        pub calls: std::cell::Cell<u64>,
    }

    fn load_exe(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("bad path")?)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    impl CommitBatchEngine {
        /// Load every batch-size variant from `dir` (default `artifacts/`).
        pub fn load(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let mut exes = BTreeMap::new();
            for b in BATCH_SIZES {
                let path = dir.join(format!("commit_batch_b{b}.hlo.txt"));
                if !path.exists() {
                    bail!("missing artifact {} — run `make artifacts`", path.display());
                }
                exes.insert(b, load_exe(&client, &path)?);
            }
            Ok(CommitBatchEngine { client, exes, calls: std::cell::Cell::new(0) })
        }

        /// Largest supported batch per execution.
        pub fn max_batch(&self) -> usize {
            *self.exes.keys().next_back().unwrap()
        }

        /// Execute one commit batch. `pending` is the current delivery
        /// frontier content; only its 256 smallest entries matter (the
        /// computation takes their min), so callers may truncate.
        pub fn commit_batch(&self, reqs: &[BatchReq], pending: &[Ts]) -> Result<Vec<BatchOut>> {
            if reqs.is_empty() {
                return Ok(vec![]);
            }
            let max_b = self.max_batch();
            let mut out = Vec::with_capacity(reqs.len());
            for chunk in reqs.chunks(max_b) {
                out.extend(self.run_chunk(chunk, pending)?);
            }
            Ok(out)
        }

        fn run_chunk(&self, reqs: &[BatchReq], pending: &[Ts]) -> Result<Vec<BatchOut>> {
            let b = *self
                .exes
                .keys()
                .find(|&&b| b >= reqs.len())
                .expect("chunked to max batch size");
            let exe = &self.exes[&b];

            // lane buffers (padded)
            let mut lts = vec![0i64; b * G_LANES];
            let mut mask = vec![0i64; b * G_LANES];
            for (i, r) in reqs.iter().enumerate() {
                assert!(!r.lts.is_empty(), "empty lts set for {:?}", r.m);
                assert!(r.lts.len() <= G_LANES, "too many destination groups");
                for (j, &t) in r.lts.iter().enumerate() {
                    lts[i * G_LANES + j] = t.encode();
                    mask[i * G_LANES + j] = 1;
                }
            }
            let mut pend = vec![0i64; P_SLOTS];
            let mut pmask = vec![0i64; P_SLOTS];
            for (i, &t) in pending.iter().take(P_SLOTS).enumerate() {
                pend[i] = t.encode();
                pmask[i] = 1;
            }

            let l_lts = xla::Literal::vec1(&lts).reshape(&[b as i64, G_LANES as i64])?;
            let l_mask = xla::Literal::vec1(&mask).reshape(&[b as i64, G_LANES as i64])?;
            let l_pend = xla::Literal::vec1(&pend);
            let l_pmask = xla::Literal::vec1(&pmask);

            let result = exe.execute::<xla::Literal>(&[l_lts, l_mask, l_pend, l_pmask])?[0][0]
                .to_literal_sync()?;
            self.calls.set(self.calls.get() + 1);
            let (gts_l, deliv_l, _pmin_l) = result.to_tuple3()?;
            let gts_v = gts_l.to_vec::<i64>()?;
            let deliv_v = deliv_l.to_vec::<i64>()?;

            Ok(reqs
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    debug_assert!(gts_v[i] != NEG_INF && gts_v[i] < POS_INF);
                    BatchOut { m: r.m, gts: Ts::decode(gts_v[i]), deliverable: deliv_v[i] != 0 }
                })
                .collect())
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }

    /// Loads and runs the latency-quantile sketch (`quantiles.hlo.txt`).
    pub struct QuantileEngine {
        _client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
    }

    impl QuantileEngine {
        pub fn load(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let exe = load_exe(&client, &dir.join("quantiles.hlo.txt"))?;
            Ok(QuantileEngine { _client: client, exe })
        }

        /// Quantiles (0.5, 0.9, 0.95, 0.99) of up to [`N_SAMPLES`] latency
        /// samples (ns). Fewer samples are padded by cycling — an
        /// approximation that preserves the empirical distribution.
        pub fn quantiles(&self, samples_ns: &[u64]) -> Result<[f64; 4]> {
            anyhow::ensure!(!samples_ns.is_empty(), "no samples");
            let mut buf = vec![0f32; N_SAMPLES];
            for i in 0..N_SAMPLES {
                buf[i] = samples_ns[i % samples_ns.len()] as f32;
            }
            let lit = xla::Literal::vec1(&buf);
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?.to_vec::<f32>()?;
            Ok([out[0] as f64, out[1] as f64, out[2] as f64, out[3] as f64])
        }
    }
}

#[cfg(feature = "xla")]
pub use imp::{CommitBatchEngine, QuantileEngine};

#[cfg(not(feature = "xla"))]
mod stub {
    use super::*;
    use anyhow::bail;

    /// Built without the `xla` feature: `load` always fails, so no value
    /// of this type can exist (the `Infallible` field makes the
    /// post-load methods statically unreachable). Callers fall back to
    /// [`crate::runtime::native`].
    pub struct CommitBatchEngine {
        never: std::convert::Infallible,
    }

    impl CommitBatchEngine {
        pub fn load(_dir: &Path) -> Result<Self> {
            bail!("wbam was built without the `xla` feature — XLA offload unavailable, use the native backend")
        }
        pub fn max_batch(&self) -> usize {
            match self.never {}
        }
        pub fn commit_batch(&self, _reqs: &[BatchReq], _pending: &[Ts]) -> Result<Vec<BatchOut>> {
            match self.never {}
        }
        pub fn platform(&self) -> String {
            match self.never {}
        }
    }

    /// See [`CommitBatchEngine`]: stub that never loads.
    pub struct QuantileEngine {
        never: std::convert::Infallible,
    }

    impl QuantileEngine {
        pub fn load(_dir: &Path) -> Result<Self> {
            bail!("wbam was built without the `xla` feature — XLA offload unavailable")
        }
        pub fn quantiles(&self, _samples_ns: &[u64]) -> Result<[f64; 4]> {
            match self.never {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{CommitBatchEngine, QuantileEngine};

/// Default artifacts directory: `$WBAM_ARTIFACTS` or `artifacts/` under
/// the crate root (works from `cargo test` / `cargo bench` cwd).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("WBAM_ARTIFACTS") {
        return d.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
