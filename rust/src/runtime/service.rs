//! Engine service thread: the `xla` crate's PJRT handles are raw
//! pointers (!Send), so a single dedicated thread owns the
//! [`CommitBatchEngine`] and serves commit batches over channels. The
//! [`EngineHandle`] is cheap to clone and `Send`, so protocol nodes and
//! coordinator threads can all submit work.

use super::{BatchOut, BatchReq, CommitBatchEngine};
use crate::types::Ts;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc;

enum Req {
    Commit { reqs: Vec<BatchReq>, pending: Vec<Ts>, reply: mpsc::Sender<Result<Vec<BatchOut>, String>> },
    Shutdown,
}

/// Client side of the engine service.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Req>,
}

impl EngineHandle {
    /// Synchronous batched commit through the XLA engine.
    pub fn commit_batch(&self, reqs: Vec<BatchReq>, pending: Vec<Ts>) -> Result<Vec<BatchOut>, String> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Req::Commit { reqs, pending, reply }).map_err(|e| e.to_string())?;
        rx.recv().map_err(|e| e.to_string())?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

/// Spawn the engine thread; fails fast if the artifacts are missing.
pub fn spawn_engine(dir: PathBuf) -> Result<EngineHandle> {
    // load on the caller thread first to surface errors synchronously…
    // (PJRT handles are !Send, so we must re-load inside the thread)
    drop(CommitBatchEngine::load(&dir)?);
    let (tx, rx) = mpsc::channel::<Req>();
    std::thread::Builder::new()
        .name("wbam-xla-engine".into())
        .spawn(move || {
            let engine = match CommitBatchEngine::load(&dir) {
                Ok(e) => e,
                Err(e) => {
                    log::error!("engine thread failed to load artifacts: {e}");
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Commit { reqs, pending, reply } => {
                        let out = engine.commit_batch(&reqs, &pending).map_err(|e| e.to_string());
                        let _ = reply.send(out);
                    }
                    Req::Shutdown => break,
                }
            }
        })
        .expect("spawn engine thread");
    Ok(EngineHandle { tx })
}

/// The commit backend abstraction protocol nodes call at commit time.
pub trait CommitBackend: Send {
    fn commit_batch(&mut self, reqs: &[BatchReq], pending: &[Ts]) -> Vec<BatchOut>;
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (default).
pub struct NativeBackend;

impl CommitBackend for NativeBackend {
    fn commit_batch(&mut self, reqs: &[BatchReq], pending: &[Ts]) -> Vec<BatchOut> {
        super::native::commit_batch_native(reqs, pending)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// XLA backend: routes through the engine service thread. Falls back to
/// the native path on engine errors (availability over offload).
pub struct XlaBackend {
    handle: EngineHandle,
    pub fallbacks: u64,
}

impl XlaBackend {
    pub fn new(handle: EngineHandle) -> Self {
        XlaBackend { handle, fallbacks: 0 }
    }
}

impl CommitBackend for XlaBackend {
    fn commit_batch(&mut self, reqs: &[BatchReq], pending: &[Ts]) -> Vec<BatchOut> {
        match self.handle.commit_batch(reqs.to_vec(), pending.to_vec()) {
            Ok(out) => out,
            Err(e) => {
                log::warn!("XLA engine error ({e}); native fallback");
                self.fallbacks += 1;
                super::native::commit_batch_native(reqs, pending)
            }
        }
    }
    fn name(&self) -> &'static str {
        "xla"
    }
}
