//! `wbam` — launcher CLI for the white-box atomic multicast framework.
//!
//! ```text
//! wbam sim   --proto wbcast|fastcast|ftskeen|skeen --groups 10 --clients 500
//!            --dest 3 --net lan|wan|theory [--delta-us 1000] [--duration-ms 5000]
//!            [--seed 42]                       # simulated deployment
//! wbam table                                   # §V latency table (T-lat)
//! wbam serve --pid 0 --config cluster.toml [--shards 4]   # TCP member endpoint
//!            [--data-dir DIR] [--sync always|never|interval|interval:<us>]
//!            [--transport tcp|epoll|uring] [--metrics-addr 127.0.0.1:9464]
//!            [--stats-json]
//! wbam client --pid 30 --config cluster.toml --dest 2 --requests 100 [--shards 4]
//!            [--transport tcp|epoll|uring] [--stamp]
//! wbam engine-check                            # load + self-test XLA artifacts
//! ```
//!
//! `--transport` picks the real transport (`serve` and `client`; both
//! sides may differ — the wire format is identical): `tcp` (default) is
//! the threaded transport with one reader thread per accepted
//! connection; `epoll` (Linux) multiplexes every connection on one
//! event-loop thread; `uring` (Linux ≥ 6.0) batches all of an
//! endpoint's IO through one io_uring submission/completion loop —
//! where the kernel (or a seccomp sandbox) cannot run io_uring the
//! endpoint falls back to epoll with a warning and a
//! `transport_fallbacks` counter tick instead of dying. See
//! `ARCHITECTURE.md` §Transports.
//!
//! Durable storage (`serve`): with `--data-dir` every hosted shard node
//! journals its protocol state into a segmented, CRC-checksummed WAL
//! under `DIR/p<pid>/` (group-commit fsync policy per `--sync`,
//! default `interval` = at most one fsync per 5 ms). A killed `serve`
//! restarted with the same `--data-dir` replays log + snapshot and
//! rejoins its group through the recovery protocol. Type `quit` (or
//! `q`) on stdin to stop cleanly; the final `CoordStats`/`NetStats`/
//! storage counter summary prints on shutdown (add `--stats-json` for a
//! machine-readable copy).
//!
//! Live observability (`serve`): `--metrics-addr HOST:PORT` starts the
//! dependency-free exposition listener (`GET /metrics` in Prometheus
//! text format, `GET /debug/flight` for the protocol flight recorder;
//! SIGUSR1 dumps the flight ring into the log) and attaches the
//! [`CoreMetrics`](wbam::obs::CoreMetrics) sink to the runtime: per-path
//! delivery counters (fast 3δ / concurrent 5δ / recovery), end-to-end
//! and per-stage latency histograms, an HLL distinct-client estimator,
//! and every `CoordStats`/`NetStats`/`StorageStats` counter. End-to-end
//! latency needs clients started with `--stamp` (wall-clock submit
//! stamps on each multicast). See `ARCHITECTURE.md` §Observability.
//!
//! Adaptive wire coalescing (`sim`, `serve` and `client` accept all
//! three; the default flushes one frame per link per event-loop cycle):
//!
//! ```text
//! --flush-max-delay-us N   hold a link's wires up to N µs for companions
//! --flush-max-bytes B      flush a link early at B pending encoded bytes
//! --flush-no-quiet         do NOT flush early when the loop goes idle
//! ```
//!
//! The cluster config file lists the deployment:
//!
//! ```toml
//! [cluster]
//! groups = 2
//! f = 1
//! [addrs]
//! p0 = "127.0.0.1:7000"   # one per process (members then clients)
//! ...
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use wbam::client::{Client, ClientCfg};
use wbam::config::{Args, Config};
use wbam::coordinator::{NodeRuntime, ShardedRuntime};
use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::net::{TcpTransport, Transport};
use wbam::obs::{
    install_sigusr1, register_coord_stats, register_net_stats, register_storage_stats, CoreMetrics,
    MetricsServer, Registry, StatsReport,
};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::Node;
use wbam::runtime::{spawn_engine, CommitBackend, NativeBackend, XlaBackend};
use wbam::sim::MS;
use wbam::storage::{Storage, SyncPolicy};
use wbam::sync::atomic::AtomicBool;
use wbam::sync::{thread, Arc};
use wbam::types::{FlushPolicy, Pid, ShardMap};

fn parse_proto(s: &str) -> Result<Proto> {
    Ok(match s {
        "skeen" => Proto::Skeen,
        "ftskeen" | "ft-skeen" => Proto::FtSkeen,
        "fastcast" => Proto::FastCast,
        "wbcast" | "wb" => Proto::WbCast,
        _ => bail!("unknown protocol {s:?} (skeen|ftskeen|fastcast|wbcast)"),
    })
}

fn parse_net(a: &Args) -> Result<Net> {
    Ok(match a.str_opt("net", "lan").as_str() {
        "lan" => Net::Lan,
        "wan" => Net::Wan,
        "theory" => Net::Theory { delta: a.u64_opt("delta-us", 1000) * 1000 },
        s => bail!("unknown net {s:?} (lan|wan|theory)"),
    })
}

/// The `--flush-*` adaptive-coalescing flags (shared by `sim`, `serve`
/// and `client`); defaults reproduce the one-frame-per-cycle policy.
fn parse_flush(a: &Args) -> FlushPolicy {
    FlushPolicy {
        max_delay_us: a.u64_opt("flush-max-delay-us", 0),
        max_bytes: a.usize_opt("flush-max-bytes", usize::MAX),
        flush_on_quiet: !a.flag("flush-no-quiet"),
    }
}

/// The `--transport` flag (`serve`, `client`): bind the endpoint over
/// the threaded TCP transport (default), the Linux epoll event loop or
/// the Linux io_uring completion loop. All speak the same wire format,
/// so a deployment may mix them. `uring` probes kernel support first
/// and degrades to epoll — with a single warning and a
/// `NetStats::transport_fallbacks` tick — instead of dying on old
/// kernels or seccomp'd CI.
fn bind_transport(a: &Args, pid: Pid, addrs: HashMap<Pid, std::net::SocketAddr>) -> Result<Box<dyn Transport>> {
    let kind = a.str_opt("transport", "tcp");
    Ok(match kind.as_str() {
        "tcp" => Box::new(TcpTransport::bind(pid, addrs)?),
        #[cfg(target_os = "linux")]
        "epoll" => Box::new(wbam::net::EpollTransport::bind(pid, addrs)?),
        #[cfg(target_os = "linux")]
        "uring" => match wbam::net::uring_probe() {
            Ok(()) => Box::new(wbam::net::UringTransport::bind(pid, addrs)?),
            Err(reason) => {
                log::warn!("transport uring unavailable ({reason}); falling back to epoll");
                eprintln!("warning: transport uring unavailable ({reason}); falling back to epoll");
                let t = wbam::net::EpollTransport::bind(pid, addrs)?;
                t.net_stats().transport_fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Box::new(t)
            }
        },
        s => bail!(
            "unknown transport {s:?} (tcp|epoll|uring{})",
            if cfg!(target_os = "linux") { "" } else { "; epoll/uring require linux" }
        ),
    })
}

fn cmd_sim(a: &Args) -> Result<()> {
    let proto = parse_proto(&a.str_opt("proto", "wbcast"))?;
    let mut cfg = RunCfg::new(
        proto,
        a.usize_opt("groups", 10),
        a.usize_opt("clients", 100),
        a.usize_opt("dest", 2),
        parse_net(a)?,
    );
    cfg.seed = a.u64_opt("seed", 42);
    cfg.duration = a.u64_opt("duration-ms", 5_000) * MS;
    cfg.flush = parse_flush(a);
    let r = run(&cfg);
    println!("{}", r.row());
    Ok(())
}

fn cmd_table(_a: &Args) -> Result<()> {
    println!("§V latency table (δ = 1 ms, constant-delay network, zero CPU cost)");
    println!("{:<10} {:>14} {:>14}  (paper: CFL / FFL)", "protocol", "collision-free", "measured-solo");
    for (proto, cfl, ffl) in
        [(Proto::Skeen, 2, 4), (Proto::WbCast, 3, 5), (Proto::FastCast, 4, 8), (Proto::FtSkeen, 6, 12)]
    {
        let mut cfg = RunCfg::new(proto, 2, 1, 2, Net::Theory { delta: MS });
        cfg.max_requests = Some(1);
        let r = run(&cfg);
        println!("{:<10} {:>13}δ {:>13.1}δ  (paper: {}δ / {}δ)", proto.name(), cfl, r.mean_lat_ms, cfl, ffl);
    }
    Ok(())
}

/// Load the cluster config: the shard map and the address book. The
/// config lists one address per *endpoint* (group members then clients);
/// with `--shards S` every member pid's shard counterparts alias the
/// member's address, so shard traffic reaches the hosting endpoint.
fn load_cluster(a: &Args) -> Result<(ShardMap, HashMap<Pid, std::net::SocketAddr>)> {
    let path = a.opt("config").context("--config required")?;
    let cfg = Config::load(path)?;
    let groups = cfg.usize("cluster.groups", 2)?;
    let f = cfg.usize("cluster.f", 1)?;
    let shards = a.usize_opt("shards", 1);
    let map = ShardMap::new(groups, f, shards);
    let members = map.members_per_shard() as u32;
    let mut addrs: HashMap<Pid, std::net::SocketAddr> = HashMap::new();
    let mut i = 0u32;
    while let Some(addr) = cfg.get(&format!("addrs.p{i}")) {
        let addr = addr.parse().with_context(|| format!("addrs.p{i}"))?;
        if i < members {
            // a member endpoint: every shard counterpart lives here
            for p in map.hosted_by(Pid(i)) {
                addrs.insert(p, addr);
            }
        } else {
            // a client: its pid is shifted past all shards' members
            addrs.insert(Pid(i - members + map.first_client_pid().0), addr);
        }
        i += 1;
    }
    if i < members {
        bail!("config lists {i} addresses; {members} group members required");
    }
    Ok((map, addrs))
}

fn cmd_serve(a: &Args) -> Result<()> {
    let (map, addrs) = load_cluster(a)?;
    let pid = Pid(a.u64_opt("pid", 0) as u32);
    if (pid.0 as usize) >= map.members_per_shard() {
        bail!("{pid:?} is not a member endpoint (0..{})", map.members_per_shard());
    }
    let mut wb = WbConfig::with_failures(5 * MS);
    wb.batch_threshold = a.usize_opt("batch", 1);
    wb.batch_flush_after = a.u64_opt("flush-us", 200) * 1000;
    // durable storage: one WAL per hosted shard node under --data-dir
    let data_dir = a.opt("data-dir").map(std::path::PathBuf::from);
    let sync_spec = a.str_opt("sync", "interval");
    let sync = SyncPolicy::parse(&sync_spec)
        .with_context(|| format!("--sync {sync_spec:?} (always | never | interval | interval:<us>)"))?;
    wb.durability = data_dir.is_some();
    let engine = if a.flag("xla") { Some(spawn_engine(wbam::runtime::engine::artifacts_dir())?) } else { None };
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    let mut stores: Vec<(Pid, Storage)> = Vec::new();
    for p in map.hosted_by(pid) {
        let topo = map.topo(map.shard_of(p).expect("hosted pid is a member"));
        let backend: Box<dyn CommitBackend> = match &engine {
            Some(h) => Box::new(XlaBackend::new(h.clone())),
            None => Box::new(NativeBackend),
        };
        let node: Box<dyn Node> = match &data_dir {
            Some(dir) => {
                let store = Storage::open(dir.join(format!("p{}", p.0)), sync)
                    .with_context(|| format!("opening storage for {p:?}"))?;
                let node: Box<dyn Node> = if store.image().is_blank() {
                    Box::new(WbNode::with_backend(p, topo, wb, backend))
                } else {
                    println!(
                        "  {p:?}: restored {} journal records from {:?}; rejoining via recovery",
                        store.record_count(),
                        store.dir()
                    );
                    Box::new(WbNode::restore_with_backend(p, topo, wb, store.image(), backend))
                };
                stores.push((p, store));
                node
            }
            None => Box::new(WbNode::with_backend(p, topo, wb, backend)),
        };
        nodes.push(node);
    }
    let transport = bind_transport(a, pid, addrs)?;
    let net = transport.net_stats();
    println!(
        "serving endpoint {pid:?}: {} shard node(s){}{} [{} transport]",
        nodes.len(),
        if nodes.len() == 1 { " (inline fast path)" } else { "" },
        if wb.durability { " [durable]" } else { "" },
        a.str_opt("transport", "tcp"),
    );
    let stop = Arc::new(AtomicBool::new(false));
    // clean-shutdown trigger: a `quit` line on stdin (the offline image
    // has no signal-handling crate); EOF leaves the server running
    {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            let mut line = String::new();
            loop {
                line.clear();
                match std::io::stdin().read_line(&mut line) {
                    Ok(0) | Err(_) => return, // EOF/closed stdin: keep serving
                    Ok(_) if matches!(line.trim(), "quit" | "q") => break,
                    Ok(_) => {}
                }
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
    }
    let mut rt = ShardedRuntime::new(nodes, transport);
    let store_stats: Vec<_> = stores.iter().map(|(_, s)| s.stats()).collect();
    for (p, s) in stores {
        rt.attach_storage(p, s);
    }
    rt.flush_policy(parse_flush(a));
    let stats = rt.stats();
    // live observability: registry + exposition listener + flight dump
    let mut obs_handles = None;
    if let Some(maddr) = a.opt("metrics-addr") {
        let reg = Arc::new(Registry::new());
        let cm = CoreMetrics::register(&reg);
        register_coord_stats(&reg, &stats);
        register_net_stats(&reg, &net);
        register_storage_stats(&reg, store_stats.clone());
        if !install_sigusr1() {
            log::warn!("could not install the SIGUSR1 flight-dump handler");
        }
        let srv = MetricsServer::serve(maddr, Arc::clone(&reg), Some(Arc::clone(&cm.flight)))
            .with_context(|| format!("--metrics-addr {maddr:?}"))?;
        println!("  metrics: http://{}/metrics  (also /debug/flight; SIGUSR1 dumps the flight ring)", srv.addr);
        rt.attach_metrics(Arc::clone(&cm));
        obs_handles = Some((srv, cm));
    }
    rt.on_deliver(Box::new(|pid, m, gts, _| {
        log::info!("{pid:?} deliver {m:?} gts {gts:?}");
    }));
    rt.run(stop);
    // final counter summary (storage WALs fsync as the runtime drops)
    let mut report = StatsReport::new(&stats, &net).with_storage(&store_stats);
    if let Some((_, cm)) = &obs_handles {
        report = report.with_core(cm);
    }
    println!("endpoint {pid:?} shut down:");
    print!("{report}");
    if a.flag("stats-json") {
        println!("{}", report.to_json());
    }
    drop(obs_handles); // joins the listener thread
    Ok(())
}

fn cmd_client(a: &Args) -> Result<()> {
    let (map, addrs) = load_cluster(a)?;
    let pid = Pid(a.u64_opt("pid", map.first_client_pid().0 as u64) as u32);
    if (pid.0 as usize) < map.num_members() {
        bail!("{pid:?} is a member pid; client pids start at {}", map.first_client_pid());
    }
    let topo = map.topo(map.client_shard(pid));
    let requests = a.u64_opt("requests", 100) as u32;
    let ccfg = ClientCfg {
        dest_groups: a.usize_opt("dest", 1),
        max_requests: Some(requests),
        resend_after: 2_000 * MS,
        // --stamp: wall-clock submit stamps for the servers' end-to-end
        // latency exporter (off by default; adds 8 real bytes per wire)
        stamp: a.flag("stamp"),
        ..Default::default()
    };
    let node = Box::new(Client::new(pid, topo, ccfg, a.u64_opt("seed", 7)));
    let transport = bind_transport(a, pid, addrs)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let mut rt = NodeRuntime::new(node, transport);
    rt.flush_policy(parse_flush(a));
    let handle = thread::spawn(move || rt.run(stop2));
    // the closed loop finishes when `requests` complete; give it a bounded
    // wall-clock window, then stop and report what we got
    let timeout = std::time::Duration::from_secs(a.u64_opt("timeout-s", 30));
    thread::sleep(timeout);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let node = handle.join().expect("client thread");
    let any: &dyn Node = &*node;
    if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
        println!("completed {} requests", c.completed.len());
        if !c.completed.is_empty() {
            let mean = c.completed.iter().map(|s| (s.done_at - s.sent_at) as f64).sum::<f64>()
                / c.completed.len() as f64;
            println!("mean latency: {:.3} ms", mean / 1e6);
        }
    }
    Ok(())
}

fn cmd_engine_check(_a: &Args) -> Result<()> {
    use wbam::runtime::{BatchReq, CommitBatchEngine};
    use wbam::types::{Gid, MsgId, Ts};
    let dir = wbam::runtime::engine::artifacts_dir();
    let eng = CommitBatchEngine::load(&dir)?;
    println!("platform: {}", eng.platform());
    let reqs =
        vec![BatchReq { m: MsgId::new(1, 1), lts: vec![Ts::new(3, Gid(0)), Ts::new(5, Gid(1))] }];
    let out = eng.commit_batch(&reqs, &[Ts::new(9, Gid(2))])?;
    anyhow::ensure!(out[0].gts == Ts::new(5, Gid(1)) && out[0].deliverable, "self-test failed");
    println!("commit_batch self-test OK ({} variants loaded)", 3);
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("sim") => cmd_sim(&args),
        Some("table") => cmd_table(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("engine-check") => cmd_engine_check(&args),
        _ => {
            eprintln!("usage: wbam <sim|table|serve|client|engine-check> [--options]");
            eprintln!("see `rust/src/main.rs` docs for details");
            Ok(())
        }
    }
}
