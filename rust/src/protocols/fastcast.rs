//! FastCast (Coelho, Schiper, Pedone; DSN'17) — the state-of-the-art
//! baseline the paper compares against (§VI).
//!
//! FastCast optimises FT-Skeen with *speculative execution* while still
//! using consensus as a black box: upon MULTICAST the group leader issues
//! a local timestamp from its clock and starts consensus#1 to persist it,
//! but *immediately* sends the timestamp to the other destination leaders
//! without waiting. Leaders act speculatively on received timestamps —
//! compute the global timestamp as the maximum and start consensus#2
//! persisting it — and exchange CONFIRM messages once consensus#1
//! decides. By the time the confirmations arrive, consensus#2 has
//! typically also decided, so the message commits at once.
//!
//! Latency: commit = max(consensus#2, CONFIRM exchange) completes 4δ
//! after multicast; the clock advance persists with consensus#2, so the
//! clock-update latency is also 4δ → collision-free 4δ, failure-free 8δ.
//!
//! Scope: steady-state path with the deployment-time leader (like
//! [`crate::protocols::ftskeen`]); the paper's recovery experiment
//! exercises only the white-box protocol.

use crate::paxos::Paxos;
use crate::protocols::{Node, Outbox, TimerKind};
use crate::types::wire::RsmCmd;
use crate::types::{DeliveryPath, Gid, GidSet, MsgId, MsgMeta, Phase, Pid, Topology, Ts, Wire};
use std::collections::{BTreeSet, HashMap, HashSet};

struct Entry {
    meta: MsgMeta,
    phase: Phase,
    lts: Ts,
    gts: Ts,
    delivered: bool,
    /// consensus#2 applied (gts persisted)
    commit_applied: bool,
    /// destination groups whose consensus#1 is confirmed
    confirms: GidSet,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct FcStats {
    pub committed: u64,
    pub delivered: u64,
    pub consensus_instances: u64,
    pub speculative_commits: u64,
}

/// One FastCast replica.
pub struct FastCastNode {
    pid: Pid,
    gid: Gid,
    topo: Topology,
    paxos: Paxos,

    // ---- replicated state ----
    clock: u64,
    entries: HashMap<MsgId, Entry>,
    pending: BTreeSet<(Ts, MsgId)>,
    committed: BTreeSet<(Ts, MsgId)>,

    // ---- leader-only speculation state ----
    /// eager local-timestamp counter (persisted clock ∨ last assignment)
    next_assign: u64,
    proposals: HashMap<MsgId, HashMap<Gid, Ts>>,
    submitted: HashSet<MsgId>,
    commit_submitted: HashSet<MsgId>,
    /// follower: highest gts delivered on the leader's order
    max_follower_gts: Ts,

    pub stats: FcStats,
}

impl FastCastNode {
    pub fn new(pid: Pid, topo: Topology) -> Self {
        let gid = topo.group_of(pid).expect("FastCastNode must be a group member");
        FastCastNode {
            pid,
            gid,
            paxos: Paxos::new(pid, &topo, gid),
            topo,
            clock: 0,
            entries: HashMap::new(),
            pending: BTreeSet::new(),
            committed: BTreeSet::new(),
            next_assign: 0,
            proposals: HashMap::new(),
            submitted: HashSet::new(),
            commit_submitted: HashSet::new(),
            max_follower_gts: Ts::BOT,
            stats: FcStats::default(),
        }
    }

    pub fn is_leader(&self) -> bool {
        self.paxos.is_leader()
    }
    pub fn clock(&self) -> u64 {
        self.clock
    }
    pub fn phase_of(&self, m: MsgId) -> Phase {
        self.entries.get(&m).map(|e| e.phase).unwrap_or(Phase::Start)
    }

    fn entry(&mut self, meta: &MsgMeta) -> &mut Entry {
        self.entries.entry(meta.id).or_insert_with(|| Entry {
            meta: meta.clone(),
            phase: Phase::Start,
            lts: Ts::BOT,
            gts: Ts::BOT,
            delivered: false,
            commit_applied: false,
            confirms: GidSet::EMPTY,
        })
    }

    fn apply(&mut self, cmd: RsmCmd, out: &mut Outbox) {
        match cmd {
            // persist the speculatively chosen local timestamp
            RsmCmd::AssignLts { meta, lts } => {
                let gid = self.gid;
                let is_leader = self.is_leader();
                let m = meta.id;
                let dest = meta.dest;
                let e = self.entry(&meta);
                if e.phase != Phase::Start {
                    return; // duplicate
                }
                e.phase = Phase::Proposed;
                e.lts = lts;
                // at the leader the (lts, m) pair is already in `pending`
                // from speculation time; BTreeSet insert is idempotent
                self.pending.insert((lts, m));
                self.clock = self.clock.max(lts.time());
                if is_leader {
                    // consensus#1 decided: confirm to the other leaders
                    for g in dest.iter() {
                        if g != gid {
                            out.send(self.topo.initial_leader(g), Wire::Confirm { m, g: gid });
                        }
                    }
                    self.on_confirm(m, gid, out);
                }
            }
            // persist the speculative global timestamp + clock advance
            RsmCmd::Commit { m, gts } => {
                let Some(e) = self.entries.get_mut(&m) else { return };
                if e.commit_applied {
                    return;
                }
                e.commit_applied = true;
                e.gts = gts;
                self.clock = self.clock.max(gts.time());
                // the in-memory assignment counter catches up with the
                // *persisted* clock only here — this is what gives
                // FastCast its 4δ clock-update latency (C in Thm. 4)
                self.next_assign = self.next_assign.max(self.clock);
                self.try_finalize(m, out);
            }
        }
    }

    /// Commit point: consensus#2 applied ∧ consensus#1 confirmed by every
    /// destination group (followers see confirmations implicitly — the
    /// leader only Learns a Commit after it committed itself, so log
    /// order suffices for them).
    fn try_finalize(&mut self, m: MsgId, out: &mut Outbox) {
        let is_leader = self.paxos.is_leader();
        let Some(e) = self.entries.get_mut(&m) else { return };
        if e.phase == Phase::Committed || !e.commit_applied {
            return;
        }
        if is_leader && e.confirms != e.meta.dest {
            return;
        }
        e.phase = Phase::Committed;
        let (lts, gts) = (e.lts, e.gts);
        self.pending.remove(&(lts, m));
        if is_leader {
            self.committed.insert((gts, m)); // followers deliver on DELIVER
        }
        self.stats.committed += 1;
        self.try_deliver(out);
    }

    fn on_confirm(&mut self, m: MsgId, g: Gid, out: &mut Outbox) {
        let Some(e) = self.entries.get_mut(&m) else { return };
        e.confirms.insert(g);
        self.try_finalize(m, out);
    }

    /// Leader-side ordered delivery. The frontier (`pending`) includes
    /// messages from *speculation* time — an in-flight assignment may
    /// still undercut a committed global timestamp (the convoy, §III).
    /// Followers are leader-driven: they deliver on `DELIVER` messages in
    /// FIFO order, which also gives them the projection of the total
    /// order (their own log-apply order could invert gts order when a
    /// speculative Commit lands in an earlier slot than a conflicting
    /// AssignLts).
    fn try_deliver(&mut self, out: &mut Outbox) {
        if !self.paxos.is_leader() {
            return;
        }
        loop {
            let Some(&(gts, m)) = self.committed.iter().next() else { break };
            if let Some(&(frontier, _)) = self.pending.iter().next() {
                if frontier <= gts {
                    break;
                }
            }
            self.committed.remove(&(gts, m));
            let e = self.entries.get_mut(&m).unwrap();
            e.delivered = true;
            let lts = e.lts;
            self.stats.delivered += 1;
            out.deliver(m, gts);
            out.send(Pid(m.client()), Wire::Delivered { m, g: self.gid, gts });
            let bal = self.paxos.ballot();
            let me = self.pid;
            out.send_to_many(
                self.topo.members(self.gid).iter().copied().filter(|&p| p != me),
                Wire::Deliver { m, bal, lts, gts, path: DeliveryPath::Unclassified },
            );
        }
    }

    /// Follower: deliver in the order the leader decided.
    fn on_deliver(&mut self, m: MsgId, gts: Ts, out: &mut Outbox) {
        if self.max_follower_gts >= gts {
            return; // duplicate
        }
        self.max_follower_gts = gts;
        if let Some(e) = self.entries.get_mut(&m) {
            e.delivered = true;
        }
        self.stats.delivered += 1;
        out.deliver(m, gts);
    }

    /// Leader: speculative commit — start consensus#2 as soon as all
    /// local timestamps are known, without waiting for consensus#1.
    fn try_speculative_commit(&mut self, m: MsgId, out: &mut Outbox) {
        if self.commit_submitted.contains(&m) {
            return;
        }
        let Some(props) = self.proposals.get(&m) else { return };
        let Some(e) = self.entries.get(&m) else { return };
        if !e.meta.dest.iter().all(|g| props.contains_key(&g)) {
            return;
        }
        let gts = e.meta.dest.iter().map(|g| props[&g]).max().unwrap();
        self.commit_submitted.insert(m);
        self.stats.consensus_instances += 1;
        self.stats.speculative_commits += 1;
        self.paxos.propose(RsmCmd::Commit { m, gts }, out);
    }
}

impl Node for FastCastNode {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, _now: u64, _out: &mut Outbox) {}

    fn on_wire(&mut self, from: Pid, wire: Wire, _now: u64, out: &mut Outbox) {
        match wire {
            Wire::Multicast { meta } => {
                if !self.is_leader() {
                    return;
                }
                debug_assert!(meta.dest.contains(self.gid), "genuineness: not a destination");
                if let Some(e) = self.entries.get(&meta.id) {
                    if e.delivered {
                        out.send(Pid(meta.id.client()), Wire::Delivered { m: meta.id, g: self.gid, gts: e.gts });
                    }
                    return;
                }
                if !self.submitted.insert(meta.id) {
                    return;
                }
                // speculatively issue the local timestamp from the
                // in-memory counter (unique; ≥ persisted clock)
                self.next_assign = self.next_assign.max(self.clock) + 1;
                let lts = Ts::new(self.next_assign, self.gid);
                let m = meta.id;
                {
                    // record meta + speculative timestamp so (a) the
                    // speculative commit can fire before consensus#1
                    // applies and (b) the delivery frontier covers
                    // in-flight assignments
                    let e = self.entry(&meta);
                    e.lts = lts;
                }
                self.pending.insert((lts, m));
                // start consensus#1 ...
                self.stats.consensus_instances += 1;
                self.paxos.propose(RsmCmd::AssignLts { meta: meta.clone(), lts }, out);
                // ... and send PROPOSE to the other leaders immediately
                for g in meta.dest.iter() {
                    if g != self.gid {
                        out.send(self.topo.initial_leader(g), Wire::Propose { m, g: self.gid, lts });
                    }
                }
                self.proposals.entry(m).or_default().insert(self.gid, lts);
                self.try_speculative_commit(m, out);
            }
            Wire::Propose { m, g, lts } => {
                if !self.is_leader() {
                    return;
                }
                // speculative: act on the unconfirmed remote timestamp
                self.proposals.entry(m).or_default().insert(g, lts);
                self.try_speculative_commit(m, out);
            }
            Wire::Confirm { m, g } => {
                if !self.is_leader() {
                    return;
                }
                self.on_confirm(m, g, out);
            }
            Wire::Deliver { m, gts, .. } => {
                if !self.is_leader() {
                    self.on_deliver(m, gts, out);
                }
            }
            Wire::Paxos { g, msg } => {
                debug_assert_eq!(g, self.gid);
                let mut decided = Vec::new(); // alloc-ok: rare Paxos decision batch
                self.paxos.on_msg(from, msg, out, &mut decided);
                for cmd in decided {
                    self.apply(cmd, out);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, _timer: TimerKind, _now: u64, _out: &mut Outbox) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, ClientCfg};
    use crate::invariants;
    use crate::sim::{CpuCost, SimConfig, World};
    use crate::types::Topology;

    const D: u64 = 1_000_000;

    fn world(k: usize, f: usize, n_clients: usize, dest_groups: usize, max_req: u32, seed: u64) -> World {
        let topo = Topology::new(k, f);
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(FastCastNode::new(p, topo.clone())));
            }
        }
        for c in 0..n_clients {
            let pid = Pid(topo.first_client_pid().0 + c as u32);
            let cfg = ClientCfg { dest_groups, max_requests: Some(max_req), ..Default::default() };
            nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, seed ^ (c as u64 + 1))));
        }
        World::new(
            topo,
            nodes,
            SimConfig {
                delay: Box::new(crate::sim::ConstDelay(D)),
                cpu: CpuCost::zero(),
                seed,
                record_full: true,
                coalesce: true,
                flush: crate::types::FlushPolicy::default(),
            },
        )
    }

    #[test]
    fn solo_message_commits_in_4_delta() {
        let mut w = world(2, 1, 1, 2, 1, 1);
        w.run_to_quiescence(100_000);
        invariants::assert_correct(&w.trace);
        // consensus#2 and the CONFIRM exchange overlap: commit at 4δ
        assert_eq!(w.trace.latencies, vec![4 * D, 4 * D]);
    }

    #[test]
    fn single_group_is_3_delta() {
        // no remote confirms needed; consensus#1 (2δ) then consensus#2
        // overlapped 1δ behind it
        let mut w = world(1, 1, 1, 1, 1, 2);
        w.run_to_quiescence(100_000);
        invariants::assert_correct(&w.trace);
        assert_eq!(w.trace.latencies, vec![3 * D]);
    }

    #[test]
    fn concurrent_messages_totally_ordered() {
        let mut w = world(3, 1, 4, 2, 30, 0xFC);
        w.run_to_quiescence(4_000_000);
        invariants::assert_correct(&w.trace);
        assert_eq!(w.trace.completions.len(), 120);
    }

    #[test]
    fn speculation_happens() {
        let mut w = world(2, 1, 2, 2, 10, 3);
        w.run_to_quiescence(1_000_000);
        invariants::assert_correct(&w.trace);
        let l0 = w.node_as::<FastCastNode>(Pid(0));
        assert!(l0.stats.speculative_commits > 0);
    }

    #[test]
    fn followers_converge() {
        let mut w = world(2, 1, 3, 2, 20, 5);
        w.run_to_quiescence(3_000_000);
        invariants::assert_correct(&w.trace);
        assert_eq!(w.trace.delivered_count, 60 * 6);
    }
}
