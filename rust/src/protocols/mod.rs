//! Protocol state machines.
//!
//! Every protocol (and the workload client) is an event-driven, pure,
//! deterministic [`Node`]: it consumes wire messages and timer firings and
//! emits [`Action`]s. No I/O happens inside a node — the same state machine
//! runs unchanged under the discrete-event simulator ([`crate::sim`]), the
//! in-process thread runtime and the TCP runtime ([`crate::net`],
//! [`crate::coordinator`]).
//!
//! * [`skeen`] — folklore Skeen's protocol among singleton reliable
//!   groups (paper Fig. 1); collision-free 2δ, failure-free 4δ.
//! * [`ftskeen`] — Skeen's state machine replicated per group with
//!   black-box Paxos (§IV "straightforward way"); 6δ / 12δ.
//! * [`fastcast`] — FastCast (Coelho et al., DSN'17), speculative
//!   black-box consensus; 4δ / 8δ.
//! * [`wbcast`] — **the paper's white-box protocol** (Fig. 4); 3δ / 5δ.

pub mod fastcast;
pub mod ftskeen;
pub mod skeen;
pub mod wbcast;

use crate::types::{MsgId, Pid, Ts, Wire};

/// Timer kinds a node may arm. Timers are never cancelled; handlers must
/// check state and ignore stale firings.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum TimerKind {
    /// Client: resend MULTICAST if no delivery notification yet (message
    /// recovery, §IV).
    ClientResend(MsgId),
    /// Client: closed-loop pacing / next request.
    ClientNext,
    /// Leader: re-examine a possibly stuck message (retry(m), Fig. 4
    /// line 32).
    Retry(MsgId),
    /// Leader: send heartbeats to group + followers check leader health.
    LssTick,
    /// Leader candidate: time out on acquiring a quorum of responses and
    /// restart recovery with a higher ballot.
    RecoveryTimeout(u32),
    /// Coordinator: flush the batched commit engine.
    BatchFlush,
}

/// Effects emitted by a node transition.
#[derive(Clone, Debug)]
pub enum Action {
    /// Send a wire message to another process (or to self).
    Send(Pid, Wire),
    /// Deliver application message `m` locally (the `deliver(m)` event of
    /// §II). `gts` is its final global timestamp.
    Deliver(MsgId, Ts),
    /// Arm a timer to fire after `after_ns`.
    Timer(TimerKind, u64),
}

/// An event-driven protocol participant.
pub trait Node: Send + std::any::Any {
    fn pid(&self) -> Pid;
    /// Called once at start-of-world; typically arms timers / kicks off
    /// client workload.
    fn on_start(&mut self, now: u64) -> Vec<Action>;
    /// Handle a wire message from `from`.
    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64) -> Vec<Action>;
    /// Handle a timer firing.
    fn on_timer(&mut self, timer: TimerKind, now: u64) -> Vec<Action>;
    /// Crash notification (used by some harness nodes for bookkeeping;
    /// crashed nodes simply stop receiving events).
    fn on_crash(&mut self, _now: u64) {}
}

/// Convenience: send one message to many recipients.
pub fn send_all<'a, I: IntoIterator<Item = &'a Pid>>(acts: &mut Vec<Action>, to: I, wire: Wire) {
    for &p in to {
        acts.push(Action::Send(p, wire.clone()));
    }
}
