//! Protocol state machines.
//!
//! Every protocol (and the workload client) is an event-driven, pure,
//! deterministic [`Node`]: it consumes wire messages and timer firings and
//! writes its effects — sends, local deliveries, timer arms — into a
//! runtime-owned [`Outbox`]. No I/O happens inside a node — the same state
//! machine runs unchanged under the discrete-event simulator
//! ([`crate::sim`]), the in-process thread runtime and the TCP runtime
//! ([`crate::net`], [`crate::coordinator`]).
//!
//! The [`Outbox`] buffers are reused across events (no per-event effect
//! allocation), and every runtime (inline loop, sharded flusher thread,
//! simulator) coalesces same-destination sends into
//! [`Wire::Batch`](crate::types::Wire::Batch) frames via the stateful
//! [`LinkCoalescer`] under a [`FlushPolicy`](crate::types::FlushPolicy)
//! — see [`outbox`] for the full design. ([`Coalescer`] is the stateless
//! per-cycle reference model the unit tests compare against.)
//!
//! * [`skeen`] — folklore Skeen's protocol among singleton reliable
//!   groups (paper Fig. 1); collision-free 2δ, failure-free 4δ.
//! * [`ftskeen`] — Skeen's state machine replicated per group with
//!   black-box Paxos (§IV "straightforward way"); 6δ / 12δ.
//! * [`fastcast`] — FastCast (Coelho et al., DSN'17), speculative
//!   black-box consensus; 4δ / 8δ.
//! * [`wbcast`] — **the paper's white-box protocol** (Fig. 4); 3δ / 5δ.

pub mod fastcast;
pub mod ftskeen;
pub mod outbox;
pub mod skeen;
pub mod wbcast;

pub use outbox::{Coalescer, DeliverEffect, LinkCoalescer, Outbox};

use crate::types::{MsgId, Pid, Wire};

/// Timer kinds a node may arm. Timers are never cancelled; handlers must
/// check state and ignore stale firings.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum TimerKind {
    /// Client: resend MULTICAST if no delivery notification yet (message
    /// recovery, §IV).
    ClientResend(MsgId),
    /// Client: closed-loop pacing / next request.
    ClientNext,
    /// Leader: re-examine a possibly stuck message (retry(m), Fig. 4
    /// line 32).
    Retry(MsgId),
    /// Leader: send heartbeats to group + followers check leader health.
    LssTick,
    /// Leader candidate: time out on acquiring a quorum of responses and
    /// restart recovery with a higher ballot.
    RecoveryTimeout(u32),
    /// Coordinator: flush the batched commit engine.
    BatchFlush,
}

/// An event-driven protocol participant. Handlers never perform I/O;
/// every effect goes through the runtime-owned [`Outbox`].
pub trait Node: Send + std::any::Any {
    fn pid(&self) -> Pid;
    /// Called once at start-of-world; typically arms timers / kicks off
    /// client workload.
    fn on_start(&mut self, now: u64, out: &mut Outbox);
    /// Handle a wire message from `from`. Runtimes unpack
    /// [`Wire::Batch`] frames, so nodes only ever see inner messages.
    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox);
    /// Handle a timer firing.
    fn on_timer(&mut self, timer: TimerKind, now: u64, out: &mut Outbox);
    /// Crash notification (used by some harness nodes for bookkeeping;
    /// crashed nodes simply stop receiving events).
    fn on_crash(&mut self, _now: u64) {}
}
