//! FT-Skeen: the "straightforward" fault-tolerant Skeen baseline (§IV).
//!
//! Each group simulates a reliable Skeen process with black-box
//! multi-Paxos. Both key actions — assigning the local timestamp (Fig. 1
//! line 10) and persisting the global timestamp / advancing the clock
//! (lines 14–15) — take a consensus round trip to *persist the effect of
//! the action* before the protocol proceeds: the local timestamp is
//! chosen eagerly from the leader's in-memory counter upon MULTICAST
//! (that is what "the effect of the action" means — the action itself is
//! immediate at the simulated reliable process), but the PROPOSE to the
//! other groups is only sent once consensus#1 has decided, and the
//! counter only advances past a global timestamp when the corresponding
//! consensus#2 (Commit) applies.
//!
//! Latency: MULTICAST δ → consensus#1 2δ → PROPOSE δ → consensus#2 2δ =
//! commit latency 6δ; the clock-update latency is also 6δ, so by
//! Theorems 3–4 the collision-free / failure-free latencies are 6δ / 12δ.

use crate::paxos::Paxos;
use crate::protocols::{Node, Outbox, TimerKind};
use crate::types::wire::RsmCmd;
use crate::types::{DeliveryPath, Gid, MsgId, MsgMeta, Phase, Pid, Topology, Ts, Wire};
use std::collections::{BTreeSet, HashMap, HashSet};

struct Entry {
    meta: MsgMeta,
    phase: Phase,
    lts: Ts,
    gts: Ts,
    delivered: bool,
}

/// Counters for stats / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct FtStats {
    pub committed: u64,
    pub delivered: u64,
    pub consensus_instances: u64,
}

/// One FT-Skeen replica.
pub struct FtSkeenNode {
    pid: Pid,
    gid: Gid,
    topo: Topology,
    paxos: Paxos,

    // ---- replicated Skeen state ----
    clock: u64,
    entries: HashMap<MsgId, Entry>,
    /// (lts, m) known but uncommitted — includes the leader's eager
    /// assignments (the delivery frontier must cover in-flight commands)
    pending: BTreeSet<(Ts, MsgId)>,
    /// (gts, m) committed, undelivered (leader delivery queue)
    committed: BTreeSet<(Ts, MsgId)>,

    // ---- leader coordination state ----
    /// eager local-timestamp counter; catches up with the persisted
    /// clock only at Commit-apply (clock-update latency 6δ)
    next_assign: u64,
    proposals: HashMap<MsgId, HashMap<Gid, Ts>>,
    submitted: HashSet<MsgId>,
    commit_submitted: HashSet<MsgId>,
    /// follower: highest gts delivered on the leader's order
    max_follower_gts: Ts,

    pub stats: FtStats,
}

impl FtSkeenNode {
    pub fn new(pid: Pid, topo: Topology) -> Self {
        let gid = topo.group_of(pid).expect("FtSkeenNode must be a group member");
        FtSkeenNode {
            pid,
            gid,
            paxos: Paxos::new(pid, &topo, gid),
            topo,
            clock: 0,
            entries: HashMap::new(),
            pending: BTreeSet::new(),
            committed: BTreeSet::new(),
            next_assign: 0,
            proposals: HashMap::new(),
            submitted: HashSet::new(),
            commit_submitted: HashSet::new(),
            max_follower_gts: Ts::BOT,
            stats: FtStats::default(),
        }
    }

    pub fn is_leader(&self) -> bool {
        self.paxos.is_leader()
    }
    pub fn clock(&self) -> u64 {
        self.clock
    }
    pub fn phase_of(&self, m: MsgId) -> Phase {
        self.entries.get(&m).map(|e| e.phase).unwrap_or(Phase::Start)
    }

    fn apply(&mut self, cmd: RsmCmd, out: &mut Outbox) {
        match cmd {
            // consensus#1 decided: the local timestamp is durable; the
            // leader may now reveal it to the other destination groups
            // (Fig. 1 line 12 after the persistence round trip)
            RsmCmd::AssignLts { meta, lts } => {
                let m = meta.id;
                let is_leader = self.is_leader();
                let e = self.entries.entry(m).or_insert_with(|| Entry {
                    meta: meta.clone(),
                    phase: Phase::Start,
                    lts: Ts::BOT,
                    gts: Ts::BOT,
                    delivered: false,
                });
                if e.phase != Phase::Start {
                    return; // duplicate decision (client retry)
                }
                e.phase = Phase::Proposed;
                e.lts = lts;
                self.pending.insert((lts, m)); // idempotent at the leader
                self.clock = self.clock.max(lts.time());
                if is_leader {
                    for g in meta.dest.iter() {
                        out.send(self.topo.initial_leader(g), Wire::Propose { m, g: self.gid, lts });
                    }
                }
            }
            // consensus#2 decided: global timestamp + clock advance are
            // durable (Fig. 1 lines 14-15 after the round trip)
            RsmCmd::Commit { m, gts } => {
                let is_leader = self.is_leader();
                let Some(e) = self.entries.get_mut(&m) else { return };
                if e.phase == Phase::Committed {
                    return;
                }
                let lts = e.lts;
                e.phase = Phase::Committed;
                e.gts = gts;
                self.clock = self.clock.max(gts.time());
                // in-memory assignment counter passes gts only now —
                // this is FT-Skeen's 6δ clock-update latency
                self.next_assign = self.next_assign.max(self.clock);
                self.pending.remove(&(lts, m));
                if is_leader {
                    self.committed.insert((gts, m));
                }
                self.stats.committed += 1;
                self.try_deliver(out);
            }
        }
    }

    /// Fig. 1 line 17 at the leader; followers deliver on the leader's
    /// DELIVER messages (first-delivery semantics match the paper's
    /// latency metric).
    fn try_deliver(&mut self, out: &mut Outbox) {
        if !self.paxos.is_leader() {
            return;
        }
        loop {
            let Some(&(gts, m)) = self.committed.iter().next() else { break };
            if let Some(&(frontier, _)) = self.pending.iter().next() {
                if frontier <= gts {
                    break;
                }
            }
            self.committed.remove(&(gts, m));
            let e = self.entries.get_mut(&m).unwrap();
            e.delivered = true;
            let lts = e.lts;
            self.stats.delivered += 1;
            out.deliver(m, gts);
            out.send(Pid(m.client()), Wire::Delivered { m, g: self.gid, gts });
            let bal = self.paxos.ballot();
            let me = self.pid;
            out.send_to_many(
                self.topo.members(self.gid).iter().copied().filter(|&p| p != me),
                Wire::Deliver { m, bal, lts, gts, path: DeliveryPath::Unclassified },
            );
        }
    }

    fn on_deliver(&mut self, m: MsgId, gts: Ts, out: &mut Outbox) {
        if self.max_follower_gts >= gts {
            return;
        }
        self.max_follower_gts = gts;
        if let Some(e) = self.entries.get_mut(&m) {
            e.delivered = true;
        }
        self.stats.delivered += 1;
        out.deliver(m, gts);
    }

    /// Once local timestamps from every destination group are known and
    /// our own is durable, submit the Commit command.
    fn try_commit(&mut self, m: MsgId, out: &mut Outbox) {
        if self.commit_submitted.contains(&m) {
            return;
        }
        let Some(e) = self.entries.get(&m) else { return };
        if e.phase != Phase::Proposed {
            return; // consensus#1 not yet decided
        }
        let Some(props) = self.proposals.get(&m) else { return };
        if !e.meta.dest.iter().all(|g| props.contains_key(&g)) {
            return;
        }
        let gts = e.meta.dest.iter().map(|g| props[&g]).max().unwrap();
        self.commit_submitted.insert(m);
        self.stats.consensus_instances += 1;
        self.paxos.propose(RsmCmd::Commit { m, gts }, out);
    }
}

impl Node for FtSkeenNode {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, _now: u64, _out: &mut Outbox) {}

    fn on_wire(&mut self, from: Pid, wire: Wire, _now: u64, out: &mut Outbox) {
        match wire {
            Wire::Multicast { meta } => {
                if !self.is_leader() {
                    return;
                }
                debug_assert!(meta.dest.contains(self.gid), "genuineness: not a destination");
                if let Some(e) = self.entries.get(&meta.id) {
                    if e.delivered {
                        out.send(Pid(meta.id.client()), Wire::Delivered { m: meta.id, g: self.gid, gts: e.gts });
                    }
                    return;
                }
                if !self.submitted.insert(meta.id) {
                    return;
                }
                // Fig. 1 lines 9-10 at the simulated reliable process:
                // eager, unique local timestamp; effect persisted by
                // consensus#1 before it is revealed
                self.next_assign = self.next_assign.max(self.clock) + 1;
                let lts = Ts::new(self.next_assign, self.gid);
                let m = meta.id;
                // frontier covers the in-flight assignment immediately
                self.entries.insert(
                    m,
                    Entry { meta: meta.clone(), phase: Phase::Start, lts, gts: Ts::BOT, delivered: false },
                );
                self.pending.insert((lts, m));
                self.stats.consensus_instances += 1;
                self.paxos.propose(RsmCmd::AssignLts { meta, lts }, out);
            }
            Wire::Propose { m, g, lts } => {
                if !self.is_leader() {
                    return;
                }
                self.proposals.entry(m).or_default().insert(g, lts);
                self.try_commit(m, out);
            }
            Wire::Deliver { m, gts, .. } => {
                if !self.is_leader() {
                    self.on_deliver(m, gts, out);
                }
            }
            Wire::Paxos { g, msg } => {
                debug_assert_eq!(g, self.gid);
                let mut decided = Vec::new(); // alloc-ok: rare Paxos decision batch
                self.paxos.on_msg(from, msg, out, &mut decided);
                for cmd in decided {
                    if let RsmCmd::AssignLts { meta, .. } = &cmd {
                        let m = meta.id;
                        self.apply(cmd.clone(), out);
                        if self.is_leader() {
                            if let Some(e) = self.entries.get(&m) {
                                let lts = e.lts;
                                self.proposals.entry(m).or_default().insert(self.gid, lts);
                            }
                            self.try_commit(m, out);
                        }
                        continue;
                    }
                    self.apply(cmd, out);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, _timer: TimerKind, _now: u64, _out: &mut Outbox) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, ClientCfg};
    use crate::invariants;
    use crate::sim::{CpuCost, SimConfig, World};
    use crate::types::Topology;

    const D: u64 = 1_000_000;

    fn world(k: usize, f: usize, n_clients: usize, dest_groups: usize, max_req: u32, seed: u64) -> World {
        let topo = Topology::new(k, f);
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(FtSkeenNode::new(p, topo.clone())));
            }
        }
        for c in 0..n_clients {
            let pid = Pid(topo.first_client_pid().0 + c as u32);
            let cfg = ClientCfg { dest_groups, max_requests: Some(max_req), ..Default::default() };
            nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, seed ^ (c as u64 + 1))));
        }
        World::new(
            topo,
            nodes,
            SimConfig {
                delay: Box::new(crate::sim::ConstDelay(D)),
                cpu: CpuCost::zero(),
                seed,
                record_full: true,
                coalesce: true,
                flush: crate::types::FlushPolicy::default(),
            },
        )
    }

    #[test]
    fn solo_message_commits_in_6_delta() {
        let mut w = world(2, 1, 1, 2, 1, 1);
        w.run_to_quiescence(100_000);
        invariants::assert_correct(&w.trace);
        // MULTICAST + consensus#1 + PROPOSE + consensus#2 = 6δ
        assert_eq!(w.trace.latencies, vec![6 * D, 6 * D]);
    }

    #[test]
    fn single_group_still_pays_two_consensus_rounds() {
        let mut w = world(1, 1, 1, 1, 1, 2);
        w.run_to_quiescence(100_000);
        invariants::assert_correct(&w.trace);
        // PROPOSE to self is free (self-send): 5δ for a single group
        assert_eq!(w.trace.latencies, vec![5 * D]);
    }

    #[test]
    fn concurrent_messages_totally_ordered() {
        let mut w = world(3, 1, 4, 2, 30, 0xF7);
        w.run_to_quiescence(3_000_000);
        invariants::assert_correct(&w.trace);
        assert_eq!(w.trace.completions.len(), 120);
    }

    #[test]
    fn followers_deliver_same_order_as_leader() {
        let mut w = world(2, 1, 3, 2, 20, 5);
        w.run_to_quiescence(2_000_000);
        invariants::assert_correct(&w.trace);
        // every member of both groups delivered all 60 messages
        assert_eq!(w.trace.delivered_count, 60 * 6);
    }

    #[test]
    fn clock_advances_past_gts() {
        let mut w = world(2, 1, 1, 2, 3, 9);
        w.run_to_quiescence(100_000);
        for p in [Pid(0), Pid(3)] {
            let n = w.node_as::<FtSkeenNode>(p);
            assert!(n.clock() >= 3);
        }
    }
}
