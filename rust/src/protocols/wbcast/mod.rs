//! **WbCast — the paper's white-box atomic multicast protocol (Fig. 4).**
//!
//! Each group of `2f + 1` processes has a leader and followers (passive
//! replication). To multicast `m`, the leaders of `dest(m)` assign local
//! timestamps and replicate them — together with the speculative clock
//! advance — in a *single* Paxos-like round trip between all destination
//! leaders and quorums of followers in all destination groups
//! (`ACCEPT` / `ACCEPT_ACK`). Global timestamps are replicated off the
//! critical path in `DELIVER` messages. Collision-free latency 3δ
//! (MULTICAST, ACCEPT, ACCEPT_ACK), failure-free 5δ; followers deliver
//! one δ later.
//!
//! Leader recovery (`NEWLEADER` / `NEW_STATE`, Fig. 4 lines 35–66) lives
//! in [`recovery`]; it recovers *all* messages at once, Zab/VR-style,
//! because each delivery decision only makes sense in the context of the
//! leader's previous decisions.

pub mod recovery;

use crate::protocols::{DeliverEffect, Node, Outbox, TimerKind};
use crate::types::{Ballot, DeliveryPath, Gid, MsgId, MsgMeta, Phase, Pid, Status, Topology, Ts, Wire};
use crate::util::{FxHashMap, FxHashSet};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

/// Tunables for liveness plumbing (timers); zero values disable a timer.
#[derive(Clone, Copy, Debug)]
pub struct WbConfig {
    /// leader heartbeat period; follower suspicion timeout is
    /// `hb_interval * hb_suspect_mult * (1 + rank)` — ranks stagger
    /// candidates so that a single stable leader emerges (Invariant 6)
    pub hb_interval: u64,
    pub hb_suspect_mult: u64,
    /// leader retry timer for stuck PROPOSED/ACCEPTED messages
    pub retry_after: u64,
    /// recovery restart timeout (candidate stuck without quorum)
    pub recovery_timeout: u64,
    /// garbage-collect delivered entries below the group-wide watermark
    pub gc: bool,
    /// commit-batch size: quorum-complete messages are staged and
    /// committed through the batch backend once this many accumulate
    /// (1 = commit immediately; >1 enables the XLA batch engine path).
    /// This is the *commit-side* coalescing knob; its wire-side
    /// companion is destination-coalesced batching in the runtimes
    /// ([`crate::sim::SimConfig::coalesce`], always-on in the
    /// coordinator): a flush of `k` staged commits emits `k` `DELIVER`s
    /// per follower, which the outbox flush folds into a single
    /// [`Wire::Batch`](crate::types::Wire::Batch) frame per follower.
    pub batch_threshold: usize,
    /// flush a non-empty stage after this long even if below threshold
    pub batch_flush_after: u64,
    /// journal ballot promises, acknowledged accepts, commits and
    /// deliveries into the runtime-attached [`crate::storage`] WAL
    /// *before* they are externally acknowledged, so a killed process
    /// can restore from disk ([`WbNode::restore`]) and rejoin through
    /// the recovery path. Off by default: the hot path then emits no
    /// records at all (a single branch per journal point).
    pub durability: bool,
}

impl Default for WbConfig {
    fn default() -> Self {
        WbConfig {
            hb_interval: 0, // disabled: failure-free benches
            hb_suspect_mult: 8,
            retry_after: 0,
            recovery_timeout: 0,
            gc: false,
            batch_threshold: 1,
            batch_flush_after: 0,
            durability: false,
        }
    }
}

impl WbConfig {
    /// Timers sized for a given network δ (used when crashes may occur).
    pub fn with_failures(delta: u64) -> Self {
        WbConfig {
            hb_interval: 2 * delta,
            hb_suspect_mult: 8,
            retry_after: 20 * delta,
            recovery_timeout: 40 * delta,
            gc: true,
            batch_threshold: 1,
            batch_flush_after: 0,
            durability: false,
        }
    }
}

/// Per-message state at a process.
pub(crate) struct Entry {
    pub meta: MsgMeta,
    pub phase: Phase,
    pub lts: Ts,
    pub gts: Ts,
    pub delivered: bool,
    /// staged in the commit-batch buffer (quorum complete, not yet flushed)
    pub staged: bool,
    /// ACCEPT messages received, per destination group: (ballot, lts)
    pub accepts: FxHashMap<Gid, (Ballot, Ts)>,
    /// leader: ACCEPT_ACK tally keyed by the ballot vector
    pub acks: FxHashMap<Vec<(Gid, Ballot)>, FxHashMap<Gid, FxHashSet<Pid>>>,
    /// node-local instant of the fresh proposal (0 = not proposed here)
    pub proposal_at: u64,
    /// node-local instant the ack quorum completed (0 = not yet)
    pub quorum_at: u64,
    /// node-local instant the commit applied (0 = not yet)
    pub commit_at: u64,
    /// state arrived through recovery (restore / NEW_STATE adoption):
    /// the delivery classifies as [`DeliveryPath::Recovery`]
    pub recovered: bool,
}

impl Entry {
    fn new(meta: MsgMeta) -> Self {
        Entry {
            meta,
            phase: Phase::Start,
            lts: Ts::BOT,
            gts: Ts::BOT,
            delivered: false,
            staged: false,
            accepts: Default::default(),
            acks: Default::default(),
            proposal_at: 0,
            quorum_at: 0,
            commit_at: 0,
            recovered: false,
        }
    }
}

/// Counters exposed for stats / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct WbStats {
    pub committed: u64,
    pub delivered: u64,
    /// deliveries that took the collision-free 3δ path
    pub delivered_fast: u64,
    /// deliveries held back by a concurrent message (5δ path)
    pub delivered_concurrent: u64,
    /// deliveries resolved through recovery (restore / NEW_STATE / resend)
    pub delivered_recovery: u64,
    pub recoveries_started: u64,
    pub recoveries_completed: u64,
    pub retries: u64,
    pub gc_dropped: u64,
}

impl WbStats {
    /// Tally one delivery under its white-box path.
    fn note_path(&mut self, path: DeliveryPath) {
        match path {
            DeliveryPath::Fast => self.delivered_fast += 1,
            DeliveryPath::Concurrent => self.delivered_concurrent += 1,
            DeliveryPath::Recovery => self.delivered_recovery += 1,
            DeliveryPath::Unclassified => {}
        }
    }
}

/// One WbCast process (Fig. 3 variables + plumbing).
pub struct WbNode {
    pub(crate) pid: Pid,
    pub(crate) gid: Gid,
    pub(crate) topo: Topology,
    pub(crate) cfg: WbConfig,

    // --- Fig. 3 state ---
    pub(crate) clock: u64,
    pub(crate) status: Status,
    pub(crate) cballot: Ballot,
    pub(crate) ballot: Ballot,
    pub(crate) entries: FxHashMap<MsgId, Entry>,
    pub(crate) cur_leader: Vec<Pid>,
    pub(crate) max_delivered_gts: Ts,

    // --- derived indices (performance; see EXPERIMENTS.md §Perf) ---
    /// (lts, m) of messages in PROPOSED/ACCEPTED — the delivery frontier
    pub(crate) pending: BTreeSet<(Ts, MsgId)>,
    /// (gts, m) committed and not yet delivered
    pub(crate) committed: BTreeSet<(Ts, MsgId)>,
    /// (gts -> m) delivered, for post-recovery DELIVER resends
    pub(crate) delivered_log: BTreeMap<Ts, MsgId>,

    // --- recovery bookkeeping (see recovery.rs) ---
    // BTreeMap, not HashMap: the merge in `on_new_leader_ack` folds the
    // reporters' states in iteration order, and the adopted state reaches
    // the wire (NEW_STATE) — reporter order must be deterministic
    pub(crate) nl_acks: BTreeMap<Pid, recovery::NlAck>,
    pub(crate) ns_acks: HashSet<Pid>,

    // --- batched commit engine (L2/L1 integration; see crate::runtime::engine) ---
    pub(crate) backend: Box<dyn crate::runtime::CommitBackend>,
    pub(crate) ready: Vec<crate::runtime::BatchReq>,

    // --- liveness plumbing ---
    pub(crate) last_hb: u64,
    /// per-follower max delivered gts (leader, for the GC watermark)
    pub(crate) gc_reports: HashMap<Pid, Ts>,
    /// per-client delivered-sequence watermark (duplicate detection after GC)
    pub(crate) gc_client_seq: HashMap<u32, u32>,

    /// virtual time at which this node last completed recovery and
    /// became leader (0 = initial leader / never)
    pub leader_since: u64,

    /// restored from disk ([`WbNode::restore`]): `on_start` immediately
    /// runs the recovery protocol to rejoin the group — the process may
    /// have missed arbitrary traffic while down, and only a NEW_STATE
    /// round resynchronises it (and fills its delivery gaps) safely
    pub(crate) rejoin: bool,

    pub stats: WbStats,
}

impl WbNode {
    pub fn new(pid: Pid, topo: Topology, cfg: WbConfig) -> Self {
        Self::with_backend(pid, topo, cfg, Box::new(crate::runtime::NativeBackend))
    }

    /// Construct with an explicit commit backend (e.g. the XLA engine
    /// service handle; see [`crate::runtime::service`]).
    pub fn with_backend(
        pid: Pid,
        topo: Topology,
        cfg: WbConfig,
        backend: Box<dyn crate::runtime::CommitBackend>,
    ) -> Self {
        let gid = topo.group_of(pid).expect("WbNode must be a group member");
        let is_initial_leader = topo.initial_leader(gid) == pid;
        // Ballot (1, initial leader) is pre-agreed at deployment time:
        // every member starts with cballot = ballot = (1, leader(g)).
        let b0 = Ballot::new(1, topo.initial_leader(gid));
        let cur_leader = topo.gids().map(|g| topo.initial_leader(g)).collect();
        WbNode {
            pid,
            gid,
            topo,
            cfg,
            clock: 0,
            status: if is_initial_leader { Status::Leader } else { Status::Follower },
            cballot: b0,
            ballot: b0,
            entries: Default::default(),
            cur_leader,
            max_delivered_gts: Ts::BOT,
            pending: BTreeSet::new(),
            committed: BTreeSet::new(),
            delivered_log: BTreeMap::new(),
            nl_acks: BTreeMap::new(),
            ns_acks: HashSet::new(),
            backend,
            ready: Vec::new(), // alloc-ok: constructor
            last_hb: 0,
            gc_reports: HashMap::new(),
            gc_client_seq: HashMap::new(),
            leader_since: 0,
            rejoin: false,
            stats: WbStats::default(),
        }
    }

    /// Rebuild a node from its durable [`crate::storage::Snapshot`]
    /// (WAL + snapshot replay, see [`crate::storage::Storage::image`]).
    /// The node comes back as a FOLLOWER regardless of its pre-crash
    /// status and, on start, rejoins through the existing recovery path
    /// (Fig. 4 lines 35–66): a fresh candidacy resynchronises it with a
    /// quorum and re-delivers everything it missed while down —
    /// `max_delivered_gts` (journaled per delivery) deduplicates, so
    /// nothing is delivered twice.
    pub fn restore(pid: Pid, topo: Topology, cfg: WbConfig, snap: &crate::storage::Snapshot) -> Self {
        Self::restore_with_backend(pid, topo, cfg, snap, Box::new(crate::runtime::NativeBackend))
    }

    /// [`WbNode::restore`] with an explicit commit backend.
    pub fn restore_with_backend(
        pid: Pid,
        topo: Topology,
        cfg: WbConfig,
        snap: &crate::storage::Snapshot,
        backend: Box<dyn crate::runtime::CommitBackend>,
    ) -> Self {
        let mut n = Self::with_backend(pid, topo, cfg, backend);
        if snap.is_blank() {
            return n; // nothing was ever journaled: a genuinely fresh node
        }
        n.status = Status::Follower;
        n.rejoin = true;
        n.ballot = n.ballot.max(snap.ballot);
        n.cballot = n.cballot.max(snap.cballot);
        n.clock = n.clock.max(snap.clock);
        n.max_delivered_gts = snap.max_delivered_gts;
        n.cur_leader[n.gid.0 as usize] = n.cballot.leader();
        n.delivered_log = snap.delivered.iter().map(|(&g, &m)| (g, m)).collect();
        n.gc_client_seq = snap.client_seq.iter().map(|(&c, &s)| (c, s)).collect();
        let delivered: HashSet<MsgId> = snap.delivered.values().copied().collect();
        for (&m, s) in &snap.state {
            let mut e = Entry::new(s.meta.clone());
            e.phase = s.phase;
            e.lts = s.lts;
            e.gts = s.gts;
            e.recovered = true;
            match s.phase {
                Phase::Accepted => {
                    n.pending.insert((s.lts, m));
                }
                Phase::Committed => {
                    e.delivered = delivered.contains(&m);
                    if !e.delivered {
                        n.committed.insert((s.gts, m));
                    }
                }
                _ => {}
            }
            // `accepts` (remote leaders' proposals) is deliberately not
            // journaled: it is re-learned from ACCEPT resends, and the
            // rejoin recovery round supersedes our own group's proposal
            n.entries.insert(m, e);
        }
        n
    }

    /// Journal `m`'s current replicated state (durability on only);
    /// drained by the runtime ahead of this cycle's sends.
    fn journal_state(&self, m: MsgId, out: &mut Outbox) {
        if !self.cfg.durability {
            return;
        }
        if let Some(e) = self.entries.get(&m) {
            out.record(crate::storage::Record::State {
                state: crate::types::wire::MsgState {
                    meta: e.meta.clone(),
                    phase: e.phase,
                    lts: e.lts,
                    gts: e.gts,
                },
                clock: self.clock,
            });
        }
    }

    /// Diagnostic dump (probe binaries / debugging).
    // printing is this function's contract; everything else in the
    // library reports through `log` or returned stats
    #[allow(clippy::print_stdout)]
    pub fn debug_dump(&self, tag: &str) {
        println!(
            "{tag}: status={:?} cballot={:?} clock={} entries={} pending={} committed={} ready={} max_dgts={:?}",
            self.status, self.cballot, self.clock, self.entries.len(), self.pending.len(),
            self.committed.len(), self.ready.len(), self.max_delivered_gts
        );
        for (i, &(lts, m)) in self.pending.iter().take(3).enumerate() {
            if let Some(e) = self.entries.get(&m) {
                let acc: Vec<String> = e.meta.dest.iter().map(|g| match e.accepts.get(&g) {
                    Some(&(b, t)) => format!("{g:?}:{b:?}@{t:?}"),
                    None => format!("{g:?}:∅"),
                }).collect();
                println!("  pending[{i}] {m:?} lts={lts:?} phase={:?} staged={} dest={:?} accepts=[{}] acks={}",
                    e.phase, e.staged, e.meta.dest, acc.join(" "), e.acks.len());
            }
        }
        if let Some(&(gts, m)) = self.committed.iter().next() {
            println!("  committed.first {m:?} gts={gts:?}");
        }
    }

    // ---------- inspection (tests, harness) ----------
    pub fn status(&self) -> Status {
        self.status
    }
    pub fn cballot(&self) -> Ballot {
        self.cballot
    }
    pub fn clock(&self) -> u64 {
        self.clock
    }
    pub fn phase_of(&self, m: MsgId) -> Phase {
        self.entries.get(&m).map(|e| e.phase).unwrap_or(Phase::Start)
    }
    pub fn gts_of(&self, m: MsgId) -> Option<Ts> {
        self.entries.get(&m).filter(|e| e.phase == Phase::Committed).map(|e| e.gts)
    }
    pub fn is_leader(&self) -> bool {
        self.status == Status::Leader
    }
    /// All committed messages with their global timestamps (probes).
    pub fn committed_view(&self) -> Vec<(MsgId, Ts)> {
        self.entries
            .iter()
            .filter(|(_, e)| e.phase == Phase::Committed)
            .map(|(&m, e)| (m, e.gts))
            .collect()
    }
    /// The local timestamp this process holds for `m`, if any (probes).
    pub fn lts_view(&self, m: MsgId) -> Option<Ts> {
        self.entries.get(&m).filter(|e| e.phase != Phase::Start && !e.lts.is_bot()).map(|e| e.lts)
    }
    pub(crate) fn rank(&self) -> u64 {
        self.topo.members(self.gid).iter().position(|&p| p == self.pid).unwrap() as u64
    }
    pub(crate) fn group(&self) -> &[Pid] {
        self.topo.members(self.gid)
    }
    pub(crate) fn quorum(&self) -> usize {
        self.topo.quorum()
    }

    /// `m` was delivered and garbage-collected: clients multicast
    /// sequentially (closed loop), so a sequence number strictly below the
    /// client's delivered watermark implies `m` completed at *every*
    /// destination group. The entry with `seq == watermark` is always
    /// retained (see [`WbNode::trim_below`]), so anything below the
    /// watermark is safe to drop and ignore.
    pub(crate) fn below_gc_watermark(&self, m: MsgId) -> bool {
        self.gc_client_seq.get(&m.client()).is_some_and(|&wm| m.seq() < wm)
    }

    /// Sorted ballot vector for the current accept set of `m`.
    fn ballot_vector(e: &Entry) -> Vec<(Gid, Ballot)> {
        // unordered-ok: sorted by gid below
        let mut v: Vec<(Gid, Ballot)> = e.accepts.iter().map(|(&g, &(b, _))| (g, b)).collect();
        v.sort_unstable_by_key(|&(g, _)| g);
        v
    }

    // ---------- Fig. 4 line 3: MULTICAST at the leader ----------
    pub(crate) fn on_multicast(&mut self, meta: MsgMeta, now: u64, out: &mut Outbox) {
        let mid = meta.id;
        if self.status != Status::Leader {
            return; // pre: status = LEADER
        }
        debug_assert!(meta.dest.contains(self.gid), "genuineness: not a destination");
        // GC'd duplicate: strictly below the client watermark the message
        // was delivered everywhere (clients are sequential); never
        // re-propose — that would mint a second global timestamp.
        if self.below_gc_watermark(meta.id) {
            out.send(Pid(meta.id.client()), Wire::Delivered { m: meta.id, g: self.gid, gts: Ts::BOT });
            return;
        }
        let e = self.entries.entry(meta.id).or_insert_with(|| Entry::new(meta.clone()));
        if e.meta.dest.is_empty() {
            e.meta = meta; // entry pre-created by a remote ACCEPT
        }
        let fresh = e.phase == Phase::Start;
        if fresh {
            // lines 5-8: fresh proposal
            self.clock += 1;
            let lts = Ts::new(self.clock, self.gid);
            e.phase = Phase::Proposed;
            e.lts = lts;
            e.proposal_at = now;
            self.pending.insert((lts, e.meta.id));
        } else if e.delivered {
            // duplicate of a delivered message: re-notify the client (its
            // notification may have been lost to a crash) — and still
            // resend the ACCEPT below, so other destination groups stuck
            // on m can finish (§IV message recovery: "groups that have
            // already processed m will just resend the corresponding
            // protocol messages")
            out.send(Pid(e.meta.id.client()), Wire::Delivered { m: e.meta.id, g: self.gid, gts: e.gts });
        }
        // (re)send ACCEPT with the locally stored data (Invariant 1: one
        // local timestamp per ballot). The Arc'd payload makes the
        // per-member wire clones shallow.
        let dest = e.meta.dest;
        let wire = Wire::Accept { meta: e.meta.clone(), g: self.gid, bal: self.cballot, lts: e.lts };
        for g in dest.iter() {
            out.send_to_many(self.topo.members(g).iter().copied(), wire.clone());
        }
        // arm the retry chain only on the first proposal: on_retry re-arms
        // itself, so one chain per message suffices (duplicates arming
        // more would multiply timers)
        if fresh && self.cfg.retry_after > 0 {
            out.timer(TimerKind::Retry(mid), self.cfg.retry_after);
        }
    }

    // ---------- Fig. 4 line 10: ACCEPT at a destination process ----------
    pub(crate) fn on_accept(&mut self, meta: MsgMeta, g: Gid, bal: Ballot, lts: Ts, _now: u64, out: &mut Outbox) {
        let mid = meta.id;
        if self.status == Status::Recovering {
            return; // pre: status ∈ {FOLLOWER, LEADER}
        }
        // learn the remote leader for retries
        if (g.0 as usize) < self.cur_leader.len() && g != self.gid {
            self.cur_leader[g.0 as usize] = bal.leader();
        }
        if self.below_gc_watermark(meta.id) {
            return; // stale ACCEPT for a collected message
        }
        let e = self.entries.entry(meta.id).or_insert_with(|| Entry::new(meta.clone()));
        if e.meta.dest.is_empty() {
            e.meta = meta;
        }
        // store the latest proposal from this group (a re-proposal after a
        // remote leader change replaces the stale one)
        e.accepts.insert(g, (bal, lts));
        self.try_accept_ack(mid, out);
    }

    /// Fire line 10's body once ACCEPTs from all destination leaders are
    /// present and our own group's ballot matches `cballot`. Re-checked
    /// whenever `cballot` changes (recovery completion).
    pub(crate) fn try_accept_ack(&mut self, m: MsgId, out: &mut Outbox) {
        let Some(e) = self.entries.get_mut(&m) else { return };
        if e.meta.dest.is_empty() {
            return;
        }
        if !e.meta.dest.iter().all(|g| e.accepts.contains_key(&g)) {
            return;
        }
        let Some(&(own_bal, own_lts)) = e.accepts.get(&self.gid) else { return };
        if own_bal != self.cballot {
            return; // pre: cballot = Bal(g0)
        }
        // lines 12-13: adopt the local timestamp (first time only)
        if e.phase <= Phase::Proposed {
            if e.phase == Phase::Proposed {
                self.pending.remove(&(e.lts, m));
            }
            e.phase = Phase::Accepted;
            e.lts = own_lts;
            self.pending.insert((own_lts, m));
        }
        // line 14: speculative clock advance to the would-be global ts
        let gts = e.accepts.values().map(|&(_, l)| l).max().unwrap(); // unordered-ok: max() fold
        self.clock = self.clock.max(gts.time());
        // line 16: acknowledge to every proposing leader (the ballot
        // vector ends up owned by the wire, so recipients are staged).
        // The acknowledged (lts, phase) pair is journaled first: the
        // runtime commits it before the ACK can leave, so a restarted
        // process still reports it in NEWLEADER_ACK (Invariant 2).
        let bals = Self::ballot_vector(e);
        self.journal_state(m, out);
        for &(_, b) in &bals {
            out.stage(b.leader());
        }
        out.send_staged(Wire::AcceptAck { m, g: self.gid, bals });
    }

    // ---------- Fig. 4 line 17: ACCEPT_ACK at the leader ----------
    pub(crate) fn on_accept_ack(
        &mut self,
        m: MsgId,
        g: Gid,
        bals: Vec<(Gid, Ballot)>,
        from: Pid,
        now: u64,
        out: &mut Outbox,
    ) {
        if self.status != Status::Leader {
            return;
        }
        let quorum = self.quorum();
        let Some(e) = self.entries.get_mut(&m) else { return };
        if e.phase == Phase::Committed {
            return;
        }
        // avoid cloning the ballot-vector key when the tally row exists
        // (every ack after the first; §Perf iteration 3)
        if !e.acks.contains_key(&bals) {
            e.acks.insert(bals.clone(), Default::default());
        }
        e.acks.get_mut(&bals).unwrap().entry(g).or_default().insert(from);
        // pre: quorum in each destination group with matching ballot
        // vectors, including myself, and matching previously received
        // ACCEPTs (our accept set must equal the ack vector)
        let tally = &e.acks[&bals];
        let have_quorums = e.meta.dest.iter().all(|g| tally.get(&g).map(|s| s.len()).unwrap_or(0) >= quorum);
        if !have_quorums {
            return;
        }
        let own_ok = bals.iter().any(|&(g, b)| g == self.gid && b == self.cballot);
        if !own_ok {
            return; // stale vector from a previous leadership
        }
        let accepts_match = bals.len() == e.meta.dest.len()
            && bals.iter().all(|&(g, b)| e.accepts.get(&g).map(|&(ab, _)| ab == b).unwrap_or(false));
        if !accepts_match {
            return;
        }
        if e.staged {
            return; // already in the commit batch
        }
        // lines 19-20: stage the commit; the global timestamp is resolved
        // by the batch backend (native or the AOT XLA engine). The entry
        // stays in `pending` until the flush applies, so the delivery
        // frontier remains exact.
        e.staged = true;
        e.quorum_at = now;
        let lts_set: Vec<Ts> = bals.iter().map(|&(g, _)| e.accepts[&g].1).collect();
        self.ready.push(crate::runtime::BatchReq { m, lts: lts_set });
        if self.ready.len() >= self.cfg.batch_threshold {
            self.flush_commits(now, out);
        } else if self.cfg.batch_flush_after > 0 && self.ready.len() == 1 {
            out.timer(TimerKind::BatchFlush, self.cfg.batch_flush_after);
        }
    }

    /// Resolve global timestamps for the staged batch through the commit
    /// backend, apply the commits, and deliver whatever is unblocked.
    pub(crate) fn flush_commits(&mut self, now: u64, out: &mut Outbox) {
        if self.ready.is_empty() {
            return;
        }
        let reqs = std::mem::take(&mut self.ready);
        // remove the batch from the frontier first: its members must not
        // block themselves
        for r in &reqs {
            if let Some(e) = self.entries.get(&r.m) {
                self.pending.remove(&(e.lts, r.m));
            }
        }
        // the backend only needs the smallest pending timestamps (min)
        let pending_snapshot: Vec<Ts> =
            self.pending.iter().take(crate::runtime::engine::P_SLOTS).map(|&(lts, _)| lts).collect();
        let outs = self.backend.commit_batch(&reqs, &pending_snapshot);
        for o in outs {
            let Some(e) = self.entries.get_mut(&o.m) else { continue };
            if e.phase == Phase::Committed {
                continue;
            }
            e.phase = Phase::Committed;
            e.staged = false;
            e.gts = o.gts;
            e.commit_at = now;
            self.committed.insert((o.gts, o.m));
            self.stats.committed += 1;
            // the resolved (lts, gts) pair is durable before any DELIVER
            // or client notification for it leaves this cycle
            self.journal_state(o.m, out);
        }
        self.try_deliver(now, out);
    }

    // ---------- Fig. 4 line 21: ordered delivery at the leader ----------
    pub(crate) fn try_deliver(&mut self, now: u64, out: &mut Outbox) {
        loop {
            let Some(&(gts, m)) = self.committed.iter().next() else { break };
            if let Some(&(frontier, _)) = self.pending.iter().next() {
                if frontier <= gts {
                    break; // an in-flight message may still undercut gts
                }
            }
            self.committed.remove(&(gts, m));
            self.deliver_one(m, gts, now, out, true);
        }
    }

    /// Mark `m` delivered at this process and replicate the decision to
    /// the followers (`DELIVER`, line 23). `notify`: send the client
    /// notification (suppressed for post-recovery resends).
    pub(crate) fn deliver_one(&mut self, m: MsgId, gts: Ts, now: u64, out: &mut Outbox, notify: bool) {
        let e = self.entries.get_mut(&m).expect("deliver_one: unknown entry");
        debug_assert_eq!(e.phase, Phase::Committed);
        let lts = e.lts;
        // white-box path classification: recovery-resolved state trumps
        // everything; otherwise a delivery that had to wait past its
        // commit instant was blocked behind a concurrent message in the
        // frontier (the 5δ case), and one that delivers in the same
        // handler activation as its commit is collision-free (3δ)
        let path = if e.recovered || !notify {
            DeliveryPath::Recovery
        } else if now > e.commit_at {
            DeliveryPath::Concurrent
        } else {
            DeliveryPath::Fast
        };
        if !e.delivered {
            e.delivered = true;
            self.delivered_log.insert(gts, m);
            if gts > self.max_delivered_gts {
                self.max_delivered_gts = gts;
                out.deliver_traced(DeliverEffect {
                    m,
                    gts,
                    path,
                    submit_ns: e.meta.submit_ns,
                    proposal_at: e.proposal_at,
                    quorum_at: e.quorum_at,
                    commit_at: e.commit_at,
                    deliver_at: now,
                });
                self.stats.delivered += 1;
                self.stats.note_path(path);
            }
            let c = m.client();
            let seq = self.gc_client_seq.entry(c).or_insert(0);
            *seq = (*seq).max(m.seq());
            if self.cfg.durability {
                out.record(crate::storage::Record::Deliver { m, lts, gts });
            }
        }
        if notify {
            out.send(Pid(m.client()), Wire::Delivered { m, g: self.gid, gts });
        }
        let me = self.pid;
        let wire = Wire::Deliver { m, bal: self.cballot, lts, gts, path };
        out.send_to_many(self.group().iter().copied().filter(|&p| p != me), wire);
    }

    // ---------- Fig. 4 line 24: DELIVER at a follower ----------
    pub(crate) fn on_deliver(&mut self, m: MsgId, b: Ballot, lts: Ts, gts: Ts, path: DeliveryPath, now: u64, out: &mut Outbox) {
        // pre: status ∈ {FOLLOWER, LEADER} ∧ cballot = b ∧ max_delivered_gts < gts
        if self.status == Status::Recovering || self.cballot != b || self.max_delivered_gts >= gts {
            return;
        }
        let e = self.entries.entry(m).or_insert_with(|| Entry::new(MsgMeta::new(m, crate::types::GidSet::EMPTY, vec![])));
        // lines 26-31
        if e.phase == Phase::Proposed || e.phase == Phase::Accepted {
            self.pending.remove(&(e.lts, m));
        }
        if e.phase == Phase::Committed && !e.delivered {
            self.committed.remove(&(e.gts, m));
        }
        e.phase = Phase::Committed;
        e.lts = lts;
        e.gts = gts;
        e.delivered = true;
        self.clock = self.clock.max(gts.time());
        self.max_delivered_gts = gts;
        self.delivered_log.insert(gts, m);
        let c = m.client();
        let seq = self.gc_client_seq.entry(c).or_insert(0);
        *seq = (*seq).max(m.seq());
        self.stats.delivered += 1;
        self.stats.note_path(path);
        if self.cfg.durability {
            out.record(crate::storage::Record::Deliver { m, lts, gts });
        }
        // the follower inherits the leader's classification byte; its own
        // stage stamps are leader-local and therefore left at zero
        out.deliver_traced(DeliverEffect {
            m,
            gts,
            path,
            submit_ns: e.meta.submit_ns,
            proposal_at: 0,
            quorum_at: 0,
            commit_at: 0,
            deliver_at: now,
        });
    }

    // ---------- Fig. 4 line 32: retry (message recovery) ----------
    fn on_retry(&mut self, m: MsgId, _now: u64, out: &mut Outbox) {
        if self.status != Status::Leader {
            return;
        }
        let Some(e) = self.entries.get(&m) else { return };
        if e.phase != Phase::Proposed && e.phase != Phase::Accepted {
            return;
        }
        self.stats.retries += 1;
        for g in e.meta.dest.iter() {
            out.stage(self.cur_leader[g.0 as usize]);
        }
        out.send_staged(Wire::Multicast { meta: e.meta.clone() });
        out.timer(TimerKind::Retry(m), self.cfg.retry_after);
    }

    // ---------- GC (§VI) ----------
    /// Leader: recompute the group-wide delivered watermark from follower
    /// reports; everything at or below it has been delivered by *every*
    /// group member, so (a) its entry can never be needed again — every
    /// member's clock and `max_delivered_gts` already exceed it — and
    /// (b) duplicates are caught by the per-client sequence watermark.
    fn gc_sweep(&mut self, out: &mut Outbox) -> Option<Ts> {
        if !self.cfg.gc || self.status != Status::Leader {
            return None;
        }
        let mut wm = self.max_delivered_gts;
        for &p in self.group() {
            if p == self.pid {
                continue;
            }
            wm = wm.min(self.gc_reports.get(&p).copied().unwrap_or(Ts::BOT));
        }
        if wm.is_bot() {
            return None;
        }
        self.trim_below(wm, out);
        Some(wm)
    }

    /// Drop delivered entries with gts ≤ `wm` (leader after a sweep,
    /// followers on the leader's watermark announcement). Each client's
    /// *latest* delivered message is always retained: remote groups may
    /// still need its local timestamp / ACCEPT resend to finish their own
    /// commit — only once a *later* message of the same client is
    /// delivered is the previous one globally complete.
    pub(crate) fn trim_below(&mut self, wm: Ts, out: &mut Outbox) {
        if self.cfg.durability {
            // journal the watermark so a restart compacts identically
            out.record(crate::storage::Record::Trim { wm });
        }
        let drop: Vec<(Ts, MsgId)> = self
            .delivered_log
            .range(..=wm)
            .filter(|&(_, &m)| self.gc_client_seq.get(&m.client()).is_some_and(|&s| m.seq() < s))
            .map(|(&g, &m)| (g, m))
            .collect();
        for (g, m) in drop {
            self.delivered_log.remove(&g);
            self.entries.remove(&m);
            self.stats.gc_dropped += 1;
        }
    }
}

impl Node for WbNode {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, now: u64, out: &mut Outbox) {
        if self.cfg.hb_interval > 0 {
            out.timer(TimerKind::LssTick, self.cfg.hb_interval);
        }
        if self.rejoin {
            // restored from disk: rejoin through the recovery protocol —
            // a fresh candidacy resynchronises us with a quorum and
            // resends the deliveries we missed while down
            self.rejoin = false;
            self.recover(now, out);
        }
    }

    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
        match wire {
            Wire::Multicast { meta } => self.on_multicast(meta, now, out),
            Wire::Accept { meta, g, bal, lts } => {
                if g == self.gid && bal.leader() == from {
                    self.last_hb = now; // own leader is alive
                }
                self.on_accept(meta, g, bal, lts, now, out)
            }
            Wire::AcceptAck { m, g, bals } => self.on_accept_ack(m, g, bals, from, now, out),
            Wire::Deliver { m, bal, lts, gts, path } => {
                if bal.leader() == from {
                    self.last_hb = now;
                }
                self.on_deliver(m, bal, lts, gts, path, now, out)
            }
            Wire::NewLeader { bal } => self.on_new_leader(bal, from, now, out),
            Wire::NewLeaderAck { bal, cbal, clock, state } => {
                self.on_new_leader_ack(bal, cbal, clock, state, from, now, out)
            }
            Wire::NewState { bal, clock, state } => self.on_new_state(bal, clock, state, from, now, out),
            Wire::NewStateAck { bal } => self.on_new_state_ack(bal, from, now, out),
            Wire::Heartbeat { bal } => {
                if bal >= self.cballot && self.topo.is_member(from, self.gid) {
                    self.last_hb = now;
                }
            }
            Wire::GcReport { max_gts } => {
                if !self.topo.is_member(from, self.gid) {
                    return;
                }
                if self.status == Status::Leader {
                    // follower report: update watermark, sweep, announce
                    self.gc_reports.insert(from, max_gts);
                    if let Some(wm) = self.gc_sweep(out) {
                        let me = self.pid;
                        out.send_to_many(
                            self.group().iter().copied().filter(|&p| p != me),
                            Wire::GcReport { max_gts: wm },
                        );
                    }
                } else if from == self.cballot.leader() {
                    // leader's group-wide watermark announcement
                    self.trim_below(max_gts, out);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, timer: TimerKind, now: u64, out: &mut Outbox) {
        match timer {
            TimerKind::Retry(m) => self.on_retry(m, now, out),
            TimerKind::LssTick => self.on_lss_tick(now, out),
            TimerKind::RecoveryTimeout(n) => self.on_recovery_timeout(n, now, out),
            TimerKind::BatchFlush => self.flush_commits(now, out),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests;
