//! WbCast unit + small-world integration tests (driven through the
//! deterministic simulator).

use super::*;
use crate::client::{Client, ClientCfg};
use crate::invariants;
use crate::protocols::{Node, Outbox};
use crate::sim::{CpuCost, SimConfig, World};
use crate::types::{GidSet, MsgId, MsgMeta, Topology};

const D: u64 = 1_000_000; // δ = 1 ms

fn world(k: usize, f: usize, n_clients: usize, dest_groups: usize, wb: WbConfig, client: ClientCfg, seed: u64) -> World {
    let topo = Topology::new(k, f);
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            nodes.push(Box::new(WbNode::new(p, topo.clone(), wb)));
        }
    }
    for c in 0..n_clients {
        let pid = Pid(topo.first_client_pid().0 + c as u32);
        let cfg = ClientCfg { dest_groups, ..client.clone() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, seed ^ (c as u64 + 1))));
    }
    World::new(
        topo,
        nodes,
        SimConfig {
            delay: Box::new(crate::sim::ConstDelay(D)),
            cpu: CpuCost::zero(),
            seed,
            record_full: true,
            coalesce: true,
            flush: crate::types::FlushPolicy::default(),
        },
    )
}

#[test]
fn solo_message_commits_in_3_delta() {
    // 2 groups, f=1, one client, one request: leaders deliver at exactly 3δ
    let mut w = world(2, 1, 1, 2, WbConfig::default(), ClientCfg { max_requests: Some(1), ..Default::default() }, 1);
    w.run_to_quiescence(10_000);
    invariants::assert_correct(&w.trace);
    // first delivery in each group at 3δ (MULTICAST, ACCEPT, ACCEPT_ACK)
    assert_eq!(w.trace.latencies, vec![3 * D, 3 * D]);
    // followers deliver at 4δ: all 6 members delivered
    assert_eq!(w.trace.delivered_count, 6);
    let max_t = w.trace.deliveries.iter().map(|d| d.time).max().unwrap();
    assert_eq!(max_t, 4 * D);
}

#[test]
fn single_group_message_follows_paxos_flow() {
    let mut w = world(1, 1, 1, 1, WbConfig::default(), ClientCfg { max_requests: Some(1), ..Default::default() }, 2);
    w.run_to_quiescence(10_000);
    invariants::assert_correct(&w.trace);
    assert_eq!(w.trace.latencies, vec![3 * D]);
}

#[test]
fn leader_state_after_commit() {
    let mut w = world(2, 1, 1, 2, WbConfig::default(), ClientCfg { max_requests: Some(1), ..Default::default() }, 3);
    w.run_to_quiescence(10_000);
    let m = MsgId::new(w.trace.topo().first_client_pid().0, 1);
    for g in [Gid(0), Gid(1)] {
        let leader = w.trace.topo().initial_leader(g);
        let n = w.node_as::<WbNode>(leader);
        assert_eq!(n.phase_of(m), Phase::Committed);
        assert!(n.is_leader());
        let gts = n.gts_of(m).unwrap();
        // clock advanced past the global timestamp (Fig. 4 line 14)
        assert!(n.clock() >= gts.time());
        assert_eq!(n.stats.committed, 1);
        assert_eq!(n.stats.delivered, 1);
    }
    // followers also delivered and committed via DELIVER
    let f1 = w.node_as::<WbNode>(Pid(1));
    assert_eq!(f1.phase_of(m), Phase::Committed);
    assert_eq!(f1.stats.delivered, 1);
}

#[test]
fn concurrent_conflicting_messages_totally_ordered() {
    // 4 clients × 50 requests to overlapping pairs of 3 groups
    let mut w = world(
        3,
        1,
        4,
        2,
        WbConfig::default(),
        ClientCfg { max_requests: Some(50), ..Default::default() },
        0xAB,
    );
    w.run_to_quiescence(2_000_000);
    invariants::assert_correct(&w.trace);
    assert_eq!(w.trace.completions.len(), 200);
}

#[test]
fn client_retransmission_does_not_double_deliver() {
    // resend interval shorter than the 3δ commit latency forces duplicate
    // MULTICASTs while the first attempt is still in flight
    let mut w = world(
        2,
        1,
        2,
        2,
        WbConfig::default(),
        ClientCfg { max_requests: Some(20), resend_after: 2 * D, ..Default::default() },
        7,
    );
    w.run_to_quiescence(4_000_000);
    invariants::assert_correct(&w.trace);
    assert_eq!(w.trace.completions.len(), 40);
}

#[test]
fn gts_is_max_of_local_timestamps() {
    let mut w = world(2, 1, 1, 2, WbConfig::default(), ClientCfg { max_requests: Some(1), ..Default::default() }, 4);
    w.run_to_quiescence(10_000);
    let m = MsgId::new(w.trace.topo().first_client_pid().0, 1);
    let l0 = w.node_as::<WbNode>(Pid(0));
    let l1 = w.node_as::<WbNode>(Pid(3));
    let gts0 = l0.gts_of(m).unwrap();
    let gts1 = l1.gts_of(m).unwrap();
    assert_eq!(gts0, gts1, "groups agree on gts (Invariant 3b)");
    // both groups proposed (1, g): max is (1, g1)
    assert_eq!(gts0, Ts::new(1, Gid(1)));
}

// ---------- recovery ----------

fn crash_world(seed: u64) -> (World, Pid) {
    // 2 groups, f=1; crash the leader of group 0 mid-run
    let wb = WbConfig::with_failures(D);
    let client = ClientCfg { max_requests: Some(30), resend_after: 30 * D, ..Default::default() };
    let w = world(2, 1, 3, 2, wb, client, seed);
    (w, Pid(0))
}

#[test]
fn leader_crash_recovers_and_terminates() {
    let (mut w, leader) = crash_world(11);
    w.crash_at(leader, 5 * D); // mid-protocol for the first wave
    w.run_until(3_000 * D);
    invariants::assert_safe(&w.trace);
    // a new leader took over in group 0
    let candidates: Vec<Pid> = vec![Pid(1), Pid(2)];
    let new_leader = candidates.iter().find(|&&p| w.node_as::<WbNode>(p).is_leader());
    assert!(new_leader.is_some(), "no new leader in group 0");
    let nl = w.node_as::<WbNode>(*new_leader.unwrap());
    assert!(nl.cballot() > Ballot::new(1, Pid(0)));
    assert!(nl.stats.recoveries_completed >= 1);
    // all 90 requests eventually complete despite the crash
    assert_eq!(w.trace.completions.len(), 90, "incomplete: {}", w.trace.incomplete());
    // termination among correct processes
    let vs = invariants::check_termination(&w.trace);
    assert!(vs.is_empty(), "{vs:?}");
}

#[test]
fn crash_during_recovery_elects_next_candidate() {
    // f = 2 (5-member groups) so that two crashes in group 0 stay within
    // the fault bound: the leader p0 and then the first candidate p1.
    let wb = WbConfig::with_failures(D);
    let client = ClientCfg { max_requests: Some(30), resend_after: 30 * D, ..Default::default() };
    let mut w = world(2, 2, 3, 2, wb, client, 13);
    w.crash_at(Pid(0), 5 * D);
    // the first candidate (rank 1 = Pid(1)) crashes just as it would be
    // taking over
    w.crash_at(Pid(1), 40 * D);
    w.run_until(5_000 * D);
    invariants::assert_safe(&w.trace);
    let survivor_leader = [Pid(2), Pid(3), Pid(4)].iter().find(|&&p| w.node_as::<WbNode>(p).is_leader());
    assert!(survivor_leader.is_some(), "a surviving member of group 0 must take over");
    assert_eq!(w.trace.completions.len(), 90, "incomplete: {}", w.trace.incomplete());
    let vs = invariants::check_termination(&w.trace);
    assert!(vs.is_empty(), "{vs:?}");
}

// ---------- crash-restart from durable storage ----------

/// A durable 2×3 world: every member journals into simulated storage
/// ([`crate::storage::MemWal`]) and can be rebuilt from the decoded fold
/// by a [`World::restart_at`] event.
fn durable_world(seed: u64, requests: u32) -> World {
    let wb = WbConfig { durability: true, ..WbConfig::with_failures(D) };
    let client = ClientCfg { max_requests: Some(requests), resend_after: 30 * D, ..Default::default() };
    let mut w = world(2, 1, 3, 2, wb, client, seed);
    crate::harness::enable_wb_storage(&mut w, &Topology::new(2, 1), wb);
    w
}

/// Tentpole acceptance (sim): kill the leader of group 0 *and* a
/// follower of group 1, restart both from their journals, and demand
/// the full, strict correctness suite — the restarts withdraw the crash
/// entries, so Termination requires the restarted processes to catch up
/// on every delivery they missed (which the rejoin recovery provides),
/// and safety (ordering/integrity/agreement) spans both incarnations.
#[test]
fn killed_members_restart_from_storage_and_rejoin() {
    let mut w = durable_world(41, 30);
    w.crash_at(Pid(0), 5 * D); // leader of group 0, mid-protocol
    w.restart_at(Pid(0), 400 * D);
    w.crash_at(Pid(4), 200 * D); // follower of group 1
    w.restart_at(Pid(4), 600 * D);
    w.run_until(6_000 * D);

    assert_eq!(w.trace.restarts.len(), 2, "restarts never fired");
    assert!(!w.store(Pid(0)).unwrap().is_empty(), "leader journaled nothing");
    assert!(!w.store(Pid(4)).unwrap().is_empty(), "follower journaled nothing");
    // both restarted nodes rejoined through the recovery protocol
    for p in [Pid(0), Pid(4)] {
        let n = w.node_as::<WbNode>(p);
        assert!(n.stats.recoveries_started >= 1, "{p:?} never re-joined");
        assert!(n.stats.delivered > 0, "{p:?} delivered nothing after restart");
    }
    // all 90 requests complete, and every invariant (incl. strict
    // termination over ALL six members) holds across the restarts
    assert_eq!(w.trace.completions.len(), 90, "incomplete: {}", w.trace.incomplete());
    assert!(w.trace.crashes.is_empty(), "restart must withdraw the crash entry");
    invariants::assert_correct(&w.trace);
}

/// Restarting without ever crashing is a no-op, and a crash without a
/// registered restart stays a plain crash-stop failure.
#[test]
fn restart_events_are_guarded() {
    let mut w = durable_world(43, 10);
    w.restart_at(Pid(1), 50 * D); // never crashed: ignored
    w.run_until(2_000 * D);
    assert!(w.trace.restarts.is_empty());
    assert_eq!(w.trace.completions.len(), 30);
    invariants::assert_correct(&w.trace);
}

/// The journal round-trips through the storage codec: the MemWal fold of
/// a running leader matches the state the node itself reports.
#[test]
fn journal_fold_matches_live_node_state() {
    // with_failures arms heartbeats, so the world never quiesces: run a
    // bounded horizon well past the 30 completions instead
    let mut w = durable_world(47, 10);
    w.run_until(2_000 * D);
    invariants::assert_correct(&w.trace);
    for p in [Pid(0), Pid(3)] {
        let snap = w.store(p).unwrap().recover();
        let n = w.node_as::<WbNode>(p);
        // no election ran, so no Promote record exists: the journal's
        // cballot stays ⊥ and restore falls back to the pre-agreed
        // initial ballot — exactly what the live node holds
        assert_eq!(snap.cballot.max(Ballot::new(1, Pid(p.0 / 3 * 3))), n.cballot());
        assert_eq!(snap.max_delivered_gts, n.max_delivered_gts, "{p:?} watermark diverged");
        assert!(snap.clock <= n.clock(), "{p:?} journaled clock ran ahead");
        // every delivered message is in the journal with its gts
        for (&gts, &m) in &n.delivered_log {
            assert_eq!(snap.delivered.get(&gts), Some(&m), "{p:?} missing delivery {m:?}");
            assert_eq!(snap.state[&m].gts, gts, "{p:?} journaled gts diverged for {m:?}");
        }
    }
}

#[test]
fn deposed_leader_cannot_commit() {
    // Crash nothing, but force a recovery in group 0 by directly injecting
    // a NEWLEADER from Pid(1): the old leader is deposed; the system keeps
    // processing (new messages go through the new leader after clients
    // learn it from Delivered senders).
    let wb = WbConfig::with_failures(D);
    let client = ClientCfg { max_requests: Some(20), resend_after: 30 * D, ..Default::default() };
    let mut w = world(2, 1, 2, 2, wb, client, 17);
    // run a bit, then depose
    w.run_until(10 * D);
    let b = Ballot::new(2, Pid(1));
    let mut o1 = Outbox::new();
    {
        let n1 = w.node_mut(Pid(1));
        let n1 = (n1 as &mut dyn std::any::Any).downcast_mut::<WbNode>().unwrap();
        n1.recover(10 * D, &mut o1);
    }
    // inject the candidate's NEWLEADER messages by hand (three hops:
    // NEWLEADER → NEWLEADER_ACK → NEW_STATE/NEWSTATE_ACK)
    for (to, wire) in o1.sends().to_vec() {
        let mut o2 = Outbox::new();
        w.node_mut(to).on_wire(Pid(1), wire, 10 * D, &mut o2);
        for (to2, wire2) in o2.sends().to_vec() {
            let mut o3 = Outbox::new();
            w.node_mut(to2).on_wire(to, wire2, 10 * D, &mut o3);
            for (to3, wire3) in o3.sends().to_vec() {
                let mut o4 = Outbox::new();
                w.node_mut(to3).on_wire(to2, wire3, 10 * D, &mut o4);
            }
        }
    }
    assert_eq!(w.node_as::<WbNode>(Pid(1)).cballot(), b);
    // keep running: safety must hold throughout
    w.run_until(3_000 * D);
    invariants::assert_safe(&w.trace);
    assert_eq!(w.trace.completions.len(), 40, "incomplete: {}", w.trace.incomplete());
}

#[test]
fn gc_trims_delivered_entries() {
    let wb = WbConfig { gc: true, hb_interval: 2 * D, ..WbConfig::with_failures(D) };
    let client = ClientCfg { max_requests: Some(50), resend_after: 50 * D, ..Default::default() };
    let mut w = world(1, 1, 2, 1, wb, client, 23);
    w.run_until(3_000 * D);
    invariants::assert_safe(&w.trace);
    assert_eq!(w.trace.completions.len(), 100);
    let leader = w.node_as::<WbNode>(Pid(0));
    assert!(leader.stats.gc_dropped > 0, "GC never ran");
    assert!(leader.entries.len() < 100, "entries not trimmed: {}", leader.entries.len());
    // duplicate MULTICAST of a GC'd message re-acks the client
    let m = MsgId::new(w.trace.topo().first_client_pid().0, 1);
    let meta = MsgMeta::new(m, GidSet::single(Gid(0)), vec![]);
    let mut out = Outbox::new();
    {
        let n = w.node_mut(Pid(0));
        let n = (n as &mut dyn std::any::Any).downcast_mut::<WbNode>().unwrap();
        assert_eq!(n.phase_of(m), Phase::Start, "entry should be GC'd");
        n.on_multicast(meta, 0, &mut out);
    }
    assert!(
        out.sends().iter().any(|(_, w)| matches!(w, Wire::Delivered { .. })),
        "GC'd duplicate must re-ack: {:?}",
        out.sends()
    );
}

#[test]
fn stale_ballot_accept_ack_is_ignored() {
    let topo = Topology::new(1, 1);
    let mut n = WbNode::new(Pid(0), topo.clone(), WbConfig::default());
    let m = MsgId::new(9, 1);
    let meta = MsgMeta::new(m, GidSet::single(Gid(0)), vec![]);
    let mut out = Outbox::new();
    n.on_multicast(meta.clone(), 0, &mut out);
    out.clear();
    // ack with a ballot vector from a previous leadership
    let stale = vec![(Gid(0), Ballot::new(0, Pid(0)))];
    n.on_accept_ack(m, Gid(0), stale, Pid(1), 0, &mut out);
    assert!(out.is_empty());
    assert_eq!(n.phase_of(m), Phase::Proposed);
}

#[test]
fn accept_from_recovering_process_is_deferred() {
    let topo = Topology::new(1, 1);
    let mut n = WbNode::new(Pid(1), topo.clone(), WbConfig::default());
    n.status = Status::Recovering;
    let m = MsgId::new(9, 1);
    let meta = MsgMeta::new(m, GidSet::single(Gid(0)), vec![]);
    let mut out = Outbox::new();
    n.on_accept(meta, Gid(0), Ballot::new(1, Pid(0)), Ts::new(1, Gid(0)), 0, &mut out);
    assert!(out.is_empty(), "recovering process must not ack");
}

#[test]
fn deliver_requires_matching_cballot() {
    let topo = Topology::new(1, 1);
    let mut n = WbNode::new(Pid(1), topo.clone(), WbConfig::default());
    let m = MsgId::new(9, 1);
    // DELIVER from a ballot we have not synchronised with
    let mut out = Outbox::new();
    n.on_deliver(m, Ballot::new(9, Pid(0)), Ts::new(1, Gid(0)), Ts::new(1, Gid(0)), DeliveryPath::Fast, 0, &mut out);
    assert!(out.is_empty());
    assert_eq!(n.phase_of(m), Phase::Start);
    // matching ballot works
    n.on_deliver(m, Ballot::new(1, Pid(0)), Ts::new(1, Gid(0)), Ts::new(1, Gid(0)), DeliveryPath::Fast, 0, &mut out);
    assert_eq!(out.delivers().len(), 1);
    out.clear();
    // duplicate (same gts) is dropped by max_delivered_gts
    n.on_deliver(m, Ballot::new(1, Pid(0)), Ts::new(1, Gid(0)), Ts::new(1, Gid(0)), DeliveryPath::Fast, 0, &mut out);
    assert!(out.is_empty());
}

#[test]
fn follower_ignores_multicast() {
    let topo = Topology::new(1, 1);
    let mut n = WbNode::new(Pid(1), topo.clone(), WbConfig::default()); // follower
    let m = MsgId::new(9, 1);
    let mut out = Outbox::new();
    n.on_multicast(MsgMeta::new(m, GidSet::single(Gid(0)), vec![]), 0, &mut out);
    assert!(out.is_empty());
    assert_eq!(n.phase_of(m), Phase::Start);
}

#[test]
fn heartbeats_keep_followers_from_recovering() {
    let wb = WbConfig::with_failures(D);
    let mut w = world(1, 1, 1, 1, wb, ClientCfg { max_requests: Some(5), ..Default::default() }, 31);
    w.run_until(2_000 * D);
    // no crash: ballot must still be the initial one everywhere
    for p in [Pid(0), Pid(1), Pid(2)] {
        let n = w.node_as::<WbNode>(p);
        assert_eq!(n.cballot(), Ballot::new(1, Pid(0)), "{p:?} moved ballots without failures");
        assert_eq!(n.stats.recoveries_started, 0);
    }
    invariants::assert_correct(&w.trace);
}
