//! WbCast leader recovery (Fig. 4 lines 35–66) and the leader-selection
//! plumbing (heartbeats, suspicion, recovery restart).
//!
//! Recovery is Zab/VR-style: because the leader takes delivery decisions
//! unilaterally, a new leader must (1) adopt a state computed from a
//! quorum of `NEWLEADER_ACK`s — keeping any COMMITTED message, and any
//! message ACCEPTED by a reporter of the *maximal* `cballot` (Paxos'
//! value-selection rule, preserving Invariant 2) — and (2) push that
//! state to a quorum of followers (`NEW_STATE` / `NEWSTATE_ACK`,
//! preserving Invariant 5) *before* resuming normal operation.

use super::{Entry, WbNode};
use crate::protocols::{Outbox, TimerKind};
use crate::types::wire::MsgState;
use crate::types::{Ballot, DeliveryPath, MsgId, Phase, Pid, Status, Ts, Wire};
use std::collections::BTreeMap;

/// Contents of a NEWLEADER_ACK, kept per reporter.
pub(crate) struct NlAck {
    pub cbal: Ballot,
    pub clock: u64,
    pub state: Vec<MsgState>,
}

impl WbNode {
    /// Snapshot of every non-START message (sent in NEWLEADER_ACK),
    /// sorted by message id: the vector goes on the wire and into Adopt
    /// journal records, so its order must not depend on hash iteration.
    fn snapshot(&self) -> Vec<MsgState> {
        let mut v: Vec<MsgState> = self
            .entries
            .values() // unordered-ok: sorted by id below
            .filter(|e| e.phase != Phase::Start)
            .map(|e| MsgState { meta: e.meta.clone(), phase: e.phase, lts: e.lts, gts: e.gts })
            .collect();
        v.sort_unstable_by_key(|s| s.meta.id);
        v
    }

    /// Fig. 4 line 35: start a new candidacy.
    pub(crate) fn recover(&mut self, _now: u64, out: &mut Outbox) {
        let n = self.ballot.n.max(self.cballot.n) + 1;
        let b = Ballot::new(n, self.pid);
        self.stats.recoveries_started += 1;
        // our own NEWLEADER (self-send) moves us to RECOVERING
        out.send_to_many(self.group().iter().copied(), Wire::NewLeader { bal: b });
        if self.cfg.recovery_timeout > 0 {
            out.timer(TimerKind::RecoveryTimeout(n), self.cfg.recovery_timeout);
        }
    }

    /// Fig. 4 line 37: vote for a prospective leader.
    pub(crate) fn on_new_leader(&mut self, b: Ballot, from: Pid, now: u64, out: &mut Outbox) {
        if !self.topo.is_member(from, self.gid) || b <= self.ballot {
            return; // pre: b > ballot
        }
        self.ballot = b;
        self.status = Status::Recovering;
        self.nl_acks.clear();
        self.ns_acks.clear();
        self.last_hb = now; // give the candidate time before suspecting it
        if self.cfg.durability {
            // the ballot promise must survive a restart: journaled (and
            // committed by the runtime) before the vote leaves
            out.record(crate::storage::Record::Promote {
                ballot: b,
                cballot: self.cballot,
                clock: self.clock,
            });
        }
        out.send(
            from,
            Wire::NewLeaderAck { bal: b, cbal: self.cballot, clock: self.clock, state: self.snapshot() },
        );
    }

    /// Fig. 4 line 42: collect votes; on quorum, compute the initial state.
    pub(crate) fn on_new_leader_ack(
        &mut self,
        b: Ballot,
        cbal: Ballot,
        clock: u64,
        state: Vec<MsgState>,
        from: Pid,
        now: u64,
        out: &mut Outbox,
    ) {
        // pre: status = RECOVERING ∧ ballot = b; `cballot < b` excludes
        // duplicate computation after the state was already adopted
        if self.status != Status::Recovering || self.ballot != b || b.leader() != self.pid || self.cballot >= b {
            return;
        }
        self.nl_acks.insert(from, NlAck { cbal, clock, state });
        if self.nl_acks.len() < self.quorum() {
            return;
        }

        // ---- lines 44-55: compute the new state ----
        let b0 = self.nl_acks.values().map(|a| a.cbal).max().unwrap();
        // phase/lts/gts triple per message; BTreeMap so the adopted state
        // (and the NEW_STATE wire built from it) is ordered by MsgId, not
        // by hash-iteration accident
        let mut merged: BTreeMap<MsgId, MsgState> = BTreeMap::new();
        for ack in self.nl_acks.values() {
            for s in &ack.state {
                // line 47: COMMITTED anywhere wins outright
                if s.phase == Phase::Committed {
                    let slot = merged.entry(s.meta.id).or_insert_with(|| s.clone());
                    if slot.phase != Phase::Committed {
                        *slot = s.clone();
                    } else if slot.meta.dest.is_empty() {
                        slot.meta = s.meta.clone();
                    }
                }
            }
        }
        for ack in self.nl_acks.values().filter(|a| a.cbal == b0) {
            for s in &ack.state {
                // line 51: ACCEPTED at the maximal cballot survives
                if s.phase == Phase::Accepted {
                    merged.entry(s.meta.id).or_insert_with(|| s.clone());
                }
                // PROPOSED entries are dropped: they were never replicated
                // and will be resurrected by message recovery if needed
            }
        }
        // line 54: recover the clock
        let new_clock = self.nl_acks.values().map(|a| a.clock).max().unwrap();

        self.adopt(&merged.values().cloned().collect::<Vec<_>>(), new_clock);
        self.cballot = b; // line 55
        let state_out: Vec<MsgState> = self.snapshot();
        if self.cfg.durability {
            // the merged state replaces the journal image wholesale (an
            // Adopt record, not per-entry upserts): a restart must not
            // resurrect entries the merge dropped (Invariant 2)
            out.record(crate::storage::Record::Adopt {
                ballot: b,
                cballot: b,
                clock: new_clock,
                state: state_out.clone(),
            });
        }
        self.ns_acks.clear();
        self.ns_acks.insert(self.pid);
        for &p in self.group() {
            if p != self.pid {
                out.send(p, Wire::NewState { bal: b, clock: new_clock, state: state_out.clone() });
            }
        }
        self.nl_acks.clear();
        self.maybe_finish_recovery(out, now);
    }

    /// Replace protocol state with `state` (recovered or pushed by the new
    /// leader), rebuilding the derived indices. Own delivery history
    /// (`delivered_log`, `max_delivered_gts`) is preserved — it is local
    /// knowledge about the `deliver(m)` events this process already
    /// emitted, not replicated state.
    fn adopt(&mut self, state: &[MsgState], clock: u64) {
        self.clock = clock;
        self.pending.clear();
        self.committed.clear();
        self.ready.clear(); // staged commits are invalidated by the new state
        let mut entries: crate::util::FxHashMap<MsgId, Entry> = Default::default();
        for s in state {
            let mut e = Entry::new(s.meta.clone());
            e.phase = s.phase;
            e.lts = s.lts;
            e.gts = s.gts;
            e.recovered = true;
            match s.phase {
                Phase::Accepted => {
                    self.pending.insert((s.lts, s.meta.id));
                }
                Phase::Committed => {
                    e.delivered = self.delivered_log.contains_key(&s.gts);
                    if !e.delivered {
                        self.committed.insert((s.gts, s.meta.id));
                    }
                }
                _ => {}
            }
            // keep remote accept proposals from the old entry: the remote
            // leaders' ballots are unaffected by our group's change
            if let Some(old) = self.entries.get(&s.meta.id) {
                e.accepts = old.accepts.clone();
                e.accepts.remove(&self.gid); // our own proposal is stale
            }
            entries.insert(s.meta.id, e);
        }
        self.entries = entries;
    }

    /// Fig. 4 line 57: follower adopts the new leader's state.
    pub(crate) fn on_new_state(
        &mut self,
        b: Ballot,
        clock: u64,
        state: Vec<MsgState>,
        from: Pid,
        now: u64,
        out: &mut Outbox,
    ) {
        if self.status != Status::Recovering || self.ballot != b {
            return;
        }
        self.adopt(&state, clock);
        self.status = Status::Follower;
        self.cballot = b;
        self.cur_leader[self.gid.0 as usize] = b.leader();
        self.last_hb = now;
        if self.cfg.durability {
            // adopted state + completed promotion, durable before the ACK
            // confirms the synchronisation (Invariant 5)
            out.record(crate::storage::Record::Adopt { ballot: b, cballot: b, clock, state });
        }
        out.send(from, Wire::NewStateAck { bal: b });
    }

    /// Fig. 4 line 63: with a quorum in sync, resume normal operation.
    pub(crate) fn on_new_state_ack(&mut self, b: Ballot, from: Pid, now: u64, out: &mut Outbox) {
        if self.status != Status::Recovering || self.ballot != b || self.cballot != b {
            return;
        }
        self.ns_acks.insert(from);
        self.maybe_finish_recovery(out, now);
    }

    fn maybe_finish_recovery(&mut self, out: &mut Outbox, now: u64) {
        if self.status != Status::Recovering || self.cballot != self.ballot || self.ns_acks.len() < self.quorum() {
            return;
        }
        // line 65: become leader
        self.status = Status::Leader;
        self.cur_leader[self.gid.0 as usize] = self.pid;
        self.stats.recoveries_completed += 1;
        self.leader_since = now;
        self.last_hb = now;

        // lines 66-68: re-deliver all committed messages "starting from
        // the beginning" — followers deduplicate via max_delivered_gts.
        // A delivered message may lack an entry: GC (or an adoption from
        // peers that already GC'd it) can trim the entry while the local
        // delivery record survives — then every member has it delivered
        // and there is nothing to resend.
        let resend: Vec<(Ts, MsgId)> = self.delivered_log.iter().map(|(&g, &m)| (g, m)).collect();
        for (gts, m) in resend {
            let Some(e) = self.entries.get(&m) else { continue };
            let (lts, bal) = (e.lts, self.cballot);
            let me = self.pid;
            out.send_to_many(
                self.group().iter().copied().filter(|&p| p != me),
                Wire::Deliver { m, bal, lts, gts, path: DeliveryPath::Recovery },
            );
            // re-notify the client: its notification may have died with
            // the old leader (clients deduplicate)
            out.send(Pid(m.client()), Wire::Delivered { m, g: self.gid, gts });
        }
        // deliver whatever is now unblocked (line 66 delivery condition)
        self.try_deliver(now, out);

        // resume stuck messages (§IV message recovery): retry every
        // still-pending (ACCEPTED) message through the MULTICAST path,
        // which re-sends ACCEPTs with our new ballot
        let stuck: Vec<MsgId> = self.pending.iter().map(|&(_, m)| m).collect();
        for m in stuck {
            self.on_retry_now(m, out);
        }
        // announce ourselves
        let me = self.pid;
        let hb = Wire::Heartbeat { bal: self.cballot };
        out.send_to_many(self.group().iter().copied().filter(|&p| p != me), hb);
    }

    /// retry(m) without the leader-status guard (we just became leader)
    fn on_retry_now(&mut self, m: MsgId, out: &mut Outbox) {
        let Some(e) = self.entries.get(&m) else { return };
        if e.phase != Phase::Proposed && e.phase != Phase::Accepted {
            return;
        }
        self.stats.retries += 1;
        for g in e.meta.dest.iter() {
            out.stage(self.cur_leader[g.0 as usize]);
        }
        out.send_staged(Wire::Multicast { meta: e.meta.clone() });
        if self.cfg.retry_after > 0 {
            out.timer(TimerKind::Retry(m), self.cfg.retry_after);
        }
    }

    // ---------- leader-selection service (Ω-style, §IV "LSS") ----------

    /// Periodic tick: leaders emit heartbeats (and run GC); followers
    /// check leader health with rank-staggered timeouts so a single
    /// stable candidate emerges (Invariant 6).
    pub(crate) fn on_lss_tick(&mut self, now: u64, out: &mut Outbox) {
        if self.cfg.hb_interval == 0 {
            return;
        }
        out.timer(TimerKind::LssTick, self.cfg.hb_interval);
        match self.status {
            Status::Leader => {
                let me = self.pid;
                let hb = Wire::Heartbeat { bal: self.cballot };
                out.send_to_many(self.group().iter().copied().filter(|&p| p != me), hb);
            }
            Status::Follower | Status::Recovering => {
                // candidates track their own progress via RecoveryTimeout
                if self.status == Status::Recovering && self.ballot.leader() == self.pid {
                    return;
                }
                if self.cfg.gc && self.status == Status::Follower && !self.max_delivered_gts.is_bot() {
                    let leader = self.cballot.leader();
                    if leader != self.pid {
                        out.send(leader, Wire::GcReport { max_gts: self.max_delivered_gts });
                    }
                }
                let timeout = self.cfg.hb_interval * self.cfg.hb_suspect_mult * (1 + self.rank());
                if now.saturating_sub(self.last_hb) > timeout {
                    self.recover(now, out);
                }
            }
        }
    }

    /// A candidacy that stalls (no quorum of NEWLEADER_ACK/NEWSTATE_ACK)
    /// restarts with a higher ballot.
    pub(crate) fn on_recovery_timeout(&mut self, n: u32, now: u64, out: &mut Outbox) {
        if self.status == Status::Recovering && self.ballot.n == n && self.ballot.leader() == self.pid {
            self.recover(now, out);
        }
    }
}
