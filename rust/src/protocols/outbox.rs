//! The reusable effects sink every [`Node`](super::Node) writes into, and
//! the destination-coalescing machinery the runtimes use to turn the flat
//! send list into per-destination wire frames.
//!
//! Design (EXPERIMENTS.md §Perf, hot-path effects refactor):
//!
//! * **`Outbox`** replaces the old `Vec<Action>` return value. The three
//!   effect buffers (sends, local deliveries, timers) are owned by the
//!   runtime and reused across events, so the steady-state hot path does
//!   zero per-event effect-vector allocations. Payload fan-out stays
//!   allocation-free too: `MsgMeta::payload` is an `Arc`-backed
//!   [`Payload`](crate::types::Payload) view, so the wire clones made by
//!   [`Outbox::send_to_many`] / [`Outbox::send_staged`] never copy
//!   payload bytes (the last recipient receives the original, so `n`
//!   recipients cost `n - 1` shallow clones), and payloads decoded from
//!   a received frame stay views into that frame's shared buffer.
//! * **`LinkCoalescer`** is the production flush point: a stateful
//!   per-link buffer enforcing a [`FlushPolicy`] (immediate per-cycle
//!   frames by default; optionally an adaptive delay/byte window), used
//!   identically by the inline single-shard runtime, the sharded
//!   flusher thread and the simulator. One frame means one arrival
//!   event (and one CPU charge) in the simulator and one encode + one
//!   length-prefixed write (one syscall) in the TCP transport. Frames
//!   are emitted in first-push order of their destination, which keeps
//!   schedules deterministic and — for single-wire destinations —
//!   identical to the uncoalesced order.
//! * **`Coalescer`** is the original stateless per-cycle grouper, kept
//!   as the reference model the unit tests compare `LinkCoalescer`'s
//!   immediate policy against.

use super::TimerKind;
use crate::types::{DeliveryPath, FlushPolicy, MsgId, Pid, Ts, Wire};
use crate::util::FxHashMap;

/// One local delivery effect. Beyond the paper-level `(m, gts)` pair it
/// carries the observability trace that rides the hot path by value (no
/// allocation): the white-box [`DeliveryPath`] classification, the
/// client's wall-clock submit stamp (0 when unstamped) and the node-local
/// per-stage timestamps (0 when unknown, e.g. on followers), so the
/// runtime can record end-to-end latency and stage waits
/// (submit → proposal → ack-quorum → commit → deliver) without asking the
/// protocol anything.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct DeliverEffect {
    pub m: MsgId,
    pub gts: Ts,
    pub path: DeliveryPath,
    /// client wall-clock submit stamp ([`crate::types::MsgMeta::submit_ns`])
    pub submit_ns: u64,
    /// node-local `now` when the local proposal was made
    pub proposal_at: u64,
    /// node-local `now` when the ack quorum completed
    pub quorum_at: u64,
    /// node-local `now` when the commit was applied
    pub commit_at: u64,
    /// node-local `now` of the delivery itself
    pub deliver_at: u64,
}

impl DeliverEffect {
    /// An untraced delivery: path unclassified, all stamps zero. What
    /// [`Outbox::deliver`] emits — the baselines and tests stay exact.
    pub fn untraced(m: MsgId, gts: Ts) -> Self {
        DeliverEffect {
            m,
            gts,
            path: DeliveryPath::Unclassified,
            submit_ns: 0,
            proposal_at: 0,
            quorum_at: 0,
            commit_at: 0,
            deliver_at: 0,
        }
    }
}

/// Effects sink passed to every [`Node`](super::Node) handler. Buffers
/// are drained (not dropped) by the runtimes and reused across events.
#[derive(Default)]
pub struct Outbox {
    pub(crate) sends: Vec<(Pid, Wire)>,
    pub(crate) delivers: Vec<DeliverEffect>,
    pub(crate) timers: Vec<(TimerKind, u64)>,
    /// durable journal records ([`crate::storage::Record`]); the owning
    /// runtime appends them to the node's WAL and commits them *before*
    /// the same cycle's sends reach the transport, so no promise leaves
    /// the process before it is recoverable
    pub(crate) records: Vec<crate::storage::Record>,
    /// staged recipient list for [`Outbox::send_staged`] (reused scratch)
    staged: Vec<Pid>,
}

impl Outbox {
    pub fn new() -> Self {
        Self::default()
    }

    /// Send `wire` to `to`. Nodes must not emit [`Wire::Batch`] frames
    /// themselves — batching belongs to the runtime flush.
    #[inline]
    pub fn send(&mut self, to: Pid, wire: Wire) {
        debug_assert!(!matches!(wire, Wire::Batch(_)), "nodes must not emit Batch frames");
        self.sends.push((to, wire));
    }

    /// Send one message to many recipients: `n - 1` shallow clones, the
    /// last recipient receives `wire` itself.
    pub fn send_to_many<I: IntoIterator<Item = Pid>>(&mut self, to: I, wire: Wire) {
        debug_assert!(!matches!(wire, Wire::Batch(_)), "nodes must not emit Batch frames");
        let mut it = to.into_iter();
        let Some(first) = it.next() else { return };
        let mut prev = first;
        for p in it {
            self.sends.push((prev, wire.clone()));
            prev = p;
        }
        self.sends.push((prev, wire));
    }

    /// Stage a recipient for the next [`Outbox::send_staged`] call. Used
    /// when the recipient list must be computed from data that ends up
    /// owned by the wire itself (e.g. `ACCEPT_ACK`'s ballot vector).
    #[inline]
    pub fn stage(&mut self, to: Pid) {
        self.staged.push(to);
    }

    /// Send `wire` to every staged recipient (clearing the stage):
    /// `n - 1` shallow clones, the last recipient receives `wire` itself.
    pub fn send_staged(&mut self, wire: Wire) {
        debug_assert!(!matches!(wire, Wire::Batch(_)), "nodes must not emit Batch frames");
        let n = self.staged.len();
        for i in 0..n.saturating_sub(1) {
            let to = self.staged[i];
            self.sends.push((to, wire.clone()));
        }
        if n > 0 {
            let to = self.staged[n - 1];
            self.sends.push((to, wire));
        }
        self.staged.clear();
    }

    /// Deliver application message `m` locally with global timestamp
    /// `gts` (the `deliver(m)` event of §II), untraced (path
    /// unclassified, no stamps) — used by the baselines and tests.
    #[inline]
    pub fn deliver(&mut self, m: MsgId, gts: Ts) {
        self.delivers.push(DeliverEffect::untraced(m, gts));
    }

    /// Deliver with the full observability trace (see [`DeliverEffect`]).
    /// The instrumented protocol (`wbcast`) uses this; the effect is a
    /// `Copy` value, so tracing adds no hot-path allocation.
    #[inline]
    pub fn deliver_traced(&mut self, eff: DeliverEffect) {
        self.delivers.push(eff);
    }

    /// Arm a timer to fire after `after_ns`.
    #[inline]
    pub fn timer(&mut self, kind: TimerKind, after_ns: u64) {
        self.timers.push((kind, after_ns));
    }

    /// Journal a durable record. The runtime persists it (and its
    /// cycle-mates) at the group-commit point ahead of the cycle's
    /// sends; runtimes without attached storage discard records.
    #[inline]
    pub fn record(&mut self, rec: crate::storage::Record) {
        self.records.push(rec);
    }

    pub fn is_empty(&self) -> bool {
        // staged counts: recipients staged without a send_staged would
        // otherwise leak invisibly into the next event's staged send
        self.sends.is_empty()
            && self.delivers.is_empty()
            && self.timers.is_empty()
            && self.records.is_empty()
            && self.staged.is_empty()
    }

    /// Drop all staged effects (buffers keep their capacity).
    pub fn clear(&mut self) {
        self.sends.clear();
        self.delivers.clear();
        self.timers.clear();
        self.records.clear();
        self.staged.clear();
    }

    // ---------- inspection (tests, probes) ----------
    pub fn sends(&self) -> &[(Pid, Wire)] {
        &self.sends
    }
    pub fn delivers(&self) -> &[DeliverEffect] {
        &self.delivers
    }
    pub fn timers(&self) -> &[(TimerKind, u64)] {
        &self.timers
    }
    pub fn records(&self) -> &[crate::storage::Record] {
        &self.records
    }
}

/// Upper bound on one coalesced frame's estimated wire size. The TCP
/// receiver rejects frames above 64 MiB (`net::read_frame`) and drops
/// the connection, so oversized batches are split into consecutive
/// frames well under that cap (per-destination FIFO is preserved —
/// consecutive chunks on the same link).
pub const MAX_FRAME_BYTES: usize = 8 << 20;

/// Reusable scratch state for grouping a flat `(destination, wire)` list
/// into per-destination frames. All maps/vectors retain capacity across
/// calls; only multi-wire destinations allocate (the `Vec<Wire>` moved
/// into the emitted [`Wire::Batch`] frame — one allocation per frame,
/// not per message).
///
/// The destination key `K` is a [`Pid`] in the simulator and the
/// single-node runtime; the sharded runtime coalesces per *link*
/// (`(from, to)` pid pair) because one endpoint's flush carries wires
/// originating at several local shard nodes.
#[derive(Default)]
pub struct Coalescer<K = Pid> {
    counts: FxHashMap<K, u32>,
    frames: FxHashMap<K, Vec<Wire>>,
    /// emission order: destinations at first occurrence; `Some(wire)`
    /// carries single-wire frames inline (no per-wire Vec allocation)
    order: Vec<(K, Option<Wire>)>,
}

impl<K: std::hash::Hash + Eq + Copy> Coalescer<K> {
    pub fn new() -> Self {
        // no `K: Default` bound needed: the maps' Default has none
        // alloc-ok: constructor; steady state reuses these buffers
        Coalescer { counts: FxHashMap::default(), frames: FxHashMap::default(), order: Vec::new() }
    }

    /// Number of frames `drain` would emit for `sends`.
    pub fn frame_count(&mut self, sends: &[(K, Wire)], coalesce: bool) -> usize {
        if !coalesce {
            return sends.len();
        }
        self.counts.clear();
        for &(to, _) in sends {
            *self.counts.entry(to).or_insert(0) += 1;
        }
        self.counts.len()
    }

    /// Drain `sends` into frames, calling `emit(to, frame)` once per
    /// destination in first-occurrence order. Multi-wire destinations are
    /// wrapped in [`Wire::Batch`] preserving their FIFO order; single-wire
    /// destinations receive the wire unwrapped. With `coalesce = false`
    /// every send is emitted as its own frame in the original order.
    pub fn drain<F: FnMut(K, Wire)>(&mut self, sends: &mut Vec<(K, Wire)>, coalesce: bool, mut emit: F) {
        if !coalesce || sends.len() <= 1 {
            for (to, wire) in sends.drain(..) {
                emit(to, wire);
            }
            return;
        }
        self.counts.clear();
        for &(to, _) in sends.iter() {
            *self.counts.entry(to).or_insert(0) += 1;
        }
        for (to, wire) in sends.drain(..) {
            if self.counts[&to] == 1 {
                self.order.push((to, Some(wire)));
            } else {
                let buf = self.frames.entry(to).or_default();
                if buf.is_empty() {
                    self.order.push((to, None));
                }
                buf.push(wire);
            }
        }
        for (to, single) in self.order.drain(..) {
            match single {
                Some(wire) => emit(to, wire),
                None => {
                    let batch = self.frames.remove(&to).expect("frame staged");
                    emit_batch_bounded(to, batch, &mut emit);
                }
            }
        }
    }
}

/// Emit `batch` as one `Wire::Batch` frame, splitting into consecutive
/// frames whenever the size estimate would exceed [`MAX_FRAME_BYTES`].
fn emit_batch_bounded<K: Copy, F: FnMut(K, Wire)>(to: K, batch: Vec<Wire>, emit: &mut F) {
    let total: usize = batch.iter().map(|w| w.size()).sum();
    if total <= MAX_FRAME_BYTES {
        emit(to, Wire::Batch(batch));
        return;
    }
    let mut chunk: Vec<Wire> = Vec::new(); // alloc-ok: oversized-frame split slow path
    let mut bytes = 0usize;
    for w in batch {
        let sz = w.size();
        if !chunk.is_empty() && bytes + sz > MAX_FRAME_BYTES {
            let frame = if chunk.len() == 1 { chunk.pop().unwrap() } else { Wire::Batch(std::mem::take(&mut chunk)) };
            emit(to, frame);
            chunk.clear();
            bytes = 0;
        }
        bytes += sz;
        chunk.push(w);
    }
    if !chunk.is_empty() {
        let frame = if chunk.len() == 1 { chunk.pop().unwrap() } else { Wire::Batch(chunk) };
        emit(to, frame);
    }
}

/// One link's pending, not-yet-flushed wires.
struct PendingLink {
    wires: Vec<Wire>,
    /// summed [`Wire::size`] estimate of `wires`
    bytes: usize,
    /// enqueue time of the oldest pending wire (the `max_delay` clock)
    since: u64,
}

/// Stateful per-link coalescing buffer enforcing a
/// [`FlushPolicy`]: wires pushed for the same destination accumulate
/// until the policy says the link must flush — immediately (the default
/// policy), when the oldest pending wire has waited `max_delay_us`, when
/// the link's estimated bytes reach `max_bytes`, or when the owning event
/// loop goes quiet (`flush_on_quiet`).
///
/// This is the single flush point shared by the inline single-shard
/// runtime, the sharded runtime's flusher thread and the simulator, so
/// all three exhibit the same batching behaviour for a given policy.
/// Per-link FIFO order is preserved unconditionally: wires leave in push
/// order, multi-wire flushes as one [`Wire::Batch`] frame (split below
/// [`MAX_FRAME_BYTES`], consecutive chunks on the same link).
///
/// The destination key `K` is a [`Pid`] for the simulator and the inline
/// runtime; the sharded flusher coalesces per `(from, to)` link because
/// one endpoint's flush carries wires originating at several local shard
/// nodes.
pub struct LinkCoalescer<K = Pid> {
    policy: FlushPolicy,
    /// `policy.max_bytes` clamped to the frame cap
    max_bytes: usize,
    pending: FxHashMap<K, PendingLink>,
    /// first-occurrence emission order; may hold stale keys (links that
    /// overflowed out early), skipped and dropped at the next flush
    order: Vec<K>,
    /// retired single-wire `Vec`s, reused so steady-state single-wire
    /// links allocate nothing
    pool: Vec<Vec<Wire>>,
}

impl<K: std::hash::Hash + Eq + Copy> LinkCoalescer<K> {
    pub fn new(policy: FlushPolicy) -> Self {
        LinkCoalescer {
            policy,
            max_bytes: policy.max_bytes.clamp(1, MAX_FRAME_BYTES),
            pending: FxHashMap::default(),
            order: Vec::new(), // alloc-ok: constructor
            pool: Vec::new(),  // alloc-ok: constructor
        }
    }

    pub fn policy(&self) -> FlushPolicy {
        self.policy
    }

    /// Queue one wire for `to`, stamped with the caller's clock. If the
    /// link's pending bytes reach the policy's `max_bytes` the link is
    /// flushed through `emit` right away (FIFO preserved — everything
    /// pending goes out ahead of any later push).
    pub fn push<F: FnMut(K, Wire)>(&mut self, now: u64, to: K, wire: Wire, emit: &mut F) {
        let sz = wire.size();
        let link = match self.pending.entry(to) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                self.order.push(to);
                e.insert(PendingLink { wires: self.pool.pop().unwrap_or_default(), bytes: 0, since: now })
            }
        };
        link.bytes += sz;
        link.wires.push(wire);
        if link.bytes >= self.max_bytes {
            self.emit_link(to, emit);
        }
    }

    /// The unified flush point, called once per event-loop cycle.
    /// `quiet` means the caller has no further input immediately pending
    /// (`flush_on_quiet` links flush on it). Links whose oldest wire has
    /// waited `max_delay` also flush; under the immediate policy every
    /// pending link flushes. Emission is in first-push order of the
    /// destinations.
    pub fn flush_cycle<F: FnMut(K, Wire)>(&mut self, now: u64, quiet: bool, emit: &mut F) {
        if self.pending.is_empty() {
            self.order.clear();
            return;
        }
        let all = self.policy.is_immediate() || (quiet && self.policy.flush_on_quiet);
        let delay = self.policy.max_delay_ns();
        let mut order = std::mem::take(&mut self.order);
        order.retain(|&to| {
            let Some(link) = self.pending.get(&to) else { return false };
            if all || now.saturating_sub(link.since) >= delay {
                self.emit_link(to, emit);
                false
            } else {
                true
            }
        });
        self.order = order;
    }

    /// Unconditionally drain every pending link (shutdown; never drop a
    /// wire that was handed to the coalescer).
    pub fn flush_all<F: FnMut(K, Wire)>(&mut self, emit: &mut F) {
        let mut order = std::mem::take(&mut self.order);
        for to in order.drain(..) {
            self.emit_link(to, emit);
        }
        self.order = order;
        debug_assert!(self.pending.is_empty(), "pending link missing from emission order");
    }

    /// Earliest `max_delay` expiry among pending links — the bound event
    /// loops put on their sleeps so held wires never outwait the policy.
    pub fn next_deadline(&self) -> Option<u64> {
        if self.pending.is_empty() {
            return None;
        }
        if self.policy.is_immediate() {
            return Some(0); // should have been flushed already; wake now
        }
        let delay = self.policy.max_delay_ns();
        self.pending.values().map(|l| l.since.saturating_add(delay)).min() // unordered-ok: min() fold
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Drop everything pending (crash simulation: unflushed wires die
    /// with the process).
    pub fn clear(&mut self) {
        // unordered-ok: buffer recycling only; nothing reaches the wire
        for (_, mut link) in self.pending.drain() {
            link.wires.clear();
            self.pool.push(link.wires);
        }
        self.order.clear();
    }

    /// Emit one link's pending wires: a lone wire goes out unwrapped, a
    /// multi-wire link as [`Wire::Batch`] frames bounded by
    /// [`MAX_FRAME_BYTES`].
    fn emit_link<F: FnMut(K, Wire)>(&mut self, to: K, emit: &mut F) {
        let Some(mut link) = self.pending.remove(&to) else { return };
        if link.wires.len() == 1 {
            let w = link.wires.pop().expect("single pending wire");
            self.pool.push(link.wires);
            emit(to, w);
        } else {
            emit_batch_bounded(to, link.wires, emit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Ballot, Gid, Ts};

    fn hb(n: u32) -> Wire {
        Wire::Heartbeat { bal: Ballot::new(n, Pid(0)) }
    }

    #[test]
    fn send_to_many_fans_out_once_per_recipient() {
        let mut out = Outbox::new();
        out.send_to_many([Pid(1), Pid(2), Pid(3)], hb(7));
        assert_eq!(out.sends().len(), 3);
        for (i, (to, w)) in out.sends().iter().enumerate() {
            assert_eq!(*to, Pid(i as u32 + 1));
            assert_eq!(*w, hb(7));
        }
        out.clear();
        out.send_to_many(std::iter::empty(), hb(1));
        assert!(out.is_empty());
    }

    #[test]
    fn staged_recipients_cleared_after_send() {
        let mut out = Outbox::new();
        out.stage(Pid(4));
        out.stage(Pid(5));
        out.send_staged(hb(1));
        assert_eq!(out.sends().len(), 2);
        out.send_staged(hb(2)); // empty stage: no sends
        assert_eq!(out.sends().len(), 2);
    }

    #[test]
    fn coalescer_groups_by_destination_preserving_fifo() {
        let mut c = Coalescer::new();
        let mut sends = vec![(Pid(1), hb(10)), (Pid(2), hb(20)), (Pid(1), hb(11)), (Pid(1), hb(12))];
        assert_eq!(c.frame_count(&sends, true), 2);
        assert_eq!(c.frame_count(&sends, false), 4);
        let mut got = Vec::new();
        c.drain(&mut sends, true, |to, w| got.push((to, w)));
        assert_eq!(got.len(), 2);
        // first-occurrence order: Pid(1) before Pid(2)
        assert_eq!(got[0].0, Pid(1));
        match &got[0].1 {
            Wire::Batch(inner) => assert_eq!(inner.as_slice(), &[hb(10), hb(11), hb(12)]),
            w => panic!("expected batch, got {w:?}"),
        }
        // single-wire destination is not wrapped
        assert_eq!(got[1], (Pid(2), hb(20)));
        assert!(sends.is_empty());
    }

    #[test]
    fn coalescer_off_preserves_exact_order() {
        let mut c = Coalescer::new();
        let mut sends = vec![(Pid(1), hb(1)), (Pid(1), hb(2)), (Pid(2), hb(3))];
        let mut got = Vec::new();
        c.drain(&mut sends, false, |to, w| got.push((to, w)));
        assert_eq!(got, vec![(Pid(1), hb(1)), (Pid(1), hb(2)), (Pid(2), hb(3))]);
    }

    #[test]
    fn oversized_batches_split_below_the_frame_cap() {
        use crate::types::{GidSet, MsgId, MsgMeta};
        // 5 × 3 MiB payloads: one destination, total ~15 MiB > cap (8 MiB)
        let big = |i: u32| Wire::Multicast {
            meta: MsgMeta::new(MsgId::new(1, i), GidSet::single(Gid(0)), vec![0u8; 3 << 20]),
        };
        let mut c = Coalescer::new();
        let mut sends: Vec<(Pid, Wire)> = (0..5).map(|i| (Pid(9), big(i))).collect();
        let mut got = Vec::new();
        c.drain(&mut sends, true, |to, w| got.push((to, w)));
        assert!(got.len() > 1, "oversized batch must split");
        let mut seen = Vec::new();
        for (to, frame) in &got {
            assert_eq!(*to, Pid(9));
            assert!(frame.size() <= MAX_FRAME_BYTES, "frame over cap: {}", frame.size());
            match frame {
                Wire::Batch(inner) => {
                    for w in inner {
                        let Wire::Multicast { meta } = w else { panic!() };
                        seen.push(meta.id.seq());
                    }
                }
                Wire::Multicast { meta } => seen.push(meta.id.seq()),
                w => panic!("unexpected {}", w.tag()),
            }
        }
        // FIFO across the split frames
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn coalescer_reuse_across_flushes() {
        let mut c = Coalescer::new();
        for round in 0..3u32 {
            let mut sends = vec![(Pid(1), hb(round)), (Pid(1), hb(round + 100))];
            let mut got = Vec::new();
            c.drain(&mut sends, true, |to, w| got.push((to, w)));
            assert_eq!(got.len(), 1);
            match &got[0].1 {
                Wire::Batch(inner) => assert_eq!(inner.len(), 2),
                w => panic!("expected batch, got {w:?}"),
            }
        }
    }

    #[test]
    fn link_coalescer_immediate_matches_classic_coalescer() {
        let sends = vec![(Pid(1), hb(10)), (Pid(2), hb(20)), (Pid(1), hb(11)), (Pid(1), hb(12))];
        let mut classic = Coalescer::new();
        let mut want = Vec::new();
        classic.drain(&mut sends.clone(), true, |to, w| want.push((to, w)));

        let mut lc = LinkCoalescer::new(FlushPolicy::immediate());
        let mut got = Vec::new();
        for (to, w) in sends {
            lc.push(7, to, w, &mut |to, f| got.push((to, f)));
        }
        lc.flush_cycle(7, true, &mut |to, f| got.push((to, f)));
        assert_eq!(got, want, "immediate policy must reproduce the per-cycle coalescer");
        assert!(lc.is_empty());
    }

    #[test]
    fn link_coalescer_quiet_flush_beats_the_delay_window() {
        let mut lc = LinkCoalescer::new(FlushPolicy::adaptive(1_000));
        let mut got = Vec::new();
        lc.push(0, Pid(1), hb(1), &mut |to, f| got.push((to, f)));
        // not quiet, delay not expired: the wire is held
        lc.flush_cycle(0, false, &mut |to, f| got.push((to, f)));
        assert!(got.is_empty());
        assert_eq!(lc.next_deadline(), Some(1_000_000));
        // quiet: flush_on_quiet releases it before the deadline
        lc.flush_cycle(10, true, &mut |to, f| got.push((to, f)));
        assert_eq!(got, vec![(Pid(1), hb(1))]);
        assert_eq!(lc.next_deadline(), None);
    }

    #[test]
    fn link_coalescer_holds_until_deadline_without_quiet_flush() {
        let policy = FlushPolicy { max_delay_us: 100, max_bytes: usize::MAX, flush_on_quiet: false };
        let mut lc = LinkCoalescer::new(policy);
        let mut got = Vec::new();
        lc.push(0, Pid(3), hb(1), &mut |to, f| got.push((to, f)));
        lc.push(40_000, Pid(3), hb(2), &mut |to, f| got.push((to, f)));
        // quiet flushes are ignored by this policy; the window keeps filling
        lc.flush_cycle(60_000, true, &mut |to, f| got.push((to, f)));
        assert!(got.is_empty(), "flush_on_quiet=false must hold the link");
        // the deadline runs from the OLDEST pending wire
        assert_eq!(lc.next_deadline(), Some(100_000));
        lc.flush_cycle(100_000, false, &mut |to, f| got.push((to, f)));
        assert_eq!(got.len(), 1);
        match &got[0].1 {
            Wire::Batch(inner) => assert_eq!(inner.as_slice(), &[hb(1), hb(2)]),
            w => panic!("expected batch, got {w:?}"),
        }
    }

    #[test]
    fn link_coalescer_max_bytes_overflow_flushes_early_in_fifo_order() {
        let unit = hb(0).size();
        let policy = FlushPolicy { max_delay_us: 1_000_000, max_bytes: 2 * unit, flush_on_quiet: false };
        let mut lc = LinkCoalescer::new(policy);
        let mut got = Vec::new();
        for i in 0..5u32 {
            lc.push(0, Pid(1), hb(i), &mut |to, f| got.push((to, f)));
        }
        // pushes 0..2 and 2..4 overflowed out as two batches; wire 4 is held
        assert_eq!(got.len(), 2);
        let mut seen = Vec::new();
        for (_, f) in &got {
            match f {
                Wire::Batch(inner) => seen.extend(inner.iter().cloned()),
                w => seen.push(w.clone()),
            }
        }
        assert_eq!(seen, (0..4).map(hb).collect::<Vec<_>>(), "overflow flushes must preserve FIFO");
        assert!(!lc.is_empty());
        lc.flush_all(&mut |to, f| got.push((to, f)));
        assert_eq!(got.len(), 3);
        assert_eq!(got[2], (Pid(1), hb(4)));
        assert!(lc.is_empty());
    }

    #[test]
    fn link_coalescer_respects_the_frame_cap_at_max_bytes_boundaries() {
        use crate::types::{GidSet, MsgId, MsgMeta};
        // 5 x 3 MiB wires with max_bytes at the frame cap: overflow fires
        // at >= 8 MiB pending, and the splitter still bounds every frame
        let big = |i: u32| Wire::Multicast {
            meta: MsgMeta::new(MsgId::new(1, i), GidSet::single(Gid(0)), vec![0u8; 3 << 20]),
        };
        let policy = FlushPolicy { max_delay_us: 1_000_000, max_bytes: MAX_FRAME_BYTES, flush_on_quiet: false };
        let mut lc = LinkCoalescer::new(policy);
        let mut got = Vec::new();
        for i in 0..5 {
            lc.push(0, Pid(9), big(i), &mut |to, f| got.push((to, f)));
        }
        lc.flush_all(&mut |to, f| got.push((to, f)));
        assert!(got.len() > 1, "15 MiB pending must not leave as one frame");
        let mut seen = Vec::new();
        for (to, frame) in &got {
            assert_eq!(*to, Pid(9));
            assert!(frame.size() <= MAX_FRAME_BYTES, "frame over cap: {}", frame.size());
            match frame {
                Wire::Batch(inner) => {
                    for w in inner {
                        let Wire::Multicast { meta } = w else { panic!() };
                        seen.push(meta.id.seq());
                    }
                }
                Wire::Multicast { meta } => seen.push(meta.id.seq()),
                w => panic!("unexpected {}", w.tag()),
            }
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4], "FIFO across overflow + splitter frames");
    }

    #[test]
    fn link_coalescer_clear_drops_pending() {
        let mut lc = LinkCoalescer::new(FlushPolicy::adaptive(1_000));
        let mut got = Vec::new();
        lc.push(0, Pid(1), hb(1), &mut |to, f| got.push((to, f)));
        lc.flush_cycle(0, false, &mut |to, f| got.push((to, f)));
        assert!(!lc.is_empty());
        lc.clear();
        assert!(lc.is_empty());
        lc.flush_all(&mut |to, f| got.push((to, f)));
        assert!(got.is_empty());
    }

    #[test]
    fn outbox_effect_kinds_land_in_their_buffers() {
        let mut out = Outbox::new();
        out.send(Pid(1), hb(1));
        out.deliver(MsgId::new(1, 1), Ts::new(3, Gid(0)));
        out.timer(TimerKind::LssTick, 500);
        assert_eq!(out.sends().len(), 1);
        assert_eq!(out.delivers(), &[DeliverEffect::untraced(MsgId::new(1, 1), Ts::new(3, Gid(0)))]);
        assert_eq!(out.timers(), &[(TimerKind::LssTick, 500)]);
        assert!(!out.is_empty());
        out.clear();
        assert!(out.is_empty());
    }
}
