//! Skeen's protocol (paper Fig. 1): genuine atomic multicast among
//! *singleton reliable groups* (`f = 0`).
//!
//! Each process is the sole (reliable) member of its group. Messages get
//! Lamport-style `(clock, group)` timestamps: on MULTICAST the process
//! proposes a local timestamp; once PROPOSE messages from all destination
//! groups arrive, the global timestamp is their maximum. A committed
//! message is delivered when every still-PROPOSED message has a local
//! timestamp above its global timestamp (the convoy condition, line 17).
//!
//! Collision-free latency 2δ (MULTICAST, PROPOSE); failure-free 4δ due to
//! the convoy effect (Fig. 2).

use crate::protocols::{Action, Node, TimerKind};
use crate::types::{Gid, MsgId, MsgMeta, Phase, Pid, Topology, Ts, Wire};
use std::collections::{BTreeSet, HashMap};

struct Entry {
    meta: MsgMeta,
    phase: Phase,
    lts: Ts,
    gts: Ts,
    delivered: bool,
    /// local-timestamp proposals received so far, per destination group
    proposals: HashMap<Gid, Ts>,
}

/// One Skeen process = one singleton group.
pub struct SkeenNode {
    pid: Pid,
    gid: Gid,
    topo: Topology,
    clock: u64,
    entries: HashMap<MsgId, Entry>,
    /// (lts, m) of messages in the PROPOSED phase — the delivery frontier
    pending: BTreeSet<(Ts, MsgId)>,
    /// (gts, m) of committed, undelivered messages
    committed: BTreeSet<(Ts, MsgId)>,
    /// number of messages delivered (for tests/inspection)
    pub delivered_count: u64,
}

impl SkeenNode {
    pub fn new(pid: Pid, topo: Topology) -> Self {
        assert_eq!(topo.f, 0, "Skeen's protocol requires singleton reliable groups");
        let gid = topo.group_of(pid).expect("SkeenNode must be a group member");
        SkeenNode {
            pid,
            gid,
            topo,
            clock: 0,
            entries: HashMap::new(),
            pending: BTreeSet::new(),
            committed: BTreeSet::new(),
            delivered_count: 0,
        }
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Deliver every committed message whose global timestamp lies below
    /// the pending frontier, in global-timestamp order (Fig. 1 line 17).
    fn try_deliver(&mut self, acts: &mut Vec<Action>) {
        loop {
            let Some(&(gts, m)) = self.committed.iter().next() else { break };
            if let Some(&(frontier, _)) = self.pending.iter().next() {
                if frontier <= gts {
                    break; // an uncommitted message may still get a lower gts
                }
            }
            self.committed.remove(&(gts, m));
            let e = self.entries.get_mut(&m).expect("committed entry");
            debug_assert!(!e.delivered);
            e.delivered = true;
            self.delivered_count += 1;
            acts.push(Action::Deliver(m, gts));
            acts.push(Action::Send(Pid(m.client()), Wire::Delivered { m, g: self.gid, gts }));
        }
    }
}

impl Node for SkeenNode {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, _now: u64) -> Vec<Action> {
        vec![]
    }

    fn on_wire(&mut self, _from: Pid, wire: Wire, _now: u64) -> Vec<Action> {
        let mut acts = Vec::new();
        match wire {
            // Fig. 1 line 8: assign a local timestamp and broadcast it to
            // the destination groups.
            Wire::Multicast { meta } => {
                debug_assert!(meta.dest.contains(self.gid), "genuineness: not a destination");
                if let Some(e) = self.entries.get(&meta.id) {
                    if e.phase != Phase::Start {
                        // duplicate (client retransmission): re-send our
                        // proposal so a lost PROPOSE cannot stall the
                        // message; re-notify if already delivered
                        if e.phase == Phase::Proposed {
                            for g in e.meta.dest.iter() {
                                let to = self.topo.initial_leader(g);
                                acts.push(Action::Send(to, Wire::Propose { m: meta.id, g: self.gid, lts: e.lts }));
                            }
                        } else if e.delivered {
                            acts.push(Action::Send(
                                Pid(meta.id.client()),
                                Wire::Delivered { m: meta.id, g: self.gid, gts: e.gts },
                            ));
                        }
                        return acts;
                    }
                    // else: entry holds parked remote proposals (a PROPOSE
                    // overtook the MULTICAST) — fall through and propose,
                    // keeping the parked proposals.
                }
                self.clock += 1;
                let lts = Ts::new(self.clock, self.gid);
                let id = meta.id;
                let dest = meta.dest;
                let parked = self.entries.remove(&id).map(|e| e.proposals).unwrap_or_default();
                self.entries.insert(
                    id,
                    Entry { meta, phase: Phase::Proposed, lts, gts: Ts::BOT, delivered: false, proposals: parked },
                );
                self.pending.insert((lts, id));
                for g in dest.iter() {
                    let to = self.topo.initial_leader(g); // singleton group
                    acts.push(Action::Send(to, Wire::Propose { m: id, g: self.gid, lts }));
                }
                // the self-send above delivers our own PROPOSE back to us,
                // which (together with any parked proposals) triggers the
                // completeness check in the Propose handler
            }
            // Fig. 1 line 13: collect proposals; once all destinations
            // proposed, commit with the maximal timestamp.
            Wire::Propose { m, g, lts } => {
                let Some(e) = self.entries.get_mut(&m) else {
                    // PROPOSE raced ahead of MULTICAST: remember it.
                    // (With FIFO channels this can only happen for remote
                    // proposals, which is fine — the entry is created on
                    // MULTICAST; park the proposal in a fresh entry.)
                    let mut proposals = HashMap::new();
                    proposals.insert(g, lts);
                    self.entries.insert(
                        m,
                        Entry {
                            meta: MsgMeta::new(m, crate::types::GidSet::EMPTY, vec![]),
                            phase: Phase::Start,
                            lts: Ts::BOT,
                            gts: Ts::BOT,
                            delivered: false,
                            proposals,
                        },
                    );
                    return acts;
                };
                e.proposals.insert(g, lts);
                if e.phase != Phase::Proposed {
                    return acts; // not yet proposed locally, or already done
                }
                if e.meta.dest.iter().all(|g| e.proposals.contains_key(&g)) {
                    let gts = e.meta.dest.iter().map(|g| e.proposals[&g]).max().unwrap();
                    e.gts = gts;
                    e.phase = Phase::Committed;
                    let lts = e.lts;
                    self.clock = self.clock.max(gts.time()); // line 15
                    self.pending.remove(&(lts, m));
                    self.committed.insert((gts, m));
                    self.try_deliver(&mut acts);
                }
            }
            _ => {}
        }
        acts
    }

    fn on_timer(&mut self, _timer: TimerKind, _now: u64) -> Vec<Action> {
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::GidSet;

    fn mcast(node: &mut SkeenNode, id: MsgId, dest: GidSet) -> Vec<Action> {
        node.on_wire(Pid(99), Wire::Multicast { meta: MsgMeta::new(id, dest, vec![]) }, 0)
    }

    #[test]
    fn solo_message_commits_and_delivers() {
        let topo = Topology::new(2, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let mut n1 = SkeenNode::new(Pid(1), topo.clone());
        let m = MsgId::new(99, 1);
        let dest = GidSet::from_iter([Gid(0), Gid(1)]);

        let a0 = mcast(&mut n0, m, dest);
        let a1 = mcast(&mut n1, m, dest);
        // each sends PROPOSE to both destinations
        assert_eq!(a0.len(), 2);
        assert_eq!(a1.len(), 2);

        // deliver all proposals to n0
        let mut out = Vec::new();
        out.extend(n0.on_wire(Pid(0), Wire::Propose { m, g: Gid(0), lts: Ts::new(1, Gid(0)) }, 1));
        out.extend(n0.on_wire(Pid(1), Wire::Propose { m, g: Gid(1), lts: Ts::new(1, Gid(1)) }, 1));
        let delivered: Vec<_> = out.iter().filter(|a| matches!(a, Action::Deliver(..))).collect();
        assert_eq!(delivered.len(), 1);
        // gts = max((1,g0),(1,g1)) = (1,g1)
        match delivered[0] {
            Action::Deliver(mm, gts) => {
                assert_eq!(*mm, m);
                assert_eq!(*gts, Ts::new(1, Gid(1)));
            }
            _ => unreachable!(),
        }
        // client notified
        assert!(out.iter().any(|a| matches!(a, Action::Send(Pid(99), Wire::Delivered { .. }))));
        assert_eq!(n0.clock(), 1);
    }

    #[test]
    fn convoy_blocks_delivery_until_conflicting_commit() {
        // m committed with gts=(5,g1); m' proposed locally with lts=(2,g0):
        // m must wait for m'.
        let topo = Topology::new(2, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let m = MsgId::new(99, 1);
        let m2 = MsgId::new(98, 1);
        let dest = GidSet::from_iter([Gid(0), Gid(1)]);

        mcast(&mut n0, m, dest); // lts (1,g0)
        mcast(&mut n0, m2, dest); // lts (2,g0)
        n0.on_wire(Pid(0), Wire::Propose { m, g: Gid(0), lts: Ts::new(1, Gid(0)) }, 1);
        let out = n0.on_wire(Pid(1), Wire::Propose { m, g: Gid(1), lts: Ts::new(5, Gid(1)) }, 1);
        // m is committed with gts (5,g1) but m2 (lts (2,g0)) blocks it
        assert!(out.iter().all(|a| !matches!(a, Action::Deliver(..))));
        // clock advanced to 5 by line 15
        assert_eq!(n0.clock(), 5);

        // commit m2 with gts (7,g1): both deliver, in gts order m(5) then m2(7)
        n0.on_wire(Pid(0), Wire::Propose { m: m2, g: Gid(0), lts: Ts::new(2, Gid(0)) }, 2);
        let out = n0.on_wire(Pid(1), Wire::Propose { m: m2, g: Gid(1), lts: Ts::new(7, Gid(1)) }, 2);
        let delivered: Vec<MsgId> = out
            .iter()
            .filter_map(|a| if let Action::Deliver(mm, _) = a { Some(*mm) } else { None })
            .collect();
        assert_eq!(delivered, vec![m, m2]);
    }

    #[test]
    fn new_multicast_after_commit_gets_higher_lts() {
        // after committing m with gts (5,g1), the clock is 5, so a new
        // message gets lts (6,g0) > gts — it can never undercut m.
        let topo = Topology::new(2, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let m = MsgId::new(99, 1);
        mcast(&mut n0, m, GidSet::from_iter([Gid(0), Gid(1)]));
        n0.on_wire(Pid(0), Wire::Propose { m, g: Gid(0), lts: Ts::new(1, Gid(0)) }, 1);
        n0.on_wire(Pid(1), Wire::Propose { m, g: Gid(1), lts: Ts::new(5, Gid(1)) }, 1);
        let m2 = MsgId::new(98, 1);
        let acts = mcast(&mut n0, m2, GidSet::from_iter([Gid(0)]));
        match &acts[0] {
            Action::Send(_, Wire::Propose { lts, .. }) => assert_eq!(*lts, Ts::new(6, Gid(0))),
            a => panic!("unexpected {a:?}"),
        }
    }

    #[test]
    fn duplicate_multicast_reproposes_or_reacks() {
        let topo = Topology::new(1, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let m = MsgId::new(99, 1);
        let dest = GidSet::single(Gid(0));
        mcast(&mut n0, m, dest);
        // still proposed: duplicate triggers PROPOSE re-send
        let acts = mcast(&mut n0, m, dest);
        assert!(acts.iter().any(|a| matches!(a, Action::Send(_, Wire::Propose { .. }))));
        // commit + deliver via self proposal
        n0.on_wire(Pid(0), Wire::Propose { m, g: Gid(0), lts: Ts::new(1, Gid(0)) }, 1);
        // duplicate after delivery: re-notify the client
        let acts = mcast(&mut n0, m, dest);
        assert!(acts.iter().any(|a| matches!(a, Action::Send(Pid(99), Wire::Delivered { .. }))));
    }

    #[test]
    fn single_group_is_atomic_broadcast() {
        // dest = {g0} — the protocol degenerates to immediate delivery
        let topo = Topology::new(1, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo);
        let m = MsgId::new(99, 1);
        mcast(&mut n0, m, GidSet::single(Gid(0)));
        let out = n0.on_wire(Pid(0), Wire::Propose { m, g: Gid(0), lts: Ts::new(1, Gid(0)) }, 1);
        assert!(out.iter().any(|a| matches!(a, Action::Deliver(..))));
    }
}
