//! Skeen's protocol (paper Fig. 1): genuine atomic multicast among
//! *singleton reliable groups* (`f = 0`).
//!
//! Each process is the sole (reliable) member of its group. Messages get
//! Lamport-style `(clock, group)` timestamps: on MULTICAST the process
//! proposes a local timestamp; once PROPOSE messages from all destination
//! groups arrive, the global timestamp is their maximum. A committed
//! message is delivered when every still-PROPOSED message has a local
//! timestamp above its global timestamp (the convoy condition, line 17).
//!
//! Collision-free latency 2δ (MULTICAST, PROPOSE); failure-free 4δ due to
//! the convoy effect (Fig. 2).

use crate::protocols::{Node, Outbox, TimerKind};
use crate::types::{Gid, MsgId, MsgMeta, Phase, Pid, Topology, Ts, Wire};
use std::collections::{BTreeSet, HashMap};

struct Entry {
    meta: MsgMeta,
    phase: Phase,
    lts: Ts,
    gts: Ts,
    delivered: bool,
    /// local-timestamp proposals received so far, per destination group
    proposals: HashMap<Gid, Ts>,
}

/// One Skeen process = one singleton group.
pub struct SkeenNode {
    pid: Pid,
    gid: Gid,
    topo: Topology,
    clock: u64,
    entries: HashMap<MsgId, Entry>,
    /// (lts, m) of messages in the PROPOSED phase — the delivery frontier
    pending: BTreeSet<(Ts, MsgId)>,
    /// (gts, m) of committed, undelivered messages
    committed: BTreeSet<(Ts, MsgId)>,
    /// number of messages delivered (for tests/inspection)
    pub delivered_count: u64,
}

impl SkeenNode {
    pub fn new(pid: Pid, topo: Topology) -> Self {
        assert_eq!(topo.f, 0, "Skeen's protocol requires singleton reliable groups");
        let gid = topo.group_of(pid).expect("SkeenNode must be a group member");
        SkeenNode {
            pid,
            gid,
            topo,
            clock: 0,
            entries: HashMap::new(),
            pending: BTreeSet::new(),
            committed: BTreeSet::new(),
            delivered_count: 0,
        }
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Deliver every committed message whose global timestamp lies below
    /// the pending frontier, in global-timestamp order (Fig. 1 line 17).
    fn try_deliver(&mut self, out: &mut Outbox) {
        loop {
            let Some(&(gts, m)) = self.committed.iter().next() else { break };
            if let Some(&(frontier, _)) = self.pending.iter().next() {
                if frontier <= gts {
                    break; // an uncommitted message may still get a lower gts
                }
            }
            self.committed.remove(&(gts, m));
            let e = self.entries.get_mut(&m).expect("committed entry");
            debug_assert!(!e.delivered);
            e.delivered = true;
            self.delivered_count += 1;
            out.deliver(m, gts);
            out.send(Pid(m.client()), Wire::Delivered { m, g: self.gid, gts });
        }
    }
}

impl Node for SkeenNode {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, _now: u64, _out: &mut Outbox) {}

    fn on_wire(&mut self, _from: Pid, wire: Wire, _now: u64, out: &mut Outbox) {
        match wire {
            // Fig. 1 line 8: assign a local timestamp and broadcast it to
            // the destination groups.
            Wire::Multicast { meta } => {
                debug_assert!(meta.dest.contains(self.gid), "genuineness: not a destination");
                if let Some(e) = self.entries.get(&meta.id) {
                    if e.phase != Phase::Start {
                        // duplicate (client retransmission): re-send our
                        // proposal so a lost PROPOSE cannot stall the
                        // message; re-notify if already delivered
                        if e.phase == Phase::Proposed {
                            for g in e.meta.dest.iter() {
                                let to = self.topo.initial_leader(g);
                                out.send(to, Wire::Propose { m: meta.id, g: self.gid, lts: e.lts });
                            }
                        } else if e.delivered {
                            out.send(
                                Pid(meta.id.client()),
                                Wire::Delivered { m: meta.id, g: self.gid, gts: e.gts },
                            );
                        }
                        return;
                    }
                    // else: entry holds parked remote proposals (a PROPOSE
                    // overtook the MULTICAST) — fall through and propose,
                    // keeping the parked proposals.
                }
                self.clock += 1;
                let lts = Ts::new(self.clock, self.gid);
                let id = meta.id;
                let dest = meta.dest;
                let parked = self.entries.remove(&id).map(|e| e.proposals).unwrap_or_default();
                self.entries.insert(
                    id,
                    Entry { meta, phase: Phase::Proposed, lts, gts: Ts::BOT, delivered: false, proposals: parked },
                );
                self.pending.insert((lts, id));
                for g in dest.iter() {
                    let to = self.topo.initial_leader(g); // singleton group
                    out.send(to, Wire::Propose { m: id, g: self.gid, lts });
                }
                // the self-send above delivers our own PROPOSE back to us,
                // which (together with any parked proposals) triggers the
                // completeness check in the Propose handler
            }
            // Fig. 1 line 13: collect proposals; once all destinations
            // proposed, commit with the maximal timestamp.
            Wire::Propose { m, g, lts } => {
                let Some(e) = self.entries.get_mut(&m) else {
                    // PROPOSE raced ahead of MULTICAST: remember it.
                    // (With FIFO channels this can only happen for remote
                    // proposals, which is fine — the entry is created on
                    // MULTICAST; park the proposal in a fresh entry.)
                    let mut proposals = HashMap::new();
                    proposals.insert(g, lts);
                    self.entries.insert(
                        m,
                        Entry {
                            meta: MsgMeta::new(m, crate::types::GidSet::EMPTY, vec![]),
                            phase: Phase::Start,
                            lts: Ts::BOT,
                            gts: Ts::BOT,
                            delivered: false,
                            proposals,
                        },
                    );
                    return;
                };
                e.proposals.insert(g, lts);
                if e.phase != Phase::Proposed {
                    return; // not yet proposed locally, or already done
                }
                if e.meta.dest.iter().all(|g| e.proposals.contains_key(&g)) {
                    let gts = e.meta.dest.iter().map(|g| e.proposals[&g]).max().unwrap();
                    e.gts = gts;
                    e.phase = Phase::Committed;
                    let lts = e.lts;
                    self.clock = self.clock.max(gts.time()); // line 15
                    self.pending.remove(&(lts, m));
                    self.committed.insert((gts, m));
                    self.try_deliver(out);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, _timer: TimerKind, _now: u64, _out: &mut Outbox) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::GidSet;

    fn mcast(node: &mut SkeenNode, id: MsgId, dest: GidSet) -> Outbox {
        let mut out = Outbox::new();
        node.on_wire(Pid(99), Wire::Multicast { meta: MsgMeta::new(id, dest, vec![]) }, 0, &mut out);
        out
    }

    fn propose(node: &mut SkeenNode, from: Pid, m: MsgId, g: Gid, lts: Ts) -> Outbox {
        let mut out = Outbox::new();
        node.on_wire(from, Wire::Propose { m, g, lts }, 1, &mut out);
        out
    }

    #[test]
    fn solo_message_commits_and_delivers() {
        let topo = Topology::new(2, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let mut n1 = SkeenNode::new(Pid(1), topo.clone());
        let m = MsgId::new(99, 1);
        let dest = GidSet::from_iter([Gid(0), Gid(1)]);

        let a0 = mcast(&mut n0, m, dest);
        let a1 = mcast(&mut n1, m, dest);
        // each sends PROPOSE to both destinations
        assert_eq!(a0.sends().len(), 2);
        assert_eq!(a1.sends().len(), 2);

        // deliver all proposals to n0
        propose(&mut n0, Pid(0), m, Gid(0), Ts::new(1, Gid(0)));
        let out = propose(&mut n0, Pid(1), m, Gid(1), Ts::new(1, Gid(1)));
        // gts = max((1,g0),(1,g1)) = (1,g1)
        assert_eq!(out.delivers().len(), 1);
        assert_eq!((out.delivers()[0].m, out.delivers()[0].gts), (m, Ts::new(1, Gid(1))));
        // client notified
        assert!(out.sends().iter().any(|(to, w)| *to == Pid(99) && matches!(w, Wire::Delivered { .. })));
        assert_eq!(n0.clock(), 1);
    }

    #[test]
    fn convoy_blocks_delivery_until_conflicting_commit() {
        // m committed with gts=(5,g1); m' proposed locally with lts=(2,g0):
        // m must wait for m'.
        let topo = Topology::new(2, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let m = MsgId::new(99, 1);
        let m2 = MsgId::new(98, 1);
        let dest = GidSet::from_iter([Gid(0), Gid(1)]);

        mcast(&mut n0, m, dest); // lts (1,g0)
        mcast(&mut n0, m2, dest); // lts (2,g0)
        propose(&mut n0, Pid(0), m, Gid(0), Ts::new(1, Gid(0)));
        let out = propose(&mut n0, Pid(1), m, Gid(1), Ts::new(5, Gid(1)));
        // m is committed with gts (5,g1) but m2 (lts (2,g0)) blocks it
        assert!(out.delivers().is_empty());
        // clock advanced to 5 by line 15
        assert_eq!(n0.clock(), 5);

        // commit m2 with gts (7,g1): both deliver, in gts order m(5) then m2(7)
        propose(&mut n0, Pid(0), m2, Gid(0), Ts::new(2, Gid(0)));
        let out = propose(&mut n0, Pid(1), m2, Gid(1), Ts::new(7, Gid(1)));
        let delivered: Vec<MsgId> = out.delivers().iter().map(|d| d.m).collect();
        assert_eq!(delivered, vec![m, m2]);
    }

    #[test]
    fn new_multicast_after_commit_gets_higher_lts() {
        // after committing m with gts (5,g1), the clock is 5, so a new
        // message gets lts (6,g0) > gts — it can never undercut m.
        let topo = Topology::new(2, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let m = MsgId::new(99, 1);
        mcast(&mut n0, m, GidSet::from_iter([Gid(0), Gid(1)]));
        propose(&mut n0, Pid(0), m, Gid(0), Ts::new(1, Gid(0)));
        propose(&mut n0, Pid(1), m, Gid(1), Ts::new(5, Gid(1)));
        let m2 = MsgId::new(98, 1);
        let out = mcast(&mut n0, m2, GidSet::from_iter([Gid(0)]));
        match &out.sends()[0] {
            (_, Wire::Propose { lts, .. }) => assert_eq!(*lts, Ts::new(6, Gid(0))),
            (_, w) => panic!("unexpected {w:?}"),
        }
    }

    #[test]
    fn duplicate_multicast_reproposes_or_reacks() {
        let topo = Topology::new(1, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo.clone());
        let m = MsgId::new(99, 1);
        let dest = GidSet::single(Gid(0));
        mcast(&mut n0, m, dest);
        // still proposed: duplicate triggers PROPOSE re-send
        let out = mcast(&mut n0, m, dest);
        assert!(out.sends().iter().any(|(_, w)| matches!(w, Wire::Propose { .. })));
        // commit + deliver via self proposal
        propose(&mut n0, Pid(0), m, Gid(0), Ts::new(1, Gid(0)));
        // duplicate after delivery: re-notify the client
        let out = mcast(&mut n0, m, dest);
        assert!(out.sends().iter().any(|(to, w)| *to == Pid(99) && matches!(w, Wire::Delivered { .. })));
    }

    #[test]
    fn single_group_is_atomic_broadcast() {
        // dest = {g0} — the protocol degenerates to immediate delivery
        let topo = Topology::new(1, 0);
        let mut n0 = SkeenNode::new(Pid(0), topo);
        let m = MsgId::new(99, 1);
        mcast(&mut n0, m, GidSet::single(Gid(0)));
        let out = propose(&mut n0, Pid(0), m, Gid(0), Ts::new(1, Gid(0)));
        assert_eq!(out.delivers().len(), 1);
    }
}
