//! The unified end-of-run stats report: one snapshot type with a
//! human-readable `Display` and a hand-rolled JSON rendering, shared by
//! `serve`'s shutdown summary, the e2e tests and the benches (which all
//! used to format the same counters ad hoc).

use super::CoreMetrics;
use crate::coordinator::CoordStats;
use crate::net::NetStats;
use crate::storage::StorageStats;
use std::fmt;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

/// A point-in-time snapshot of an endpoint's counters. Build with
/// [`StatsReport::new`], extend with [`StatsReport::with_storage`] /
/// [`StatsReport::with_core`], then `{report}` or
/// [`StatsReport::to_json`].
#[derive(Default)]
pub struct StatsReport {
    /// (name, value) pairs in render order, grouped by the `coord.` /
    /// `net.` / `storage.` / `obs.` name prefix.
    fields: Vec<(&'static str, u64)>,
}

impl StatsReport {
    /// Snapshot the coordinator and transport counters.
    pub fn new(coord: &CoordStats, net: &NetStats) -> Self {
        let fields = vec![
            ("coord.wires_in", coord.wires_in.load(Relaxed)),
            ("coord.wires_out", coord.wires_out.load(Relaxed)),
            ("coord.self_wires", coord.self_wires.load(Relaxed)),
            ("coord.delivered", coord.delivered.load(Relaxed)),
            ("coord.dropped_frames", coord.dropped_frames.load(Relaxed)),
            ("net.dropped_frames", net.dropped_frames.load(Relaxed)),
            ("net.probes_alive", net.probes_alive.load(Relaxed)),
            ("net.probes_dead", net.probes_dead.load(Relaxed)),
            ("net.reconnects_attempted", net.reconnects_attempted.load(Relaxed)),
            ("net.reconnects_succeeded", net.reconnects_succeeded.load(Relaxed)),
            ("net.transport_fallbacks", net.transport_fallbacks.load(Relaxed)),
        ];
        StatsReport { fields }
    }

    /// Add the storage counters, summed across hosted shards.
    pub fn with_storage(mut self, shards: &[Arc<StorageStats>]) -> Self {
        let sum = |f: fn(&StorageStats) -> u64| shards.iter().map(|s| f(s)).sum::<u64>();
        self.fields.extend([
            ("storage.records_appended", sum(|s| s.records_appended.load(Relaxed))),
            ("storage.bytes_appended", sum(|s| s.bytes_appended.load(Relaxed))),
            ("storage.commits", sum(|s| s.commits.load(Relaxed))),
            ("storage.fsyncs", sum(|s| s.fsyncs.load(Relaxed))),
            ("storage.rotations", sum(|s| s.rotations.load(Relaxed))),
            ("storage.snapshots_written", sum(|s| s.snapshots_written.load(Relaxed))),
            ("storage.poisoned", sum(|s| s.poisoned.load(Relaxed))),
        ]);
        self
    }

    /// Add the white-box delivery split and latency summary (the
    /// latency quantiles read [`super::SharedHist::peek`], so a
    /// concurrently scraping exporter's interval window is undisturbed).
    pub fn with_core(mut self, core: &CoreMetrics) -> Self {
        self.fields.extend([
            ("obs.delivered_fast", core.path[crate::types::DeliveryPath::Fast as usize].load(Relaxed)),
            ("obs.delivered_concurrent", core.path[crate::types::DeliveryPath::Concurrent as usize].load(Relaxed)),
            ("obs.delivered_recovery", core.path[crate::types::DeliveryPath::Recovery as usize].load(Relaxed)),
            ("obs.delivered_unclassified", core.path[crate::types::DeliveryPath::Unclassified as usize].load(Relaxed)),
            ("obs.distinct_clients", core.clients.estimate()),
        ]);
        let lat = core.e2e.peek();
        if lat.count() > 0 {
            self.fields.extend([("obs.latency_p50_ns", lat.p50()), ("obs.latency_p99_ns", lat.p99())]);
        }
        self
    }

    /// Look up one field by its full dotted name (test convenience).
    pub fn get(&self, name: &str) -> Option<u64> {
        self.fields.iter().find(|(n, _)| *n == name).map(|&(_, v)| v)
    }

    /// One flat JSON object: `{"coord.wires_in":12,...}`. Hand-rolled —
    /// every key is a known `&'static str` and every value a `u64`, so
    /// no escaping is needed.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(self.fields.len() * 32 + 2);
        s.push('{');
        for (i, (name, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{name}\":{v}"));
        }
        s.push('}');
        s
    }
}

impl fmt::Display for StatsReport {
    /// Grouped `  prefix: name=value ...` lines — the shape `serve`
    /// prints at shutdown.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut last_prefix = "";
        let mut first_in_group = true;
        for (name, v) in &self.fields {
            let (prefix, field) = name.split_once('.').unwrap_or(("", name));
            if prefix != last_prefix {
                if !first_in_group {
                    writeln!(f)?;
                }
                write!(f, "  {prefix}:")?;
                last_prefix = prefix;
                first_in_group = false;
            }
            write!(f, " {field}={v}")?;
        }
        if !first_in_group {
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_grouped_display_and_flat_json() {
        let coord = CoordStats::default();
        coord.delivered.fetch_add(5, Relaxed);
        let net = NetStats::default();
        net.probes_alive.fetch_add(2, Relaxed);
        let st = Arc::new(StorageStats::default());
        st.commits.fetch_add(3, Relaxed);
        let rep = StatsReport::new(&coord, &net).with_storage(&[st]);
        let text = rep.to_string();
        assert!(text.contains("coord: wires_in=0"), "{text}");
        assert!(text.contains("delivered=5"), "{text}");
        assert!(text.contains("net: dropped_frames=0"), "{text}");
        assert!(text.contains("storage:"), "{text}");
        let json = rep.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        assert!(json.contains("\"coord.delivered\":5"), "{json}");
        assert!(json.contains("\"storage.commits\":3"), "{json}");
        assert_eq!(rep.get("coord.delivered"), Some(5));
        assert_eq!(rep.get("nope"), None);
    }

    #[test]
    fn core_section_reports_the_path_split() {
        let reg = super::super::Registry::new();
        let cm = CoreMetrics::register(&reg);
        cm.path[crate::types::DeliveryPath::Fast as usize].fetch_add(4, Relaxed);
        let rep = StatsReport::new(&CoordStats::default(), &NetStats::default()).with_core(&cm);
        assert_eq!(rep.get("obs.delivered_fast"), Some(4));
        assert_eq!(rep.get("obs.latency_p50_ns"), None, "no samples, no quantiles");
    }
}
