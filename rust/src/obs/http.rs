//! Tiny dependency-free HTTP/1.1 exposition listener.
//!
//! One detached thread, std TCP sockets, a hand-written request-line
//! parser, and a raw-syscall signal shim (glibc symbol, no `libc` crate
//! — the same no-deps discipline as the epoll/uring transports'
//! `mod sys`). Serves exactly two routes:
//!
//! * `GET /metrics` — Prometheus text exposition from the [`Registry`].
//! * `GET /debug/flight` — the flight-recorder tail as text.
//!
//! SIGUSR1 renders the flight recorder into the log from the listener
//! thread: the signal handler itself only stores one atomic flag (the
//! only async-signal-safe thing to do), and the accept loop — which
//! polls with a short timeout — picks the flag up.

use super::{FlightRecorder, Registry};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Raw signal shim (glibc symbol; the offline image has no `libc`
/// crate). Only what the dump trigger needs: installing a SIGUSR1
/// handler, which std does not expose.
mod sys {
    /// Linux SIGUSR1.
    pub const SIGUSR1: i32 = 10;

    extern "C" {
        /// glibc `signal(2)` wrapper (BSD semantics: the handler stays
        /// installed after delivery).
        pub fn signal(signum: i32, handler: usize) -> usize;
    }
}

/// Set by the SIGUSR1 handler, drained by the listener thread.
static USR1_PENDING: AtomicBool = AtomicBool::new(false);

/// The installed handler: a single atomic store is async-signal-safe;
/// everything else (locking the flight ring, formatting, logging)
/// happens later on the listener thread.
extern "C" fn on_sigusr1(_sig: i32) {
    USR1_PENDING.store(true, Ordering::Relaxed);
}

/// Install the SIGUSR1 → flight-dump trigger (idempotent). Returns
/// whether installation succeeded.
pub fn install_sigusr1() -> bool {
    // SAFETY: passing a valid `extern "C" fn(i32)` as the handler for a
    // valid signal number; `signal` itself touches no caller memory.
    // SIG_ERR is usize::MAX (-1) on failure.
    let prev = unsafe { sys::signal(sys::SIGUSR1, on_sigusr1 as usize) };
    prev != usize::MAX
}

/// How long the accept loop sleeps between polls of the stop flag, the
/// SIGUSR1 flag and the (nonblocking) listener.
const POLL: Duration = Duration::from_millis(25);

/// Per-connection read/write timeout: a stuck scraper cannot wedge the
/// listener thread for long.
const CONN_TIMEOUT: Duration = Duration::from_secs(2);

/// A running metrics listener. Dropping it (or calling
/// [`MetricsServer::stop`]) shuts the thread down.
pub struct MetricsServer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// The bound address (useful when the caller asked for port 0).
    pub addr: SocketAddr,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`; port 0 picks a free port) and
    /// serve `registry` — plus `flight`, when given, under
    /// `/debug/flight` and on SIGUSR1.
    pub fn serve(addr: &str, registry: Arc<Registry>, flight: Option<Arc<FlightRecorder>>) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let bound = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new().name("wbam-metrics".into()).spawn(move || {
            accept_loop(listener, registry, flight, stop2);
        })?;
        Ok(MetricsServer { stop, handle: Some(handle), addr: bound })
    }

    /// Stop the listener thread and join it.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, registry: Arc<Registry>, flight: Option<Arc<FlightRecorder>>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        if USR1_PENDING.swap(false, Ordering::Relaxed) {
            if let Some(fl) = &flight {
                log::info!("SIGUSR1 flight dump:\n{}", fl.render());
            } else {
                log::info!("SIGUSR1 received but no flight recorder attached");
            }
        }
        match listener.accept() {
            Ok((conn, _)) => {
                if let Err(e) = handle_conn(conn, &registry, flight.as_deref()) {
                    log::debug!("metrics connection error: {e}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) => {
                log::warn!("metrics accept error: {e}");
                std::thread::sleep(POLL);
            }
        }
    }
}

/// Read one request, answer it, close. Keep-alive is deliberately not
/// offered (`Connection: close`): scrapes are cheap and the loop serves
/// one connection at a time.
fn handle_conn(mut conn: TcpStream, registry: &Registry, flight: Option<&FlightRecorder>) -> std::io::Result<()> {
    conn.set_read_timeout(Some(CONN_TIMEOUT))?;
    conn.set_write_timeout(Some(CONN_TIMEOUT))?;
    conn.set_nonblocking(false)?;
    let mut buf = [0u8; 2048];
    let mut used = 0;
    // read until the header terminator; request bodies are not supported
    loop {
        if used == buf.len() {
            return respond(&mut conn, 431, "text/plain", "header too large\n");
        }
        let n = conn.read(&mut buf[used..])?;
        if n == 0 {
            return Ok(()); // peer went away
        }
        used += n;
        if buf[..used].windows(4).any(|w| w == b"\r\n\r\n") || buf[..used].windows(2).any(|w| w == b"\n\n") {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf[..used]);
    let mut parts = text.lines().next().unwrap_or("").split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut conn, 405, "text/plain", "method not allowed\n");
    }
    match path {
        "/metrics" => {
            let body = registry.render();
            respond(&mut conn, 200, "text/plain; version=0.0.4", &body)
        }
        "/debug/flight" => match flight {
            Some(fl) => respond(&mut conn, 200, "text/plain", &fl.render()),
            None => respond(&mut conn, 404, "text/plain", "no flight recorder attached\n"),
        },
        _ => respond(&mut conn, 404, "text/plain", "not found (try /metrics or /debug/flight)\n"),
    }
}

fn respond(conn: &mut TcpStream, code: u16, ctype: &str, body: &str) -> std::io::Result<()> {
    let reason = match code {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        431 => "Request Header Fields Too Large",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes())?;
    conn.write_all(body.as_bytes())?;
    conn.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Minimal scrape client (shared with the e2e tests' approach): one
    /// GET, read to EOF, split head from body.
    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut conn = TcpStream::connect(addr).expect("connect");
        write!(conn, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").expect("write");
        let mut out = String::new();
        conn.read_to_string(&mut out).expect("read");
        let code: u16 = out.split_whitespace().nth(1).expect("status").parse().expect("code");
        let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (code, body)
    }

    #[test]
    fn serves_metrics_and_flight_routes() {
        let reg = Arc::new(Registry::new());
        let c: Arc<AtomicU64> = reg.counter("wbam_http_test_total", "t", vec![]);
        c.fetch_add(5, Ordering::Relaxed);
        let fl = Arc::new(FlightRecorder::new(8));
        fl.push(crate::obs::FlightEvent::journal(1, crate::types::Pid(0)));
        let mut srv = MetricsServer::serve("127.0.0.1:0", reg, Some(fl)).expect("bind");
        let (code, body) = get(srv.addr, "/metrics");
        assert_eq!(code, 200);
        assert!(body.contains("wbam_http_test_total 5"), "{body}");
        let (code, body) = get(srv.addr, "/debug/flight");
        assert_eq!(code, 200);
        assert!(body.contains("JOURNAL"), "{body}");
        let (code, _) = get(srv.addr, "/nope");
        assert_eq!(code, 404);
        srv.stop();
    }

    #[test]
    fn sigusr1_handler_installs() {
        assert!(install_sigusr1());
        // raising the signal must not kill the process, only set the flag
        // SAFETY: raising a signal we just installed a handler for
        unsafe {
            extern "C" {
                fn raise(sig: i32) -> i32;
            }
            raise(sys::SIGUSR1);
        }
        // the handler may run asynchronously; give it a moment
        for _ in 0..100 {
            if USR1_PENDING.load(Ordering::Relaxed) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(USR1_PENDING.swap(false, Ordering::Relaxed), "handler must set the flag");
    }
}
