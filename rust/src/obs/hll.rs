//! HyperLogLog distinct-value estimator (Flajolet et al., 2007), built
//! in-tree (the offline image has no cardinality crate). Backs the
//! `wbam_distinct_clients` gauge: each delivery inserts the submitting
//! client id, the scrape reads the estimate.
//!
//! Shape: `M = 2^P` one-byte registers; a 64-bit mix of the value picks
//! a register with its low `P` bits and the register keeps the maximum
//! `1 + leading_zeros` rank of the remaining bits. Standard error is
//! `1.04 / sqrt(M)` ≈ 1.6% at `P = 12` (4 KiB per estimator), with the
//! linear-counting correction below `2.5 M`. Registers are `AtomicU8`
//! `fetch_max`es, so concurrent shard workers insert lock-free.

use std::sync::atomic::{AtomicU8, Ordering};

/// Register-count exponent: `M = 2^P` registers.
const P: u32 = 12;
const M: usize = 1 << P;

/// 64-bit finalizer of splitmix64 — a full-avalanche mix, so sequential
/// client ids spread uniformly over registers and ranks.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Lock-free HyperLogLog sketch over `u64` values.
pub struct Hll {
    regs: Vec<AtomicU8>,
}

impl Hll {
    pub fn new() -> Self {
        Hll { regs: (0..M).map(|_| AtomicU8::new(0)).collect() }
    }

    /// Insert one value (idempotent — re-inserting changes nothing).
    pub fn insert(&self, v: u64) {
        let h = mix(v);
        let idx = (h & (M as u64 - 1)) as usize;
        // rank of the remaining 64 - P bits: 1 + leading zeros, capped
        let rest = h >> P;
        let rank = (64 - P).min(rest.leading_zeros() + 1) as u8;
        self.regs[idx].fetch_max(rank, Ordering::Relaxed);
    }

    /// Estimated distinct-value count.
    pub fn estimate(&self) -> u64 {
        // alpha_m for m >= 128 (Flajolet et al., Fig. 3)
        let alpha = 0.7213 / (1.0 + 1.079 / M as f64);
        let mut inv_sum = 0.0f64;
        let mut zeros = 0u64;
        for r in &self.regs {
            let v = r.load(Ordering::Relaxed);
            inv_sum += 1.0 / ((1u64 << v) as f64);
            if v == 0 {
                zeros += 1;
            }
        }
        let raw = alpha * (M as f64) * (M as f64) / inv_sum;
        // small-range correction: linear counting while registers are
        // mostly empty
        let est = if raw <= 2.5 * M as f64 && zeros > 0 { (M as f64) * (M as f64 / zeros as f64).ln() } else { raw };
        est.round() as u64
    }
}

impl Default for Hll {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1.04 / sqrt(M): the sketch's standard error.
    fn std_err() -> f64 {
        1.04 / (M as f64).sqrt()
    }

    #[test]
    fn small_cardinalities_are_near_exact() {
        let h = Hll::new();
        for v in 0..100u64 {
            h.insert(v);
        }
        let est = h.estimate();
        assert!((90..=110).contains(&est), "est {est} for 100 distinct");
    }

    #[test]
    fn insert_is_idempotent() {
        let h = Hll::new();
        for _ in 0..50 {
            for v in 0..20u64 {
                h.insert(v);
            }
        }
        let est = h.estimate();
        assert!((15..=25).contains(&est), "est {est} for 20 distinct");
    }

    #[test]
    fn error_stays_within_bounds_across_scales() {
        // 5 sigma over the sketch's standard error: deterministic inputs,
        // so a failure means the estimator (not luck) regressed
        for &n in &[1_000u64, 10_000, 100_000] {
            let h = Hll::new();
            for v in 0..n {
                // spread ids: client ids in the wild are not consecutive
                h.insert(v.wrapping_mul(2_654_435_761));
            }
            let est = h.estimate() as f64;
            let rel = (est - n as f64).abs() / n as f64;
            assert!(rel < 5.0 * std_err(), "n={n}: est {est} rel err {rel:.4} vs bound {:.4}", 5.0 * std_err());
        }
    }

    #[test]
    fn empty_sketch_estimates_zero() {
        assert_eq!(Hll::new().estimate(), 0);
    }
}
