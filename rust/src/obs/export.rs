//! Bridges from the pre-existing stats structs to the [`Registry`]:
//! every public `AtomicU64` field of
//! [`CoordStats`](crate::coordinator::CoordStats),
//! [`NetStats`](crate::net::NetStats) and
//! [`StorageStats`](crate::storage::StorageStats) is registered as a
//! scrape-time counter closure over the shared `Arc` — no change to the
//! owning structs, no extra hot-path cost.
//!
//! Coverage is lint-enforced: `cargo xtask lint` parses the three struct
//! definitions and fails if any public counter field's name does not
//! appear in this file, so adding a stats field without exporting it
//! breaks the build, not the dashboard.

use super::Registry;
use crate::coordinator::CoordStats;
use crate::net::NetStats;
use crate::storage::StorageStats;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Export every [`CoordStats`] field under `wbam_coord_*`.
pub fn register_coord_stats(reg: &Registry, stats: &Arc<CoordStats>) {
    let s = stats.clone();
    reg.counter_fn("wbam_coord_wires_in_total", "Protocol wires fed into local nodes", vec![], move || {
        s.wires_in.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_coord_wires_out_total", "Wires handed to the transport flush", vec![], move || {
        s.wires_out.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_coord_self_wires_total", "Wires routed in-process between hosted pids", vec![], move || {
        s.self_wires.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_coord_delivered_total", "Local deliveries drained from node outboxes", vec![], move || {
        s.delivered.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_coord_dropped_frames_total", "Incoming frames addressed to an unhosted pid", vec![], move || {
        s.dropped_frames.load(Ordering::Relaxed)
    });
}

/// Export every [`NetStats`] field under `wbam_net_*`.
pub fn register_net_stats(reg: &Registry, stats: &Arc<NetStats>) {
    let s = stats.clone();
    reg.counter_fn("wbam_net_dropped_frames_total", "Frames observably lost on send or decode", vec![], move || {
        s.dropped_frames.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_net_probes_alive_total", "Idle-probe verdicts: connection still healthy", vec![], move || {
        s.probes_alive.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_net_probes_dead_total", "Dead-link verdicts on cached connections", vec![], move || {
        s.probes_dead.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_net_reconnects_attempted_total", "Re-establishment attempts after a dead link", vec![], move || {
        s.reconnects_attempted.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_net_reconnects_succeeded_total", "Reconnect attempts that produced a working connection", vec![], move || {
        s.reconnects_succeeded.load(Ordering::Relaxed)
    });
    let s = stats.clone();
    reg.counter_fn("wbam_net_transport_fallbacks_total", "Capability fallbacks at transport startup", vec![], move || {
        s.transport_fallbacks.load(Ordering::Relaxed)
    });
}

/// Export every [`StorageStats`] field under `wbam_storage_*`, summed
/// across the endpoint's hosted shards (one `Storage` per pid).
pub fn register_storage_stats(reg: &Registry, shards: Vec<Arc<StorageStats>>) {
    let shards = Arc::new(shards);
    let sum = |shards: &Arc<Vec<Arc<StorageStats>>>, f: fn(&StorageStats) -> u64| {
        let shards = shards.clone();
        move || shards.iter().map(|s| f(s)).sum()
    };
    reg.counter_fn(
        "wbam_storage_records_appended_total",
        "Journal records appended across hosted shards",
        vec![],
        sum(&shards, |s| s.records_appended.load(Ordering::Relaxed)),
    );
    reg.counter_fn(
        "wbam_storage_bytes_appended_total",
        "Journal payload bytes appended across hosted shards",
        vec![],
        sum(&shards, |s| s.bytes_appended.load(Ordering::Relaxed)),
    );
    reg.counter_fn(
        "wbam_storage_commits_total",
        "Group-commit flushes across hosted shards",
        vec![],
        sum(&shards, |s| s.commits.load(Ordering::Relaxed)),
    );
    reg.counter_fn(
        "wbam_storage_fsyncs_total",
        "Durability syncs (data + rotation) across hosted shards",
        vec![],
        sum(&shards, |s| s.fsyncs.load(Ordering::Relaxed)),
    );
    reg.counter_fn(
        "wbam_storage_rotations_total",
        "Journal segment rotations across hosted shards",
        vec![],
        sum(&shards, |s| s.rotations.load(Ordering::Relaxed)),
    );
    reg.counter_fn(
        "wbam_storage_snapshots_written_total",
        "Snapshots written across hosted shards",
        vec![],
        sum(&shards, |s| s.snapshots_written.load(Ordering::Relaxed)),
    );
    reg.counter_fn(
        "wbam_storage_poisoned_total",
        "Storages that hit an unrecoverable write error",
        vec![],
        sum(&shards, |s| s.poisoned.load(Ordering::Relaxed)),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exported_fields_appear_in_the_exposition() {
        let reg = Registry::new();
        let cs = Arc::new(CoordStats::default());
        cs.wires_in.fetch_add(7, Ordering::Relaxed);
        register_coord_stats(&reg, &cs);
        let ns = Arc::new(NetStats::default());
        ns.reconnects_attempted.fetch_add(2, Ordering::Relaxed);
        register_net_stats(&reg, &ns);
        let st1 = Arc::new(StorageStats::default());
        let st2 = Arc::new(StorageStats::default());
        st1.fsyncs.fetch_add(3, Ordering::Relaxed);
        st2.fsyncs.fetch_add(4, Ordering::Relaxed);
        register_storage_stats(&reg, vec![st1, st2]);
        let text = reg.render();
        assert!(text.contains("wbam_coord_wires_in_total 7"), "{text}");
        assert!(text.contains("wbam_net_reconnects_attempted_total 2"), "{text}");
        assert!(text.contains("wbam_storage_fsyncs_total 7"), "{text}");
        assert!(text.contains("# TYPE wbam_coord_delivered_total counter"), "{text}");
    }
}
