//! Live observability: a dependency-free metrics registry with
//! Prometheus text exposition, white-box delivery-path accounting, an
//! HLL distinct-client estimator and a bounded protocol flight recorder.
//!
//! Design (ARCHITECTURE.md §Observability):
//!
//! * [`Registry`] — named + labeled metrics. Counters are plain
//!   `Arc<AtomicU64>` handles the hot path bumps directly; gauges are
//!   closures evaluated at scrape time (which is how the pre-existing
//!   [`CoordStats`](crate::coordinator::CoordStats) /
//!   [`NetStats`](crate::net::NetStats) / storage counters export
//!   without changing their types — see [`export`]); histograms are
//!   shard-striped [`crate::stats::Histogram`] wrappers ([`SharedHist`])
//!   rendered as summary quantiles over the *interval* since the
//!   previous scrape.
//! * [`CoreMetrics`] — the protocol-core instrument pack: per-path
//!   delivery counters (fast 3δ / concurrent 5δ / recovery — the
//!   white-box split a black-box implementation cannot report),
//!   end-to-end latency, per-stage waits and the distinct-client HLL.
//!   Fed from the runtimes' delivery drain via
//!   [`DeliverEffect`](crate::protocols::DeliverEffect) — all `Copy`
//!   data, no hot-path allocation.
//! * [`http`] — a tiny HTTP/1.1 listener (std sockets + a raw-syscall
//!   signal shim, same no-external-deps discipline as the epoll/uring
//!   transports) serving `GET /metrics` and `GET /debug/flight`, with a
//!   SIGUSR1 handler that dumps the flight recorder to the log.
//! * [`flight`] — the per-node bounded ring of recent protocol events.
//! * [`hll`] — the HyperLogLog estimator behind
//!   `wbam_distinct_clients`.

pub mod export;
pub mod flight;
pub mod hll;
pub mod http;
pub mod report;

pub use export::{register_coord_stats, register_net_stats, register_storage_stats};
pub use flight::{FlightEvent, FlightKind, FlightRecorder};
pub use hll::Hll;
pub use http::MetricsServer;
pub use report::StatsReport;

use crate::protocols::DeliverEffect;
use crate::stats::Histogram;
use crate::types::DeliveryPath;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Wall-clock nanoseconds since the Unix epoch. This is the one clock
/// domain shared by clients and servers (each runtime's internal `now`
/// is epoch-relative and incomparable across endpoints), so it is what
/// [`crate::types::MsgMeta::submit_ns`] stamps and what end-to-end
/// latency is measured against.
pub fn wallclock_ns() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

/// Number of lock stripes in a [`SharedHist`]; recording threads spread
/// across them (per-thread stripe index), so concurrent shards rarely
/// contend on the same mutex.
const HIST_SHARDS: usize = 8;

static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each recording thread picks one stripe for life.
    static STRIPE: usize = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed);
}

/// A shard-striped [`Histogram`] behind `Arc`: `record` locks only the
/// calling thread's stripe, cumulative count/sum stay lock-free, and
/// [`SharedHist::take_window`] drains every stripe into one interval
/// histogram for the exporter (interval — not lifetime — percentiles).
pub struct SharedHist {
    stripes: Vec<Mutex<Histogram>>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl SharedHist {
    pub fn new() -> Self {
        SharedHist {
            stripes: (0..HIST_SHARDS).map(|_| Mutex::new(Histogram::new())).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value (nanoseconds, by convention).
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        let i = STRIPE.with(|s| *s) % self.stripes.len();
        self.stripes[i].lock().expect("hist stripe poisoned").record(v);
    }

    /// Lifetime sample count.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Lifetime sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Drain and merge every stripe: the histogram of everything
    /// recorded since the previous call (or ever, on the first call).
    pub fn take_window(&self) -> Histogram {
        let mut merged = Histogram::new();
        for s in &self.stripes {
            merged.merge(&s.lock().expect("hist stripe poisoned").take_window());
        }
        merged
    }

    /// Merge every stripe without draining (tests / end-of-run reports
    /// that must not disturb a concurrent exporter's window).
    pub fn peek(&self) -> Histogram {
        let mut merged = Histogram::new();
        for s in &self.stripes {
            merged.merge(&s.lock().expect("hist stripe poisoned"));
        }
        merged
    }
}

impl Default for SharedHist {
    fn default() -> Self {
        Self::new()
    }
}

enum Kind {
    /// Monotonic counter the owner bumps directly.
    Counter(Arc<AtomicU64>),
    /// Evaluated at scrape time; `counter` picks the exposition TYPE.
    Fn { f: Box<dyn Fn() -> u64 + Send + Sync>, counter: bool },
    /// Summary-rendered histogram (interval quantiles + lifetime
    /// `_sum`/`_count`).
    Hist(Arc<SharedHist>),
}

struct Metric {
    name: &'static str,
    help: &'static str,
    labels: Vec<(&'static str, String)>,
    kind: Kind,
}

/// The metrics registry: register once at startup, scrape via
/// [`Registry::render`] (Prometheus text exposition format 0.0.4).
/// Metric names are emitted in registration order; metrics sharing a
/// name (label variants) must be registered consecutively to keep the
/// exposition's one-`TYPE`-per-name shape.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<Vec<Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Register a counter and return the handle the hot path bumps.
    pub fn counter(&self, name: &'static str, help: &'static str, labels: Vec<(&'static str, String)>) -> Arc<AtomicU64> {
        let c = Arc::new(AtomicU64::new(0));
        self.push(Metric { name, help, labels, kind: Kind::Counter(c.clone()) });
        c
    }

    /// Register a scrape-time gauge closure.
    pub fn gauge_fn(
        &self,
        name: &'static str,
        help: &'static str,
        labels: Vec<(&'static str, String)>,
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.push(Metric { name, help, labels, kind: Kind::Fn { f: Box::new(f), counter: false } });
    }

    /// Register a scrape-time closure exposed with `TYPE counter` —
    /// how pre-existing monotonic `AtomicU64` stats fields export
    /// without changing their owning structs (see [`export`]).
    pub fn counter_fn(
        &self,
        name: &'static str,
        help: &'static str,
        labels: Vec<(&'static str, String)>,
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.push(Metric { name, help, labels, kind: Kind::Fn { f: Box::new(f), counter: true } });
    }

    /// Register a shard-striped histogram, rendered as a summary.
    pub fn histogram(&self, name: &'static str, help: &'static str, labels: Vec<(&'static str, String)>) -> Arc<SharedHist> {
        let h = Arc::new(SharedHist::new());
        self.push(Metric { name, help, labels, kind: Kind::Hist(h.clone()) });
        h
    }

    fn push(&self, m: Metric) {
        self.metrics.lock().expect("registry poisoned").push(m);
    }

    /// Render the Prometheus text exposition. Histogram quantiles cover
    /// the window since the previous `render` call (interval
    /// percentiles); `_count`/`_sum` stay cumulative.
    pub fn render(&self) -> String {
        let metrics = self.metrics.lock().expect("registry poisoned");
        let mut out = String::with_capacity(1024);
        let mut last_name = "";
        for m in metrics.iter() {
            if m.name != last_name {
                let ty = match &m.kind {
                    Kind::Counter(_) | Kind::Fn { counter: true, .. } => "counter",
                    Kind::Fn { counter: false, .. } => "gauge",
                    Kind::Hist(_) => "summary",
                };
                let _ = writeln!(out, "# HELP {} {}", m.name, m.help);
                let _ = writeln!(out, "# TYPE {} {}", m.name, ty);
                last_name = m.name;
            }
            match &m.kind {
                Kind::Counter(c) => {
                    let _ = writeln!(out, "{}{} {}", m.name, render_labels(&m.labels, None), c.load(Ordering::Relaxed));
                }
                Kind::Fn { f, .. } => {
                    let _ = writeln!(out, "{}{} {}", m.name, render_labels(&m.labels, None), f());
                }
                Kind::Hist(h) => {
                    let w = h.take_window();
                    for (q, v) in [(0.5, w.p50()), (0.99, w.p99())] {
                        let _ = writeln!(out, "{}{} {}", m.name, render_labels(&m.labels, Some(q)), v);
                    }
                    let _ = writeln!(out, "{}_sum{} {}", m.name, render_labels(&m.labels, None), h.sum());
                    let _ = writeln!(out, "{}_count{} {}", m.name, render_labels(&m.labels, None), h.count());
                }
            }
        }
        out
    }
}

fn render_labels(labels: &[(&'static str, String)], quantile: Option<f64>) -> String {
    if labels.is_empty() && quantile.is_none() {
        return String::new();
    }
    let mut s = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{k}=\"{v}\"");
    }
    if let Some(q) = quantile {
        if !labels.is_empty() {
            s.push(',');
        }
        let _ = write!(s, "quantile=\"{q}\"");
    }
    s.push('}');
    s
}

/// The protocol-core instrument pack: everything the runtimes' delivery
/// drain records per [`DeliverEffect`]. One `Arc<CoreMetrics>` is shared
/// by all shards of an endpoint; every member is lock-free or
/// lock-striped, so recording from concurrent shard workers is safe and
/// allocation-free.
pub struct CoreMetrics {
    /// Deliveries by [`DeliveryPath`] (indexed by the path's `u8` value):
    /// the white-box 3δ-vs-5δ split.
    pub path: [Arc<AtomicU64>; 4],
    /// Submit → deliver wall-clock latency (stamped messages only).
    pub e2e: Arc<SharedHist>,
    /// Leader-local proposal → ack-quorum wait.
    pub stage_quorum: Arc<SharedHist>,
    /// Leader-local ack-quorum → commit wait.
    pub stage_commit: Arc<SharedHist>,
    /// Leader-local commit → deliver wait (frontier hold time).
    pub stage_deliver: Arc<SharedHist>,
    /// Distinct submitting clients (HyperLogLog estimate).
    pub clients: Arc<Hll>,
    /// Recent protocol events, dumpable via `/debug/flight` / SIGUSR1.
    pub flight: Arc<FlightRecorder>,
}

impl CoreMetrics {
    /// Build the pack and register every member under its metric name.
    pub fn register(reg: &Registry) -> Arc<CoreMetrics> {
        let path = [DeliveryPath::Fast, DeliveryPath::Concurrent, DeliveryPath::Recovery, DeliveryPath::Unclassified]
            .map(|p| {
                reg.counter(
                    "wbam_deliveries_total",
                    "Delivered multicasts by white-box latency path (fast=3delta, concurrent=5delta)",
                    vec![("path", p.as_str().to_string())],
                )
            });
        let e2e = reg.histogram("wbam_delivery_latency_ns", "Client submit to delivery wall-clock latency", vec![]);
        let stage_quorum =
            reg.histogram("wbam_stage_wait_ns", "Per-stage waits on the leader path", vec![("stage", "quorum".into())]);
        let stage_commit =
            reg.histogram("wbam_stage_wait_ns", "Per-stage waits on the leader path", vec![("stage", "commit".into())]);
        let stage_deliver =
            reg.histogram("wbam_stage_wait_ns", "Per-stage waits on the leader path", vec![("stage", "deliver".into())]);
        let clients = Arc::new(Hll::new());
        {
            let h = clients.clone();
            reg.gauge_fn("wbam_distinct_clients", "HyperLogLog estimate of distinct submitting clients", vec![], move || {
                h.estimate()
            });
        }
        let flight = Arc::new(FlightRecorder::new(flight::DEFAULT_CAP));
        Arc::new(CoreMetrics { path, e2e, stage_quorum, stage_commit, stage_deliver, clients, flight })
    }

    /// Record one delivery. `Copy` reads + atomics only — safe on the
    /// hot path (the metrics-overhead ablation in EXPERIMENTS.md pins
    /// the cost).
    pub fn on_deliver(&self, d: &DeliverEffect) {
        self.path[d.path as u8 as usize].fetch_add(1, Ordering::Relaxed);
        self.clients.insert(d.m.client() as u64);
        if d.submit_ns != 0 {
            self.e2e.record(wallclock_ns().saturating_sub(d.submit_ns));
        }
        if d.quorum_at >= d.proposal_at && d.proposal_at != 0 {
            self.stage_quorum.record(d.quorum_at - d.proposal_at);
        }
        if d.commit_at >= d.quorum_at && d.quorum_at != 0 {
            self.stage_commit.record(d.commit_at - d.quorum_at);
        }
        if d.deliver_at >= d.commit_at && d.commit_at != 0 {
            self.stage_deliver.record(d.deliver_at - d.commit_at);
        }
    }

    /// Total deliveries across every path label.
    pub fn delivered_total(&self) -> u64 {
        self.path.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Gid, MsgId, Ts};

    #[test]
    fn counters_and_gauges_render_prometheus_text() {
        let reg = Registry::new();
        let c = reg.counter("wbam_test_total", "help one", vec![("path", "fast".into())]);
        c.fetch_add(3, Ordering::Relaxed);
        reg.gauge_fn("wbam_test_gauge", "help two", vec![], || 42);
        let text = reg.render();
        assert!(text.contains("# TYPE wbam_test_total counter"), "{text}");
        assert!(text.contains("wbam_test_total{path=\"fast\"} 3"), "{text}");
        assert!(text.contains("# TYPE wbam_test_gauge gauge"), "{text}");
        assert!(text.contains("wbam_test_gauge 42"), "{text}");
    }

    #[test]
    fn histogram_renders_interval_quantiles_and_cumulative_count() {
        let reg = Registry::new();
        let h = reg.histogram("wbam_test_lat_ns", "latency", vec![]);
        for v in [100u64, 200, 300, 400] {
            h.record(v);
        }
        let text = reg.render();
        assert!(text.contains("# TYPE wbam_test_lat_ns summary"), "{text}");
        assert!(text.contains("quantile=\"0.5\""), "{text}");
        assert!(text.contains("wbam_test_lat_ns_count 4"), "{text}");
        assert!(text.contains("wbam_test_lat_ns_sum 1000"), "{text}");
        // second scrape: the window drained, but the cumulative count stays
        let text2 = reg.render();
        assert!(text2.contains("wbam_test_lat_ns_count 4"), "{text2}");
        assert!(text2.contains("wbam_test_lat_ns{quantile=\"0.5\"} 0"), "{text2}");
    }

    #[test]
    fn shared_hist_stripes_merge() {
        let h = SharedHist::new();
        for v in 1..=100u64 {
            h.record(v * 1000);
        }
        assert_eq!(h.count(), 100);
        let w = h.take_window();
        assert_eq!(w.count(), 100);
        assert!(w.p50() >= 45_000 && w.p50() <= 55_000, "p50 {}", w.p50());
        assert_eq!(h.take_window().count(), 0, "window drained");
        assert_eq!(h.count(), 100, "cumulative count survives the drain");
    }

    #[test]
    fn core_metrics_count_paths_and_sum_to_total() {
        let reg = Registry::new();
        let cm = CoreMetrics::register(&reg);
        let mut d = crate::protocols::DeliverEffect::untraced(MsgId::new(7, 1), Ts::new(1, Gid(0)));
        d.path = DeliveryPath::Fast;
        cm.on_deliver(&d);
        d.path = DeliveryPath::Concurrent;
        cm.on_deliver(&d);
        cm.on_deliver(&d);
        assert_eq!(cm.delivered_total(), 3);
        assert_eq!(cm.path[DeliveryPath::Fast as usize].load(Ordering::Relaxed), 1);
        assert_eq!(cm.path[DeliveryPath::Concurrent as usize].load(Ordering::Relaxed), 2);
        let text = reg.render();
        assert!(text.contains("wbam_deliveries_total{path=\"fast\"} 1"), "{text}");
        assert!(text.contains("wbam_deliveries_total{path=\"concurrent\"} 2"), "{text}");
    }

    #[test]
    fn e2e_latency_recorded_only_for_stamped_messages() {
        let reg = Registry::new();
        let cm = CoreMetrics::register(&reg);
        let mut d = crate::protocols::DeliverEffect::untraced(MsgId::new(1, 1), Ts::new(1, Gid(0)));
        cm.on_deliver(&d); // unstamped: no sample
        assert_eq!(cm.e2e.count(), 0);
        d.submit_ns = wallclock_ns().saturating_sub(1_000_000);
        cm.on_deliver(&d);
        assert_eq!(cm.e2e.count(), 1);
        assert!(cm.e2e.peek().max() >= 1_000_000);
    }
}
