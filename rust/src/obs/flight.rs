//! The protocol flight recorder: a fixed-capacity ring of recent
//! protocol events (wire in/out with their tags, ballot-changing
//! recovery traffic, journal appends, deliveries with their white-box
//! path). Bounded by construction — a misbehaving run can never grow it
//! — and cheap enough to leave on in production: one short mutex hold
//! per event, no allocation after construction.
//!
//! Dump surfaces: `GET /debug/flight` on the metrics listener, SIGUSR1
//! (rendered to the log), and automatically when a sim-harness invariant
//! check fails ([`crate::invariants`]) — the assert message becomes a
//! replayable event tail.

use crate::types::{DeliveryPath, MsgId, Pid, Ts, Wire};
use std::fmt::Write as _;
use std::sync::Mutex;

/// Default ring capacity (events), sized to hold the last few thousand
/// protocol steps — enough to see a full recovery round.
pub const DEFAULT_CAP: usize = 4096;

/// Event class recorded in the ring.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FlightKind {
    /// A protocol message arrived (`peer` = sender).
    WireIn,
    /// A protocol message was emitted (`peer` = destination).
    WireOut,
    /// A ballot-carrying recovery message (NEWLEADER / NEW_STATE family)
    /// moved — the ballot lives in `a`.
    BallotChange,
    /// Journal records reached the WAL's group-commit point.
    Journal,
    /// A local delivery; `a` = message id, `b` = gts time, label = path.
    Deliver,
}

/// One recorded event. All-`Copy`, fixed-size; `label` is a `'static`
/// tag (wire tag or delivery path), so the ring never owns heap data.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    /// Runtime-local (or sim-virtual) nanosecond timestamp.
    pub at: u64,
    /// The node recording the event.
    pub pid: Pid,
    /// Sender (WireIn), destination (WireOut), or the recording node.
    pub peer: Pid,
    pub kind: FlightKind,
    /// Wire tag ([`Wire::tag`]) or delivery-path label.
    pub label: &'static str,
    /// Kind-specific payload (message id, encoded ballot, ...).
    pub a: u64,
    /// Kind-specific payload (gts time, ...).
    pub b: u64,
}

/// True for wire variants whose movement marks a ballot change.
fn is_ballot_wire(w: &Wire) -> bool {
    matches!(w, Wire::NewLeader { .. } | Wire::NewLeaderAck { .. } | Wire::NewState { .. } | Wire::NewStateAck { .. })
}

fn wire_detail(w: &Wire) -> (u64, u64) {
    match w {
        Wire::Multicast { meta } => (meta.id.0, 0),
        Wire::Accept { meta, bal, .. } => (meta.id.0, ballot_bits(bal.n, bal.p.0)),
        Wire::AcceptAck { m, .. } => (m.0, 0),
        Wire::Deliver { m, gts, .. } => (m.0, gts.time()),
        Wire::Delivered { m, gts, .. } => (m.0, gts.time()),
        Wire::NewLeader { bal } | Wire::NewStateAck { bal } | Wire::Heartbeat { bal } => (ballot_bits(bal.n, bal.p.0), 0),
        Wire::NewLeaderAck { bal, clock, .. } | Wire::NewState { bal, clock, .. } => (ballot_bits(bal.n, bal.p.0), *clock),
        _ => (0, 0),
    }
}

fn ballot_bits(n: u32, p: u32) -> u64 {
    ((n as u64) << 32) | p as u64
}

impl FlightEvent {
    /// A message arriving at `pid` from `from`.
    pub fn wire_in(at: u64, pid: Pid, from: Pid, w: &Wire) -> Self {
        let (a, b) = wire_detail(w);
        let kind = if is_ballot_wire(w) { FlightKind::BallotChange } else { FlightKind::WireIn };
        FlightEvent { at, pid, peer: from, kind, label: w.tag(), a, b }
    }

    /// A message leaving `pid` toward `to`.
    pub fn wire_out(at: u64, pid: Pid, to: Pid, w: &Wire) -> Self {
        let (a, b) = wire_detail(w);
        let kind = if is_ballot_wire(w) { FlightKind::BallotChange } else { FlightKind::WireOut };
        FlightEvent { at, pid, peer: to, kind, label: w.tag(), a, b }
    }

    /// Journal records committed at `pid`.
    pub fn journal(at: u64, pid: Pid) -> Self {
        FlightEvent { at, pid, peer: pid, kind: FlightKind::Journal, label: "JOURNAL", a: 0, b: 0 }
    }

    /// A local delivery at `pid`.
    pub fn deliver(at: u64, pid: Pid, m: MsgId, gts: Ts, path: DeliveryPath) -> Self {
        FlightEvent { at, pid, peer: pid, kind: FlightKind::Deliver, label: path.as_str(), a: m.0, b: gts.time() }
    }
}

struct Ring {
    buf: Vec<FlightEvent>,
    /// fixed capacity (not `buf.capacity()`, which may over-allocate)
    cap: usize,
    /// next write slot
    head: usize,
    /// live events (saturates at capacity)
    len: usize,
    /// total pushes ever (so dumps report how much history was shed)
    pushed: u64,
}

/// The bounded recorder. One per node/endpoint; shared by `Arc`.
pub struct FlightRecorder {
    ring: Mutex<Ring>,
}

impl FlightRecorder {
    /// `cap` is clamped to at least 1.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        FlightRecorder {
            ring: Mutex::new(Ring { buf: Vec::with_capacity(cap), cap, head: 0, len: 0, pushed: 0 }),
        }
    }

    /// Record one event, evicting the oldest once full.
    pub fn push(&self, ev: FlightEvent) {
        let mut r = self.ring.lock().expect("flight ring poisoned");
        let cap = r.cap;
        if r.buf.len() < cap {
            r.buf.push(ev);
            r.len += 1;
        } else {
            let head = r.head;
            r.buf[head] = ev;
        }
        r.head = (r.head + 1) % cap;
        r.pushed += 1;
    }

    /// Live events, oldest first.
    pub fn dump(&self) -> Vec<FlightEvent> {
        let r = self.ring.lock().expect("flight ring poisoned");
        let cap = r.cap;
        let mut out = Vec::with_capacity(r.len);
        if r.buf.len() < cap {
            out.extend_from_slice(&r.buf);
        } else {
            out.extend_from_slice(&r.buf[r.head..]);
            out.extend_from_slice(&r.buf[..r.head]);
        }
        out
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().expect("flight ring poisoned").len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever pushed (dumps report `pushed - len` shed).
    pub fn pushed(&self) -> u64 {
        self.ring.lock().expect("flight ring poisoned").pushed
    }

    /// One-line-per-event text rendering (the `/debug/flight` body and
    /// the SIGUSR1 / invariant-failure dump).
    pub fn render(&self) -> String {
        let events = self.dump();
        let pushed = self.pushed();
        let mut s = String::with_capacity(events.len() * 64 + 64);
        let _ = writeln!(s, "# flight recorder: {} events held, {} recorded total", events.len(), pushed);
        for e in &events {
            let _ = writeln!(
                s,
                "{:>14} p{:<4} {:12} {:14} peer=p{} a={:#x} b={}",
                e.at,
                e.pid.0,
                format!("{:?}", e.kind),
                e.label,
                e.peer.0,
                e.a,
                e.b
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Ballot, Gid};

    fn ev(i: u64) -> FlightEvent {
        FlightEvent::deliver(i, Pid(1), MsgId::new(1, i as u32), Ts::new(i, Gid(0)), DeliveryPath::Fast)
    }

    #[test]
    fn ring_holds_everything_below_capacity() {
        let fl = FlightRecorder::new(8);
        for i in 0..5 {
            fl.push(ev(i));
        }
        let d = fl.dump();
        assert_eq!(d.len(), 5);
        assert_eq!(d.iter().map(|e| e.at).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert_eq!(fl.pushed(), 5);
    }

    #[test]
    fn ring_wraps_keeping_the_newest_tail_in_order() {
        let fl = FlightRecorder::new(4);
        for i in 0..11 {
            fl.push(ev(i));
        }
        let d = fl.dump();
        assert_eq!(d.len(), 4, "bounded at capacity");
        // oldest-first tail: 7, 8, 9, 10
        assert_eq!(d.iter().map(|e| e.at).collect::<Vec<_>>(), vec![7, 8, 9, 10]);
        assert_eq!(fl.pushed(), 11);
        // keep wrapping: ordering holds at every phase offset
        for i in 11..17 {
            fl.push(ev(i));
        }
        let d = fl.dump();
        assert_eq!(d.iter().map(|e| e.at).collect::<Vec<_>>(), vec![13, 14, 15, 16]);
    }

    #[test]
    fn ballot_wires_classify_as_ballot_changes() {
        let w = Wire::NewLeader { bal: Ballot::new(7, Pid(2)) };
        let e = FlightEvent::wire_in(5, Pid(1), Pid(2), &w);
        assert_eq!(e.kind, FlightKind::BallotChange);
        assert_eq!(e.label, "NEWLEADER");
        assert_eq!(e.a, (7u64 << 32) | 2);
        let hb = Wire::Heartbeat { bal: Ballot::new(1, Pid(0)) };
        assert_eq!(FlightEvent::wire_out(5, Pid(1), Pid(3), &hb).kind, FlightKind::WireOut);
    }

    #[test]
    fn render_mentions_capacity_and_events() {
        let fl = FlightRecorder::new(2);
        fl.push(ev(1));
        fl.push(ev(2));
        fl.push(ev(3));
        let text = fl.render();
        assert!(text.contains("2 events held, 3 recorded total"), "{text}");
        assert!(text.contains("fast"), "{text}");
    }
}
