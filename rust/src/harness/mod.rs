//! Experiment harness: builds a simulated deployment for any protocol,
//! runs a workload, and summarises the metrics the paper's figures plot.
//! Shared by `cargo bench` drivers, the examples and the integration
//! tests.

use crate::client::{Client, ClientCfg};
use crate::protocols::fastcast::FastCastNode;
use crate::protocols::ftskeen::FtSkeenNode;
use crate::protocols::skeen::SkeenNode;
use crate::protocols::wbcast::{WbConfig, WbNode};
use crate::protocols::Node;
use crate::sim::{ConstDelay, CpuCost, DelayModel, LanDelay, SimConfig, Trace, WanDelay, World, MS};
use crate::stats::Histogram;
use crate::types::{FlushPolicy, Pid, ShardMap, Topology};

/// Protocol under test.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Proto {
    /// unreplicated Skeen (Fig. 1; requires f = 0)
    Skeen,
    /// Skeen over black-box Paxos (6δ / 12δ)
    FtSkeen,
    /// FastCast (4δ / 8δ)
    FastCast,
    /// the paper's white-box protocol (3δ / 5δ)
    WbCast,
}

impl Proto {
    pub const ALL: [Proto; 4] = [Proto::Skeen, Proto::FtSkeen, Proto::FastCast, Proto::WbCast];
    /// The three replicated protocols of the paper's evaluation (§VI).
    pub const EVAL: [Proto; 3] = [Proto::FtSkeen, Proto::FastCast, Proto::WbCast];

    pub fn name(self) -> &'static str {
        match self {
            Proto::Skeen => "Skeen",
            Proto::FtSkeen => "FT-Skeen",
            Proto::FastCast => "FastCast",
            Proto::WbCast => "WbCast",
        }
    }
}

/// Network model selector (paper testbeds).
#[derive(Clone, Copy, Debug)]
pub enum Net {
    /// constant δ, zero CPU cost — §V theory setting
    Theory { delta: u64 },
    /// CloudLab-like LAN (≈0.1 ms RTT) with server CPU cost
    Lan,
    /// GCP 3-DC WAN (60/75/130 ms RTTs); group member i → site i
    Wan,
}

/// One experiment configuration.
#[derive(Clone, Debug)]
pub struct RunCfg {
    pub proto: Proto,
    pub groups: usize,
    pub f: usize,
    pub clients: usize,
    /// destination groups per multicast
    pub dest_groups: usize,
    pub net: Net,
    pub seed: u64,
    /// per-client request cap (None: run until `duration`)
    pub max_requests: Option<u32>,
    /// total virtual time to simulate (used when max_requests is None)
    pub duration: u64,
    /// fraction of `duration` discarded as warm-up
    pub warmup_frac: f64,
    /// record the full delivery trace (needed for safety checking)
    pub record_full: bool,
    /// WbCast liveness tunables (heartbeats etc.)
    pub wb: WbConfig,
    /// client retransmission interval (0: disabled)
    pub resend_after: u64,
    /// destination-coalesced wire batching in the simulated transport
    /// (see [`crate::sim::SimConfig::coalesce`]; on by default)
    pub coalesce: bool,
    /// adaptive per-link flush policy applied by the simulated transport
    /// when `coalesce` is on (default: flush every event immediately)
    pub flush: FlushPolicy,
    /// leader shards per group ([`ShardMap`]): `shards` independent
    /// protocol instances, clients partitioned round-robin across them
    /// (1 = the plain unsharded deployment)
    pub shards: usize,
}

impl RunCfg {
    pub fn new(proto: Proto, groups: usize, clients: usize, dest_groups: usize, net: Net) -> Self {
        RunCfg {
            proto,
            groups,
            f: if proto == Proto::Skeen { 0 } else { 1 },
            clients,
            dest_groups,
            net,
            seed: 42,
            max_requests: None,
            duration: 10_000 * MS,
            warmup_frac: 0.2,
            record_full: false,
            wb: WbConfig::default(),
            resend_after: 0,
            coalesce: true,
            flush: FlushPolicy::default(),
            shards: 1,
        }
    }
}

/// Summary of one run — a row of a paper figure.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub proto: Proto,
    pub clients: usize,
    pub dest_groups: usize,
    /// mean first-delivery latency, ms
    pub mean_lat_ms: f64,
    pub p50_lat_ms: f64,
    pub p99_lat_ms: f64,
    pub max_lat_ms: f64,
    /// completed multicasts per second in the measurement window
    pub throughput: f64,
    /// protocol messages sent per completed multicast
    pub msgs_per_multicast: f64,
    pub completed: usize,
}

impl RunResult {
    pub fn row(&self) -> String {
        format!(
            "{:<9} clients={:<5} dest={:<2} lat(ms) mean={:<8.3} p50={:<8.3} p99={:<8.3} thru={:<10.0} msgs/mc={:<6.1}",
            self.proto.name(),
            self.clients,
            self.dest_groups,
            self.mean_lat_ms,
            self.p50_lat_ms,
            self.p99_lat_ms,
            self.throughput,
            self.msgs_per_multicast
        )
    }
}

fn delay_model(net: Net, map: &ShardMap) -> (Box<dyn DelayModel>, CpuCost) {
    match net {
        Net::Theory { delta } => (Box::new(ConstDelay(delta)), CpuCost::zero()),
        Net::Lan => (Box::new(LanDelay::cloudlab()), CpuCost::lan_server()),
        Net::Wan => {
            let gsize = map.group_size();
            let stride = map.members_per_shard() as u32;
            let members = map.num_members() as u32;
            // each group has one replica per data centre (§VI); a pid's
            // shard counterparts share its site (same machine); clients
            // are spread across the three sites round-robin
            let site_of = move |p: Pid| {
                if p.0 < members {
                    ((p.0 % stride) as usize) % gsize % 3
                } else {
                    (p.0 - members) as usize % 3
                }
            };
            (Box::new(WanDelay::gcp3(site_of)), CpuCost::lan_server())
        }
    }
}

/// Construct the simulated deployment for `cfg`: `cfg.shards`
/// independent protocol instances per [`ShardMap`], clients partitioned
/// round-robin across them.
pub fn build_world(cfg: &RunCfg) -> World {
    let map = ShardMap::new(cfg.groups, cfg.f, cfg.shards);
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for s in 0..map.shards {
        let topo = map.topo(s);
        for g in topo.gids() {
            for &p in topo.members(g) {
                match cfg.proto {
                    Proto::Skeen => nodes.push(Box::new(SkeenNode::new(p, topo.clone()))),
                    Proto::FtSkeen => nodes.push(Box::new(FtSkeenNode::new(p, topo.clone()))),
                    Proto::FastCast => nodes.push(Box::new(FastCastNode::new(p, topo.clone()))),
                    Proto::WbCast => nodes.push(Box::new(WbNode::new(p, topo.clone(), cfg.wb))),
                }
            }
        }
    }
    for c in 0..cfg.clients {
        let pid = Pid(map.first_client_pid().0 + c as u32);
        let topo = map.topo(map.client_shard(pid));
        let ccfg = ClientCfg {
            dest_groups: cfg.dest_groups,
            max_requests: cfg.max_requests,
            resend_after: cfg.resend_after,
            ..Default::default()
        };
        nodes.push(Box::new(Client::new(pid, topo, ccfg, cfg.seed ^ ((c as u64) << 13) ^ 0x5EED)));
    }
    let (delay, cpu) = delay_model(cfg.net, &map);
    World::new_sharded(
        map,
        nodes,
        SimConfig {
            delay,
            cpu,
            seed: cfg.seed,
            record_full: cfg.record_full,
            coalesce: cfg.coalesce,
            flush: cfg.flush,
        },
    )
}

/// Give every member of `topo` simulated durable storage plus a
/// rebuilder that restores a [`WbNode`] from its journal fold — after
/// this, [`World::restart_at`] can bring any crashed member of the
/// topology back through the recovery protocol (`wb` should have
/// `durability` set, or the journals stay empty). Call once per shard
/// topology for sharded worlds.
pub fn enable_wb_storage(world: &mut World, topo: &Topology, wb: WbConfig) {
    for g in topo.gids() {
        for &p in topo.members(g) {
            let t = topo.clone();
            world.enable_storage(
                p,
                Box::new(move |snap: crate::storage::Snapshot| -> Box<dyn Node> {
                    Box::new(WbNode::restore(p, t.clone(), wb, &snap))
                }),
            );
        }
    }
}

/// Run `cfg` and summarise. With `max_requests` set the run goes to
/// quiescence; otherwise it simulates `duration` and measures after the
/// warm-up window.
pub fn run(cfg: &RunCfg) -> RunResult {
    let mut world = build_world(cfg);
    let (from, to) = if cfg.max_requests.is_some() {
        world.run_to_quiescence(u64::MAX);
        (0, world.now().max(1))
    } else {
        world.run_until(cfg.duration);
        ((cfg.duration as f64 * cfg.warmup_frac) as u64, cfg.duration)
    };
    summarize(cfg, &world.trace, from, to)
}

/// Build a RunResult from a trace over the window `[from, to)`.
pub fn summarize(cfg: &RunCfg, trace: &Trace, from: u64, to: u64) -> RunResult {
    let mut h = Histogram::new();
    for &l in &trace.latencies {
        h.record(l.max(1));
    }
    let completed = trace.completions.iter().filter(|&&t| t >= from && t < to).count();
    let thru = completed as f64 / ((to - from) as f64 / 1e9);
    let total_done = trace.completions.len().max(1);
    RunResult {
        proto: cfg.proto,
        clients: cfg.clients,
        dest_groups: cfg.dest_groups,
        mean_lat_ms: h.mean() / 1e6,
        p50_lat_ms: h.p50() as f64 / 1e6,
        p99_lat_ms: h.p99() as f64 / 1e6,
        max_lat_ms: h.max() as f64 / 1e6,
        throughput: thru,
        msgs_per_multicast: trace.sends as f64 / total_done as f64,
        completed,
    }
}

/// A client that multicasts a fixed script of messages at exact virtual
/// times — used by the latency-theory bench to construct the adversarial
/// §V scenarios (e.g. Fig. 2's convoy timing).
pub struct ScriptedClient {
    pid: Pid,
    topo: Topology,
    /// (send time, destination groups) in increasing time order
    script: Vec<(u64, crate::types::GidSet)>,
    next: usize,
    seq: u32,
}

impl ScriptedClient {
    pub fn new(pid: Pid, topo: Topology, script: Vec<(u64, crate::types::GidSet)>) -> Self {
        ScriptedClient { pid, topo, script, next: 0, seq: 0 }
    }

    fn fire_due(&mut self, now: u64, out: &mut crate::protocols::Outbox) {
        use crate::protocols::TimerKind;
        use crate::types::{MsgId, MsgMeta, Wire};
        while self.next < self.script.len() && self.script[self.next].0 <= now {
            let (_, dest) = self.script[self.next];
            self.next += 1;
            self.seq += 1;
            let meta = MsgMeta::new(MsgId::new(self.pid.0, self.seq), dest, vec![0u8; 20]);
            for g in dest.iter() {
                out.send(self.topo.initial_leader(g), Wire::Multicast { meta: meta.clone() });
            }
        }
        if self.next < self.script.len() {
            out.timer(TimerKind::ClientNext, self.script[self.next].0 - now);
        }
    }
}

impl crate::protocols::Node for ScriptedClient {
    fn pid(&self) -> Pid {
        self.pid
    }
    fn on_start(&mut self, now: u64, out: &mut crate::protocols::Outbox) {
        self.fire_due(now, out);
    }
    fn on_wire(&mut self, _f: Pid, _w: crate::types::Wire, _n: u64, _out: &mut crate::protocols::Outbox) {}
    fn on_timer(&mut self, _t: crate::protocols::TimerKind, now: u64, out: &mut crate::protocols::Outbox) {
        self.fire_due(now, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_latencies_match_table_1() {
        // solo message per protocol: commit latency = collision-free
        // latency (Theorem 3): Skeen 2δ, WbCast 3δ, FastCast 4δ, FT-Skeen 6δ
        let delta = MS;
        let expect = [(Proto::Skeen, 2.0), (Proto::WbCast, 3.0), (Proto::FastCast, 4.0), (Proto::FtSkeen, 6.0)];
        for (proto, d) in expect {
            let mut cfg = RunCfg::new(proto, 2, 1, 2, Net::Theory { delta });
            cfg.max_requests = Some(1);
            cfg.record_full = true;
            let r = run(&cfg);
            assert_eq!(r.completed, 1);
            assert!(
                (r.mean_lat_ms - d).abs() < 1e-6,
                "{}: expected {d}δ, got {} ms",
                proto.name(),
                r.mean_lat_ms
            );
        }
    }

    #[test]
    fn all_protocols_safe_under_lan_contention() {
        for proto in Proto::EVAL {
            let mut cfg = RunCfg::new(proto, 3, 8, 2, Net::Lan);
            cfg.max_requests = Some(20);
            cfg.record_full = true;
            let mut w = build_world(&cfg);
            // flight recorder rides along: an invariant failure dumps
            // the event tail instead of a bare assert
            w.enable_flight(crate::obs::flight::DEFAULT_CAP);
            w.run_to_quiescence(50_000_000);
            w.check_invariants();
            assert_eq!(w.trace.completions.len(), 160, "{}", proto.name());
        }
    }

    #[test]
    fn skeen_safe_with_singleton_groups() {
        let mut cfg = RunCfg::new(Proto::Skeen, 4, 6, 2, Net::Lan);
        cfg.max_requests = Some(25);
        cfg.record_full = true;
        let mut w = build_world(&cfg);
        w.enable_flight(crate::obs::flight::DEFAULT_CAP);
        w.run_to_quiescence(10_000_000);
        w.check_invariants();
        assert_eq!(w.trace.completions.len(), 150);
    }

    #[test]
    fn wbcast_beats_fastcast_beats_ftskeen_on_wan_latency() {
        let mut rows = Vec::new();
        for proto in Proto::EVAL {
            let mut cfg = RunCfg::new(proto, 3, 20, 2, Net::Wan);
            cfg.max_requests = Some(10);
            let r = run(&cfg);
            rows.push((proto, r.mean_lat_ms));
        }
        let wb = rows.iter().find(|r| r.0 == Proto::WbCast).unwrap().1;
        let fc = rows.iter().find(|r| r.0 == Proto::FastCast).unwrap().1;
        let ft = rows.iter().find(|r| r.0 == Proto::FtSkeen).unwrap().1;
        assert!(wb < fc, "WbCast {wb} !< FastCast {fc}");
        assert!(fc < ft, "FastCast {fc} !< FT-Skeen {ft}");
    }

    #[test]
    fn sharded_world_correct_per_shard() {
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 8, 2, Net::Lan);
        cfg.shards = 4;
        cfg.max_requests = Some(10);
        cfg.record_full = true;
        let mut w = build_world(&cfg);
        w.enable_flight(crate::obs::flight::DEFAULT_CAP);
        w.run_to_quiescence(50_000_000);
        w.check_invariants();
        // all 8 clients (2 per shard) completed their 10 requests
        assert_eq!(w.trace.completions.len(), 80);
    }

    /// Sharding the leaders lifts the CPU-saturation knee: same offered
    /// load, ≥1.5x the completed multicasts with 4 shards (each shard is
    /// an independent single-threaded server in the sim's cost model).
    #[test]
    fn sharding_lifts_saturation_throughput() {
        let thru = |shards: usize| {
            let mut cfg = RunCfg::new(Proto::WbCast, 2, 256, 2, Net::Lan);
            cfg.shards = shards;
            cfg.duration = 300 * MS;
            run(&cfg).throughput
        };
        let t1 = thru(1);
        let t4 = thru(4);
        assert!(t4 >= 1.5 * t1, "sharding gain below 1.5x: {t1:.0}/s -> {t4:.0}/s");
    }

    #[test]
    fn throughput_window_measurement() {
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 50, 1, Net::Lan);
        cfg.duration = 2_000 * MS;
        let r = run(&cfg);
        assert!(r.throughput > 1000.0, "throughput {}", r.throughput);
        assert!(r.mean_lat_ms < 10.0, "latency {}", r.mean_lat_ms);
    }
}
