//! Mini property-test driver (the offline image has no `proptest`).
//!
//! Runs a property over many seeded random cases; on failure it panics with
//! the offending seed so the case can be replayed exactly:
//!
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.range(1, 20);
//!     /* build random input, assert invariant */
//! });
//! ```
//!
//! Replay a single failure with [`check_seed`].

use super::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Base seed; override with env `WBAM_PROP_SEED` to explore other corners.
fn base_seed() -> u64 {
    std::env::var("WBAM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Number-of-cases multiplier; override with env `WBAM_PROP_CASES_MUL`.
fn cases_mul() -> u64 {
    std::env::var("WBAM_PROP_CASES_MUL")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Under Miri (CI runs the codec + MemWal property tests through it),
/// each case costs ~100-1000x native: run a small deterministic slice
/// of the case space instead of the full count. The interpreter checks
/// UB per operation, so shrinking the case count loses random-input
/// breadth (the native run keeps it) but not UB coverage.
fn cases_cap() -> u64 {
    if cfg!(miri) {
        8
    } else {
        u64::MAX
    }
}

/// Run `prop` over `cases` random cases (capped under Miri — see
/// [`cases_cap`]). Panics with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(cases: u64, mut prop: F) {
    let base = base_seed();
    for i in 0..(cases * cases_mul()).min(cases_cap()) {
        let seed = base ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {i} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case with an explicit seed.
pub fn check_seed<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            assert!(a + b <= 198);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check(50, |rng| {
                assert!(rng.below(10) < 5, "boom");
            })
        });
        let err = r.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "got: {msg}");
    }
}
