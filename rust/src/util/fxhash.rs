//! Minimal FxHash-style hasher (Firefox's multiply-rotate hash) for the
//! hot-path maps — std's default SipHash is DoS-resistant but ~3-5x
//! slower for the small integer keys (MsgId, Pid pairs) that dominate
//! the simulator and protocol state. Keys here are internal, so the
//! DoS-resistance is not needed. (No external crates offline.)

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, w: u64) {
        self.hash = (self.hash.rotate_left(5) ^ w).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(w));
        }
    }
    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// Drop-in HashMap/HashSet with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_small_keys() {
        let mut buckets = [0u32; 64];
        for i in 0u64..10_000 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            buckets[(h.finish() % 64) as usize] += 1;
        }
        // roughly uniform: no bucket more than 3x the mean
        assert!(buckets.iter().all(|&b| b < 3 * 10_000 / 64));
    }

    #[test]
    fn map_works() {
        let mut m: FxHashMap<(u32, u32), u64> = Default::default();
        for i in 0..100u32 {
            m.insert((i, i + 1), i as u64);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m[&(7, 8)], 7);
    }
}
