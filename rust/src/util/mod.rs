//! Small self-contained utilities: a seeded PRNG and a mini property-test
//! driver. The offline build has no `rand`/`proptest`, so these are
//! in-repo; the property driver reports failing seeds for replay.

pub mod fxhash;
pub mod prop;
pub mod rng;

pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;

/// Format a nanosecond quantity as a human-readable duration.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3}us", ns as f64 / 1e3)
    } else {
        format!("{}ns", ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(5), "5ns");
        assert_eq!(fmt_ns(1_500), "1.500us");
        assert_eq!(fmt_ns(2_000_000), "2.000ms");
        assert_eq!(fmt_ns(3_500_000_000), "3.500s");
    }
}
