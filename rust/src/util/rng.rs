//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Used everywhere randomness is needed (simulator jitter, workload
//! generation, property tests) so that every run is reproducible from a
//! single `u64` seed.

/// xoshiro256++ PRNG (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is fine: the state is
    /// expanded through SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Lemire's debiased multiply-shift.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with the given mean.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Choose a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct elements from `0..n` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Derive a child RNG (for independent sub-streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let mean = 10.0;
        let sum: f64 = (0..n).map(|_| r.exp(mean)).sum();
        let m = sum / n as f64;
        assert!((m - mean).abs() < 0.2, "sample mean {m}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let s = r.sample_indices(10, 4);
            assert_eq!(s.len(), 4);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 4, "duplicates in {s:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
