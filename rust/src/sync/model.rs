//! Miniature CHESS-style model checker backing the `cfg(loom)` build.
//!
//! The offline image cannot fetch the real `loom` crate, so this module
//! provides the same *shape*: drop-in `Mutex`/atomic/`mpsc`/`thread`
//! types (re-exported through [`crate::sync`] under `--cfg loom`) plus a
//! [`model`] entry point that runs a closure under **every** distinct
//! thread interleaving the scheduler can produce, up to a preemption
//! bound.
//!
//! ## How it works
//!
//! Threads are real OS threads, but they execute one at a time: a token
//! (`SchedState::active`) names the only thread allowed to run, and every
//! synchronization operation (atomic access, mutex lock/unlock, channel
//! send/recv, spawn/join/yield) is a *scheduling point* that may hand the
//! token to a different runnable thread. Which thread runs next is a
//! recorded `Choice`; the driver performs an iterative-deepening DFS over
//! the choice tree: replay a prefix, take first-choices to the end,
//! then advance the deepest non-exhausted choice and repeat. When the
//! tree is exhausted the run prints how many interleavings it explored.
//!
//! Bounds (all overridable by env var):
//!
//! * `WBAM_LOOM_PREEMPTION_BOUND` (default 3) — maximum *involuntary*
//!   context switches per execution, the classic CHESS bound; voluntary
//!   switches (block on a lock/empty channel, join, finish) are free.
//! * `WBAM_LOOM_MAX_EXECUTIONS` (default 500_000) — hard cap on explored
//!   interleavings; exceeding it panics loudly rather than silently
//!   truncating coverage.
//!
//! ## Semantics and limitations
//!
//! * Atomics wrap the real `std` atomics and accept `Ordering` arguments,
//!   but the checker explores *sequentially consistent* interleavings
//!   only — it does not model C11 weak-memory reorderings (neither does
//!   CHESS; loom does). What it does catch: lost updates, ordering bugs
//!   between threads, deadlocks, shutdown races, and any assertion
//!   failure reachable by interleaving at synchronization granularity.
//! * `mpsc::Receiver::recv_timeout` treats the timeout as a
//!   nondeterministic choice, allowed at most once consecutively per
//!   channel while senders are alive. This explores the idle-tick path
//!   of `run_flusher`/`ShardWorker` exactly once per quiet stretch and
//!   keeps the state space finite.
//! * Outside a [`model`] run every primitive degrades to plain `std`
//!   behavior, so a `--cfg loom` build of the whole crate (bins, tests,
//!   examples) stays fully functional.
//! * `Arc` and `OnceLock` are re-exported from `std` unchanged: refcounts
//!   and one-time init are not the race surfaces under test here.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering as StdOrdering};
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};

pub use std::sync::{Arc, OnceLock};

type Tid = usize;

/// Steps (scheduling points) allowed in one execution before we assume a
/// livelock and abort the run.
const MAX_STEPS: u64 = 1_000_000;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// One recorded scheduling decision: which of `options` alternatives was
/// taken. The DFS driver advances the deepest non-exhausted `chosen`.
#[derive(Clone, Copy, Debug)]
struct Choice {
    chosen: usize,
    options: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BlockedOn {
    /// Waiting for the mutex with this object id to unlock.
    Mutex(usize),
    /// Waiting for a send (or disconnect) on the channel with this id.
    Recv(usize),
    /// Waiting for this thread to finish.
    Join(Tid),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    Runnable,
    Blocked(BlockedOn),
    Finished,
}

struct SchedState {
    statuses: Vec<Status>,
    /// The one thread currently allowed to execute.
    active: Tid,
    /// Choice sequence: replayed up to `pos`, extended (first-choice) after.
    path: Vec<Choice>,
    pos: usize,
    steps: u64,
    preemptions: u64,
    /// Set on failure/deadlock/cap: every parked thread wakes and unwinds.
    abort: Option<String>,
}

pub(crate) struct Scheduler {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    preemption_bound: u64,
}

thread_local! {
    static CURRENT: RefCell<Option<(StdArc<Scheduler>, Tid)>> = RefCell::new(None);
}

fn ctx() -> Option<(StdArc<Scheduler>, Tid)> {
    CURRENT.with(|c| c.borrow().clone())
}

fn set_ctx(v: Option<(StdArc<Scheduler>, Tid)>) {
    CURRENT.with(|c| *c.borrow_mut() = v);
}

/// A scheduling point for the calling thread, if a model run is active.
fn point() {
    if let Some((s, t)) = ctx() {
        s.sched_point(t);
    }
}

static NEXT_OBJ_ID: StdAtomicUsize = StdAtomicUsize::new(1);

fn next_obj_id() -> usize {
    NEXT_OBJ_ID.fetch_add(1, StdOrdering::Relaxed)
}

impl Scheduler {
    /// Lock the scheduler state, shrugging off poisoning: a step-cap or
    /// deadlock panic may unwind while holding this lock, and every other
    /// thread still needs to observe `abort` to shut down cleanly.
    fn st(&self) -> StdMutexGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn cv_wait<'a>(&self, st: StdMutexGuard<'a, SchedState>) -> StdMutexGuard<'a, SchedState> {
        self.cv.wait(st).unwrap_or_else(|p| p.into_inner())
    }

    fn new(prefix: Vec<Choice>, preemption_bound: u64) -> Self {
        Scheduler {
            state: StdMutex::new(SchedState {
                statuses: vec![Status::Runnable], // tid 0 is the root closure
                active: 0,
                path: prefix,
                pos: 0,
                steps: 0,
                preemptions: 0,
                abort: None,
            }),
            cv: StdCondvar::new(),
            preemption_bound,
        }
    }

    /// Runnable tids with `prefer` (the caller) rotated to the front, so
    /// choice 0 always means "keep running the current thread" and the
    /// first-choice path is the sequential execution.
    fn runnable_locked(st: &SchedState, prefer: Tid) -> Vec<Tid> {
        let mut r: Vec<Tid> = (0..st.statuses.len())
            .filter(|&t| st.statuses[t] == Status::Runnable)
            .collect();
        if let Some(i) = r.iter().position(|&t| t == prefer) {
            r.rotate_left(i);
        }
        r
    }

    /// Replay or record one decision among `options` alternatives.
    fn choose_locked(st: &mut SchedState, options: usize) -> usize {
        debug_assert!(options >= 1);
        if st.pos < st.path.len() {
            let c = st.path[st.pos];
            assert_eq!(
                c.options, options,
                "loom model: nondeterministic replay (program makes decisions \
                 not controlled by the scheduler — wall clock? randomness?)"
            );
            st.pos += 1;
            c.chosen
        } else {
            st.path.push(Choice { chosen: 0, options });
            st.pos += 1;
            0
        }
    }

    fn bump_steps_locked(&self, st: &mut SchedState) {
        st.steps += 1;
        if st.steps > MAX_STEPS {
            let r = format!("loom model: execution exceeded {MAX_STEPS} scheduling points (livelock?)");
            st.abort = Some(r.clone());
            self.cv.notify_all();
            // Never double-panic: scheduling points run inside Drop impls,
            // which may themselves execute during an unwind.
            if !std::thread::panicking() {
                panic!("{r}");
            }
        }
    }

    /// Park until this thread holds the token; panics if the run aborts.
    fn wait_until_active<'a>(
        &self,
        mut st: StdMutexGuard<'a, SchedState>,
        tid: Tid,
    ) -> StdMutexGuard<'a, SchedState> {
        loop {
            if let Some(r) = &st.abort {
                let r = r.clone();
                drop(st);
                panic!("{r}");
            }
            if st.active == tid && st.statuses[tid] == Status::Runnable {
                return st;
            }
            st = self.cv_wait(st);
        }
    }

    /// The heart of the checker: maybe hand the token to another runnable
    /// thread. Quiet (no panic) when the run is aborting, because this is
    /// called from `Drop` impls on unwind paths.
    fn sched_point(&self, tid: Tid) {
        let mut st = self.st();
        if st.abort.is_some() {
            return;
        }
        self.bump_steps_locked(&mut st);
        if st.abort.is_some() {
            return;
        }
        let runnable = Self::runnable_locked(&st, tid);
        if runnable.len() <= 1 || st.preemptions >= self.preemption_bound {
            return;
        }
        let idx = Self::choose_locked(&mut st, runnable.len());
        let next = runnable[idx];
        if next != tid {
            st.preemptions += 1;
            st.active = next;
            self.cv.notify_all();
            let st = self.wait_until_active(st, tid);
            drop(st);
        }
    }

    /// An explicit data choice (e.g. "does this recv_timeout fire?").
    /// Not a context switch; never counts as a preemption.
    fn choice(&self, _tid: Tid, options: usize) -> usize {
        let mut st = self.st();
        if st.abort.is_some() {
            return 0;
        }
        self.bump_steps_locked(&mut st);
        if st.abort.is_some() {
            return 0;
        }
        Self::choose_locked(&mut st, options)
    }

    /// Block the calling thread on `on` and hand the token to some
    /// runnable thread (a free, non-preemptive switch). Returns once a
    /// waker marks us runnable and a scheduling decision picks us.
    fn block_on(&self, tid: Tid, on: BlockedOn) {
        let mut st = self.st();
        if let Some(r) = &st.abort {
            let r = r.clone();
            drop(st);
            panic!("{r}");
        }
        self.bump_steps_locked(&mut st);
        if let Some(r) = &st.abort {
            let r = r.clone();
            drop(st);
            panic!("{r}");
        }
        st.statuses[tid] = Status::Blocked(on);
        let runnable = Self::runnable_locked(&st, tid);
        if runnable.is_empty() {
            let r = format!(
                "loom model: deadlock — thread {tid} blocked on {on:?} with no runnable thread left"
            );
            st.abort = Some(r.clone());
            self.cv.notify_all();
            drop(st);
            panic!("{r}");
        }
        let idx = if runnable.len() > 1 { Self::choose_locked(&mut st, runnable.len()) } else { 0 };
        st.active = runnable[idx];
        self.cv.notify_all();
        let st = self.wait_until_active(st, tid);
        drop(st);
    }

    /// Mark every thread blocked on `on` runnable again (they run when a
    /// later scheduling decision picks them). Quiet on abort: called from
    /// `Drop` impls.
    fn wake(&self, on: BlockedOn) {
        let mut st = self.st();
        for s in st.statuses.iter_mut() {
            if *s == Status::Blocked(on) {
                *s = Status::Runnable;
            }
        }
    }

    /// Register a newly spawned thread; it starts Runnable but parks in
    /// `wait_for_start` until a scheduling decision gives it the token.
    fn register_thread(&self) -> Tid {
        let mut st = self.st();
        st.statuses.push(Status::Runnable);
        st.statuses.len() - 1
    }

    fn wait_for_start(&self, tid: Tid) {
        let st = self.st();
        let st = self.wait_until_active(st, tid);
        drop(st);
    }

    /// Terminal bookkeeping for a finished thread: wake joiners, hand the
    /// token onward. Never panics — runs after the closure's result is
    /// already stored, including on abort paths.
    fn finish(&self, tid: Tid) {
        let mut st = self.st();
        st.statuses[tid] = Status::Finished;
        for s in st.statuses.iter_mut() {
            if *s == Status::Blocked(BlockedOn::Join(tid)) {
                *s = Status::Runnable;
            }
        }
        if st.abort.is_none() {
            let runnable = Self::runnable_locked(&st, tid);
            if let Some(&first) = runnable.first() {
                let idx =
                    if runnable.len() > 1 { Self::choose_locked(&mut st, runnable.len()) } else { 0 };
                st.active = if idx == 0 { first } else { runnable[idx] };
            } else if st.statuses.iter().any(|s| matches!(s, Status::Blocked(_))) {
                st.abort = Some(
                    "loom model: deadlock — a thread finished leaving only blocked threads".into(),
                );
            }
        }
        self.cv.notify_all();
    }

    /// Wait (as thread `me`) until `target` has finished.
    fn join_wait(&self, me: Tid, target: Tid) {
        self.sched_point(me);
        loop {
            {
                let st = self.st();
                if let Some(r) = &st.abort {
                    let r = r.clone();
                    drop(st);
                    panic!("{r}");
                }
                if st.statuses[target] == Status::Finished {
                    return;
                }
            }
            // Only one thread runs at a time, so `target` cannot finish
            // between the check above and blocking here.
            self.block_on(me, BlockedOn::Join(target));
        }
    }

    /// Root closure returned normally: mark tid 0 finished and drive the
    /// remaining threads until everyone has finished.
    fn finish_root(&self) {
        let mut st = self.st();
        st.statuses[0] = Status::Finished;
        loop {
            if let Some(r) = &st.abort {
                let r = r.clone();
                drop(st);
                panic!("{r}");
            }
            if st.statuses.iter().all(|s| *s == Status::Finished) {
                self.cv.notify_all();
                return;
            }
            let runnable = Self::runnable_locked(&st, st.active);
            if runnable.is_empty() {
                let r = "loom model: deadlock — root finished but other threads are blocked"
                    .to_string();
                st.abort = Some(r.clone());
                self.cv.notify_all();
                drop(st);
                panic!("{r}");
            }
            if st.statuses[st.active] != Status::Runnable {
                let idx =
                    if runnable.len() > 1 { Self::choose_locked(&mut st, runnable.len()) } else { 0 };
                st.active = runnable[idx];
            }
            self.cv.notify_all();
            st = self.cv_wait(st);
        }
    }

    /// Root closure panicked: abort the run and reap every worker thread
    /// (they wake from their park loops, unwind, and mark Finished).
    fn abort_all(&self) {
        let mut st = self.st();
        st.statuses[0] = Status::Finished;
        if st.abort.is_none() {
            st.abort = Some("loom model: run aborted (failure on another thread)".into());
        }
        self.cv.notify_all();
        while !st.statuses.iter().all(|s| *s == Status::Finished) {
            st = self.cv_wait(st);
        }
    }
}

/// Advance to the next unexplored schedule: bump the deepest
/// non-exhausted choice, dropping exhausted tails. `None` = done.
fn next_prefix(mut path: Vec<Choice>) -> Option<Vec<Choice>> {
    while let Some(last) = path.last_mut() {
        if last.chosen + 1 < last.options {
            last.chosen += 1;
            return Some(path);
        }
        path.pop();
    }
    None
}

fn run_one<F: Fn()>(f: &F, prefix: Vec<Choice>, bound: u64) -> (std::thread::Result<()>, Vec<Choice>) {
    let sched = StdArc::new(Scheduler::new(prefix, bound));
    set_ctx(Some((sched.clone(), 0)));
    let r = catch_unwind(AssertUnwindSafe(|| {
        f();
        sched.finish_root();
    }));
    if r.is_err() {
        sched.abort_all();
    }
    set_ctx(None);
    let path = sched.st().path.clone();
    (r, path)
}

/// Run `f` under every schedule the bounded DFS can produce. Panics (by
/// re-raising `f`'s panic) on the first failing interleaving; prints the
/// number of interleavings explored on success.
pub fn model<F: Fn()>(f: F) {
    let max_execs = env_u64("WBAM_LOOM_MAX_EXECUTIONS", 500_000);
    let bound = env_u64("WBAM_LOOM_PREEMPTION_BOUND", 3);
    let mut prefix = Vec::new();
    let mut execs: u64 = 0;
    loop {
        execs += 1;
        if execs > max_execs {
            panic!(
                "loom model: exceeded {max_execs} executions without exhausting the schedule \
                 space; shrink the test or raise WBAM_LOOM_MAX_EXECUTIONS"
            );
        }
        let (r, path) = run_one(&f, prefix, bound);
        if let Err(e) = r {
            eprintln!(
                "loom model: FAILED on interleaving {execs} (after {} passing)",
                execs - 1
            );
            resume_unwind(e);
        }
        match next_prefix(path) {
            Some(p) => prefix = p,
            None => break,
        }
    }
    eprintln!("loom model: explored {execs} interleavings");
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

pub use std::sync::{LockResult, PoisonError, TryLockError, TryLockResult};

/// Model-checked mutex: `std::sync::Mutex` plus a scheduling point on
/// lock/unlock and blocking via the scheduler instead of the OS.
pub struct Mutex<T> {
    id: usize,
    inner: StdMutex<T>,
}

pub struct MutexGuard<'a, T> {
    sched: Option<(StdArc<Scheduler>, Tid)>,
    id: usize,
    inner: Option<StdMutexGuard<'a, T>>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Self {
        Mutex { id: next_obj_id(), inner: StdMutex::new(t) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((s, tid)) = ctx() {
            s.sched_point(tid);
            loop {
                match self.inner.try_lock() {
                    Ok(g) => {
                        return Ok(MutexGuard {
                            sched: Some((s, tid)),
                            id: self.id,
                            inner: Some(g),
                        })
                    }
                    Err(TryLockError::Poisoned(p)) => {
                        return Err(PoisonError::new(MutexGuard {
                            sched: Some((s, tid)),
                            id: self.id,
                            inner: Some(p.into_inner()),
                        }))
                    }
                    // Held by another (suspended) thread: block until its
                    // guard drop wakes us.
                    Err(TryLockError::WouldBlock) => s.block_on(tid, BlockedOn::Mutex(self.id)),
                }
            }
        } else {
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { sched: None, id: self.id, inner: Some(g) }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    sched: None,
                    id: self.id,
                    inner: Some(p.into_inner()),
                })),
            }
        }
    }

    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        let sched = ctx();
        if let Some((s, tid)) = &sched {
            s.sched_point(*tid);
        }
        match self.inner.try_lock() {
            Ok(g) => Ok(MutexGuard { sched, id: self.id, inner: Some(g) }),
            Err(TryLockError::Poisoned(p)) => {
                Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                    sched,
                    id: self.id,
                    inner: Some(p.into_inner()),
                })))
            }
            Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").field("inner", &self.inner).finish()
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().unwrap()
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().unwrap()
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the OS lock first, then let blocked threads race for it.
        drop(self.inner.take());
        if let Some((s, t)) = self.sched.take() {
            s.wake(BlockedOn::Mutex(self.id));
            s.sched_point(t); // quiet on abort: safe during unwind
        }
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

pub mod atomic {
    //! Model-checked atomics. Each operation is one scheduling point; the
    //! underlying op is the real `std` atomic, explored under sequential
    //! consistency regardless of the `Ordering` passed.
    pub use std::sync::atomic::Ordering;

    use super::point;

    macro_rules! int_atomic {
        ($name:ident, $std:ty, $prim:ty) => {
            #[derive(Debug, Default)]
            pub struct $name($std);

            impl $name {
                pub const fn new(v: $prim) -> Self {
                    Self(<$std>::new(v))
                }
                pub fn load(&self, o: Ordering) -> $prim {
                    point();
                    self.0.load(o)
                }
                pub fn store(&self, v: $prim, o: Ordering) {
                    point();
                    self.0.store(v, o)
                }
                pub fn swap(&self, v: $prim, o: Ordering) -> $prim {
                    point();
                    self.0.swap(v, o)
                }
                pub fn fetch_add(&self, v: $prim, o: Ordering) -> $prim {
                    point();
                    self.0.fetch_add(v, o)
                }
                pub fn fetch_sub(&self, v: $prim, o: Ordering) -> $prim {
                    point();
                    self.0.fetch_sub(v, o)
                }
                pub fn fetch_max(&self, v: $prim, o: Ordering) -> $prim {
                    point();
                    self.0.fetch_max(v, o)
                }
                pub fn compare_exchange(
                    &self,
                    current: $prim,
                    new: $prim,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$prim, $prim> {
                    point();
                    self.0.compare_exchange(current, new, success, failure)
                }
            }
        };
    }

    int_atomic!(AtomicU16, std::sync::atomic::AtomicU16, u16);
    int_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
    int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

    #[derive(Debug, Default)]
    pub struct AtomicBool(std::sync::atomic::AtomicBool);

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            Self(std::sync::atomic::AtomicBool::new(v))
        }
        pub fn load(&self, o: Ordering) -> bool {
            point();
            self.0.load(o)
        }
        pub fn store(&self, v: bool, o: Ordering) {
            point();
            self.0.store(v, o)
        }
        pub fn swap(&self, v: bool, o: Ordering) -> bool {
            point();
            self.0.swap(v, o)
        }
        pub fn fetch_or(&self, v: bool, o: Ordering) -> bool {
            point();
            self.0.fetch_or(v, o)
        }
        pub fn compare_exchange(
            &self,
            current: bool,
            new: bool,
            success: Ordering,
            failure: Ordering,
        ) -> Result<bool, bool> {
            point();
            self.0.compare_exchange(current, new, success, failure)
        }
    }
}

// ---------------------------------------------------------------------------
// mpsc
// ---------------------------------------------------------------------------

pub mod mpsc {
    //! Model-checked unbounded channel with `std::sync::mpsc`'s API and
    //! error types. In a model run, blocking goes through the scheduler
    //! and `recv_timeout` is a bounded nondeterministic choice; outside
    //! one it is a plain condvar queue.
    pub use std::sync::mpsc::{RecvError, RecvTimeoutError, SendError, TryRecvError};

    use super::{ctx, next_obj_id, BlockedOn};
    use std::collections::VecDeque;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::{Duration, Instant};

    struct Inner<T> {
        queue: VecDeque<T>,
        senders: usize,
        receiver_alive: bool,
        /// True right after a model-mode recv_timeout chose to time out;
        /// suppresses a second consecutive timeout so idle-tick loops
        /// stay finite. Reset by every send and successful recv.
        timeout_streak: bool,
    }

    struct Shared<T> {
        id: usize,
        m: Mutex<Inner<T>>,
        cv: Condvar,
    }

    pub struct Sender<T>(Arc<Shared<T>>);
    pub struct Receiver<T>(Arc<Shared<T>>);

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let shared = Arc::new(Shared {
            id: next_obj_id(),
            m: Mutex::new(Inner {
                queue: VecDeque::new(),
                senders: 1,
                receiver_alive: true,
                timeout_streak: false,
            }),
            cv: Condvar::new(),
        });
        (Sender(shared.clone()), Receiver(shared))
    }

    impl<T> Sender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            super::point();
            let mut q = self.0.m.lock().unwrap();
            if !q.receiver_alive {
                return Err(SendError(t));
            }
            q.queue.push_back(t);
            q.timeout_streak = false;
            drop(q);
            if let Some((s, _)) = ctx() {
                s.wake(BlockedOn::Recv(self.0.id));
            }
            self.0.cv.notify_one();
            Ok(())
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            self.0.m.lock().unwrap().senders += 1;
            Sender(self.0.clone())
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut q = self.0.m.lock().unwrap();
            q.senders -= 1;
            let last = q.senders == 0;
            drop(q);
            if last {
                // Disconnect is observable: wake any parked receiver.
                if let Some((s, _)) = ctx() {
                    s.wake(BlockedOn::Recv(self.0.id));
                }
                self.0.cv.notify_all();
            }
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            if let Some((s, tid)) = ctx() {
                s.sched_point(tid);
                loop {
                    {
                        let mut q = self.0.m.lock().unwrap();
                        if let Some(v) = q.queue.pop_front() {
                            q.timeout_streak = false;
                            return Ok(v);
                        }
                        if q.senders == 0 {
                            return Err(RecvError);
                        }
                    }
                    s.block_on(tid, BlockedOn::Recv(self.0.id));
                }
            } else {
                let mut q = self.0.m.lock().unwrap();
                loop {
                    if let Some(v) = q.queue.pop_front() {
                        return Ok(v);
                    }
                    if q.senders == 0 {
                        return Err(RecvError);
                    }
                    q = self.0.cv.wait(q).unwrap();
                }
            }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            super::point();
            let mut q = self.0.m.lock().unwrap();
            if let Some(v) = q.queue.pop_front() {
                q.timeout_streak = false;
                return Ok(v);
            }
            if q.senders == 0 {
                Err(TryRecvError::Disconnected)
            } else {
                Err(TryRecvError::Empty)
            }
        }

        pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
            if let Some((s, tid)) = ctx() {
                s.sched_point(tid);
                loop {
                    {
                        let mut q = self.0.m.lock().unwrap();
                        if let Some(v) = q.queue.pop_front() {
                            q.timeout_streak = false;
                            return Ok(v);
                        }
                        if q.senders == 0 {
                            return Err(RecvTimeoutError::Disconnected);
                        }
                        // Model time: "did the timeout fire before a send?"
                        // is a schedule choice, allowed at most once in a
                        // row so idle loops terminate.
                        if !q.timeout_streak && s.choice(tid, 2) == 1 {
                            q.timeout_streak = true;
                            return Err(RecvTimeoutError::Timeout);
                        }
                    }
                    s.block_on(tid, BlockedOn::Recv(self.0.id));
                }
            } else {
                let deadline = Instant::now() + timeout;
                let mut q = self.0.m.lock().unwrap();
                loop {
                    if let Some(v) = q.queue.pop_front() {
                        return Ok(v);
                    }
                    if q.senders == 0 {
                        return Err(RecvTimeoutError::Disconnected);
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(RecvTimeoutError::Timeout);
                    }
                    let (g, _) = self.0.cv.wait_timeout(q, deadline - now).unwrap();
                    q = g;
                }
            }
        }

        pub fn iter(&self) -> Iter<'_, T> {
            Iter { rx: self }
        }

        pub fn try_iter(&self) -> TryIter<'_, T> {
            TryIter { rx: self }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            self.0.m.lock().unwrap().receiver_alive = false;
        }
    }

    pub struct Iter<'a, T> {
        rx: &'a Receiver<T>,
    }

    impl<T> Iterator for Iter<'_, T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }

    pub struct TryIter<'a, T> {
        rx: &'a Receiver<T>,
    }

    impl<T> Iterator for TryIter<'_, T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.try_recv().ok()
        }
    }

    impl<'a, T> IntoIterator for &'a Receiver<T> {
        type Item = T;
        type IntoIter = Iter<'a, T>;
        fn into_iter(self) -> Iter<'a, T> {
            self.iter()
        }
    }

    impl<T> std::fmt::Debug for Sender<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("Sender { .. }")
        }
    }

    impl<T> std::fmt::Debug for Receiver<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("Receiver { .. }")
        }
    }
}

// ---------------------------------------------------------------------------
// thread
// ---------------------------------------------------------------------------

pub mod thread {
    //! Model-checked threads. Inside a model run, spawned closures run on
    //! real OS threads but only when the scheduler hands them the token;
    //! `sleep` is a pure scheduling point (model time does not pass).
    pub use std::thread::{current, Result};

    use super::{ctx, set_ctx, Scheduler, Tid};
    use std::io;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc as StdArc, Mutex as StdMutex};
    use std::time::Duration;

    pub struct Builder {
        inner: std::thread::Builder,
    }

    enum Imp<T> {
        Model {
            tid: Tid,
            slot: StdArc<StdMutex<Option<Result<T>>>>,
            real: Option<std::thread::JoinHandle<()>>,
            sched: StdArc<Scheduler>,
        },
        Real(std::thread::JoinHandle<T>),
    }

    pub struct JoinHandle<T>(Imp<T>);

    impl Builder {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Builder {
            Builder { inner: std::thread::Builder::new() }
        }

        pub fn name(self, name: String) -> Builder {
            Builder { inner: self.inner.name(name) }
        }

        pub fn spawn<F, T>(self, f: F) -> io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            match ctx() {
                Some((sched, me)) => {
                    sched.sched_point(me);
                    let tid = sched.register_thread();
                    let slot: StdArc<StdMutex<Option<Result<T>>>> =
                        StdArc::new(StdMutex::new(None));
                    let slot2 = slot.clone();
                    let sched2 = sched.clone();
                    let real = self.inner.spawn(move || {
                        set_ctx(Some((sched2.clone(), tid)));
                        // Everything — including the park-for-token, which
                        // panics on abort — stays inside catch_unwind so
                        // `finish` always runs and the driver can reap us.
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            sched2.wait_for_start(tid);
                            f()
                        }));
                        *slot2.lock().unwrap() = Some(r);
                        set_ctx(None);
                        sched2.finish(tid);
                    })?;
                    Ok(JoinHandle(Imp::Model { tid, slot, real: Some(real), sched }))
                }
                None => self.inner.spawn(f).map(|h| JoinHandle(Imp::Real(h))),
            }
        }
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> Result<T> {
            match self.0 {
                Imp::Model { tid, slot, real, sched } => {
                    let (_, me) = ctx().expect("model JoinHandle joined outside the model run");
                    sched.join_wait(me, tid);
                    if let Some(r) = real {
                        // Logically finished; the OS thread exits momentarily.
                        let _ = r.join();
                    }
                    slot.lock().unwrap().take().expect("joined thread stored no result")
                }
                Imp::Real(h) => h.join(),
            }
        }

        pub fn is_finished(&self) -> bool {
            match &self.0 {
                Imp::Model { real, .. } => {
                    real.as_ref().map(|r| r.is_finished()).unwrap_or(true)
                }
                Imp::Real(h) => h.is_finished(),
            }
        }
    }

    impl<T> std::fmt::Debug for JoinHandle<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("JoinHandle { .. }")
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    pub fn yield_now() {
        if let Some((s, t)) = ctx() {
            s.sched_point(t);
        } else {
            std::thread::yield_now();
        }
    }

    pub fn sleep(d: Duration) {
        if let Some((s, t)) = ctx() {
            let _ = d; // model time does not pass
            s.sched_point(t);
        } else {
            std::thread::sleep(d);
        }
    }
}

// ---------------------------------------------------------------------------
// Self-tests for the checker itself
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize as RawUsize, Ordering as RawOrdering};
    use std::sync::Arc as StdArc;

    /// Two communicating threads must yield more than one interleaving.
    #[test]
    fn loom_model_explores_multiple_interleavings() {
        let execs = StdArc::new(RawUsize::new(0));
        let execs2 = execs.clone();
        model(move || {
            execs2.fetch_add(1, RawOrdering::Relaxed);
            let a = StdArc::new(atomic::AtomicU64::new(0));
            let a2 = a.clone();
            let h = thread::spawn(move || {
                a2.store(1, atomic::Ordering::SeqCst);
            });
            // Load may see 0 or 1 depending on schedule.
            let _ = a.load(atomic::Ordering::SeqCst);
            h.join().unwrap();
        });
        assert!(
            execs.load(RawOrdering::Relaxed) > 1,
            "expected >1 explored interleavings, got {}",
            execs.load(RawOrdering::Relaxed)
        );
    }

    /// The classic lost-update: unsynchronized read-modify-write on an
    /// atomic. The checker must find the schedule where an increment is
    /// lost.
    #[test]
    fn loom_model_finds_lost_update() {
        let r = std::panic::catch_unwind(|| {
            model(|| {
                let a = StdArc::new(atomic::AtomicU64::new(0));
                let a2 = a.clone();
                let h = thread::spawn(move || {
                    let v = a2.load(atomic::Ordering::SeqCst);
                    a2.store(v + 1, atomic::Ordering::SeqCst);
                });
                let v = a.load(atomic::Ordering::SeqCst);
                a.store(v + 1, atomic::Ordering::SeqCst);
                h.join().unwrap();
                assert_eq!(a.load(atomic::Ordering::SeqCst), 2, "lost update");
            });
        });
        assert!(r.is_err(), "model failed to find the lost-update interleaving");
    }

    /// ABBA lock ordering must be reported as a deadlock, not a hang.
    #[test]
    fn loom_model_detects_deadlock() {
        let r = std::panic::catch_unwind(|| {
            model(|| {
                let a = StdArc::new(Mutex::new(0u32));
                let b = StdArc::new(Mutex::new(0u32));
                let (a2, b2) = (a.clone(), b.clone());
                let h = thread::spawn(move || {
                    let _ga = a2.lock().unwrap();
                    let _gb = b2.lock().unwrap();
                });
                {
                    let _gb = b.lock().unwrap();
                    let _ga = a.lock().unwrap();
                }
                h.join().unwrap();
            });
        });
        let msg = match r {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "<non-string panic>".into()),
            Ok(()) => panic!("model failed to find the ABBA deadlock"),
        };
        assert!(msg.contains("deadlock"), "unexpected failure: {msg}");
    }

    /// Channel send/recv plus disconnect: every sent value is received
    /// in every schedule, and disconnect is seen after drain.
    #[test]
    fn loom_model_channel_drains_before_disconnect() {
        model(|| {
            let (tx, rx) = mpsc::channel();
            let h = thread::spawn(move || {
                for i in 0..3u32 {
                    tx.send(i).unwrap();
                }
            });
            let mut got = Vec::new();
            loop {
                match rx.recv() {
                    Ok(v) => got.push(v),
                    Err(mpsc::RecvError) => break,
                }
            }
            h.join().unwrap();
            assert_eq!(got, vec![0, 1, 2]);
        });
    }

    /// recv_timeout in the model: timeout is explored but bounded, so
    /// this terminates and still always drains the queued value.
    #[test]
    fn loom_model_recv_timeout_is_bounded() {
        model(|| {
            let (tx, rx) = mpsc::channel();
            let h = thread::spawn(move || {
                tx.send(7u32).unwrap();
            });
            let mut got = None;
            loop {
                match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                    Ok(v) => got = Some(v),
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            h.join().unwrap();
            assert_eq!(got, Some(7));
        });
    }
}
