//! Synchronization facade: the one import path for every concurrency
//! primitive the runtime uses.
//!
//! In a normal build this module is a plain re-export of `std::sync` /
//! `std::thread`, so it costs nothing. Under `--cfg loom` the same names
//! resolve to the model-checked equivalents in [`model`], and the
//! `loom_`-prefixed tests drive the real runtime code (`run_flusher`,
//! shard workers, storage poison, `NetStats`) through **every** bounded
//! thread interleaving:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release loom_
//! ```
//!
//! The migrated modules — `coordinator`, `net` (except the raw-syscall
//! transports, see below), `storage`, `protocols::outbox` callers — must
//! not name `std::sync`/`std::thread` primitives directly; the
//! `cargo xtask lint` gate (rule `sync-facade`) enforces this. The
//! epoll/uring transports are exempt: their atomics live in
//! kernel-shared mmap'd rings and must remain real `std` atomics.
//!
//! `Arc` and `OnceLock` are `std`'s in both worlds: refcounting and
//! one-time init are not the race surfaces the model explores, and
//! keeping them `std` lets model-mode types interoperate with
//! non-modeled code.

#[cfg(loom)]
pub mod model;

#[cfg(loom)]
pub use model::{atomic, model, mpsc, thread, Arc, Mutex, MutexGuard, OnceLock};

#[cfg(not(loom))]
pub use std::sync::{atomic, mpsc, Arc, Mutex, MutexGuard, OnceLock};

#[cfg(not(loom))]
pub use std::thread;
