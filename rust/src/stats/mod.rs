//! Metrics: log-bucketed latency histogram (HdrHistogram-style, built
//! in-repo — the offline image has no hdrhistogram crate), percentile
//! estimation and throughput time-bins.

/// Log-bucketed histogram for latencies in nanoseconds.
///
/// Buckets have ~2% relative width (64 sub-buckets per octave), covering
/// 1 ns .. ~584 years; memory is a flat `Vec<u64>`.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 6; // 64 sub-buckets per octave
const SUB: u64 = 1 << SUB_BITS;

fn bucket_of(v: u64) -> usize {
    let v = v.max(1);
    let msb = 63 - v.leading_zeros() as u64;
    if msb < SUB_BITS as u64 {
        return v as usize;
    }
    let shift = msb - SUB_BITS as u64;
    let sub = (v >> shift) - SUB;
    ((msb - SUB_BITS as u64 + 1) * SUB as u64 + sub) as usize
}

fn bucket_lower(b: usize) -> u64 {
    let b = b as u64;
    if b < SUB * 2 {
        // Buckets below two octaves are exact: lower bound == index.
        return b;
    }
    let octave = b / SUB - 1;
    let sub = b % SUB;
    (SUB + sub) << octave
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; bucket_of(u64::MAX) + 1], total: 0, sum: 0.0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Value at quantile `q` in [0, 1] (bucket lower bound; ≤2% error).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut acc = 0;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_lower(b).max(self.min).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Reset to the empty state without reallocating the bucket vector.
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum = 0.0;
        self.min = u64::MAX;
        self.max = 0;
    }

    /// Drain the window accumulated since the previous call: returns a
    /// histogram holding everything recorded so far and leaves `self`
    /// empty. Lets an exporter report *interval* percentiles (per scrape
    /// window) instead of lifetime ones.
    pub fn take_window(&mut self) -> Histogram {
        let mut out = Histogram::new();
        std::mem::swap(self, &mut out);
        out
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Raw (quantile, value) sketch rows for the XLA quantile artifact /
    /// reporting.
    pub fn snapshot(&self, qs: &[f64]) -> Vec<(f64, u64)> {
        qs.iter().map(|&q| (q, self.quantile(q))).collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn exact_for_small_values() {
        let mut h = Histogram::new();
        for v in 1..=50 {
            h.record(v);
        }
        assert_eq!(h.count(), 50);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 50);
        assert_eq!(h.p50(), 25);
        assert!((h.mean() - 25.5).abs() < 1e-9);
    }

    #[test]
    fn quantile_relative_error_bounded() {
        prop::check(50, |r: &mut Rng| {
            let mut h = Histogram::new();
            let mut vals: Vec<u64> = (0..500).map(|_| r.range(1, 10_000_000_000)).collect();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_unstable();
            for &(q, idx) in &[(0.5f64, 249usize), (0.9, 449), (0.99, 494)] {
                let est = h.quantile(q);
                let tru = vals[idx];
                let rel = (est as f64 - tru as f64).abs() / tru as f64;
                assert!(rel < 0.05, "q={q}: est {est} vs true {tru} (rel {rel})");
            }
        });
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut r = Rng::new(3);
        for _ in 0..200 {
            let v = r.range(1, 1_000_000);
            if r.chance(0.5) {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.p50(), c.p50());
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn bucket_bounds_consistent() {
        for v in [1u64, 2, 63, 64, 65, 127, 128, 1000, 1 << 20, (1 << 40) + 12345] {
            let b = bucket_of(v);
            let lo = bucket_lower(b);
            assert!(lo <= v, "v={v} b={b} lo={lo}");
            assert!(bucket_of(lo) == b || lo == 0, "v={v}");
        }
    }

    #[test]
    fn clear_and_take_window_reset_state() {
        let mut h = Histogram::new();
        for v in [10u64, 100, 1000] {
            h.record(v);
        }
        let w = h.take_window();
        assert_eq!(w.count(), 3);
        assert_eq!(w.max(), 1000);
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        h.record(7);
        assert_eq!((h.count(), h.min(), h.max()), (1, 7, 7));
        h.clear();
        assert_eq!(h.count(), 0);
        assert!(h.mean().is_nan());
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn empty_histogram_is_sane() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.mean().is_nan());
        assert_eq!(h.quantile(0.5), 0);
    }
}
