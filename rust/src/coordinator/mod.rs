//! Coordinator runtime: drives the same [`Node`] state machines that run
//! under the simulator on *real threads* over a [`Transport`]
//! (in-process or TCP). One `NodeRuntime` per process; the leader's
//! commit path can offload batched global-timestamp resolution to the
//! XLA engine service ([`crate::runtime::service`]).
//!
//! Event loop: poll the transport with a timeout bounded by the next
//! armed timer; on wake-up drain *all* ready transport messages (not one
//! per poll — a backlog must not pay a timeout-poll per message),
//! dispatching each into the node; apply the effects from the shared
//! [`Outbox`] (timers → local heap, deliveries → the registered
//! callback, self-sends → straight back through the node); finally flush
//! the accumulated outgoing sends once per drain cycle, coalesced into
//! one [`Wire::Batch`](crate::types::Wire::Batch) frame per destination.

use crate::net::{Incoming, Transport};
use crate::protocols::{Coalescer, Node, Outbox, TimerKind};
use crate::types::{MsgId, Pid, Ts, Wire};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Delivery callback: `(pid, message, gts, elapsed_ns)`.
pub type DeliverFn = Box<dyn FnMut(Pid, MsgId, Ts, u64) + Send>;

/// Upper bound on wires dispatched per drain cycle, so a firehose peer
/// cannot starve the timer wheel forever.
const MAX_DRAIN: usize = 4096;

/// Runs one protocol node over a transport until stopped.
pub struct NodeRuntime<T: Transport> {
    node: Box<dyn Node>,
    transport: T,
    timers: BinaryHeap<Reverse<(u64, u64, TimerKind)>>,
    timer_seq: u64,
    epoch: Instant,
    on_deliver: Option<DeliverFn>,
    /// shared effects sink (reused across events)
    outbox: Outbox,
    /// swap buffer for outbox sends while self-sends recurse into the node
    scratch: Vec<(Pid, Wire)>,
    /// outgoing sends accumulated across one drain cycle, flushed as
    /// coalesced frames
    outgoing: Vec<(Pid, Wire)>,
    coalescer: Coalescer,
    /// statistics
    pub wires_in: u64,
    pub wires_out: u64,
    pub delivered: u64,
}

impl<T: Transport> NodeRuntime<T> {
    pub fn new(node: Box<dyn Node>, transport: T) -> Self {
        NodeRuntime {
            node,
            transport,
            timers: BinaryHeap::new(),
            timer_seq: 0,
            epoch: Instant::now(),
            on_deliver: None,
            outbox: Outbox::new(),
            scratch: Vec::new(),
            outgoing: Vec::new(),
            coalescer: Coalescer::new(),
            wires_in: 0,
            wires_out: 0,
            delivered: 0,
        }
    }

    pub fn on_deliver(&mut self, f: DeliverFn) {
        self.on_deliver = Some(f);
    }

    fn now(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Feed one transport wire into the node, unpacking batch frames (the
    /// node only ever sees inner messages), then settle the outbox.
    fn dispatch_wire(&mut self, from: Pid, wire: Wire) {
        let now = self.now();
        match wire {
            Wire::Batch(inner) => {
                for w in inner {
                    self.wires_in += 1;
                    self.node.on_wire(from, w, now, &mut self.outbox);
                }
            }
            w => {
                self.wires_in += 1;
                self.node.on_wire(from, w, now, &mut self.outbox);
            }
        }
        self.drain_effects();
    }

    /// Settle the outbox: deliveries and timers directly; self-sends loop
    /// back through the node (repeating until the outbox is quiet);
    /// remote sends accumulate in `outgoing` for the next flush.
    fn drain_effects(&mut self) {
        loop {
            let now = self.now();
            for i in 0..self.outbox.delivers.len() {
                let (m, gts) = self.outbox.delivers[i];
                self.delivered += 1;
                if let Some(f) = &mut self.on_deliver {
                    f(self.node.pid(), m, gts, now);
                }
            }
            self.outbox.delivers.clear();
            for i in 0..self.outbox.timers.len() {
                let (kind, after) = self.outbox.timers[i];
                self.timer_seq += 1;
                self.timers.push(Reverse((now + after, self.timer_seq, kind)));
            }
            self.outbox.timers.clear();
            if self.outbox.sends.is_empty() {
                break;
            }
            std::mem::swap(&mut self.outbox.sends, &mut self.scratch);
            let me = self.node.pid();
            for (to, wire) in self.scratch.drain(..) {
                self.wires_out += 1;
                if to == me {
                    // self-send: loop straight back through the node
                    self.node.on_wire(to, wire, now, &mut self.outbox);
                } else {
                    self.outgoing.push((to, wire));
                }
            }
        }
    }

    /// Flush the cycle's outgoing sends: one coalesced frame per
    /// destination, one transport send (→ one encode + one write) each.
    fn flush_outgoing(&mut self) {
        let NodeRuntime { coalescer, outgoing, transport, .. } = self;
        coalescer.drain(outgoing, true, |to, frame| transport.send(to, frame));
    }

    /// Run until `stop` is raised. Returns the node back for inspection.
    pub fn run(mut self, stop: Arc<AtomicBool>) -> Box<dyn Node> {
        let now0 = self.now();
        self.node.on_start(now0, &mut self.outbox);
        self.drain_effects();
        self.flush_outgoing();
        while !stop.load(Ordering::Relaxed) {
            // fire due timers
            let now = self.now();
            let mut fired = false;
            while let Some(Reverse((t, _, _))) = self.timers.peek() {
                if *t > now {
                    break;
                }
                let Reverse((_, _, kind)) = self.timers.pop().unwrap();
                self.node.on_timer(kind, now, &mut self.outbox);
                self.drain_effects();
                fired = true;
            }
            if fired {
                self.flush_outgoing();
            }
            // poll bounded by the next timer (or a coarse idle tick)
            let next = self.timers.peek().map(|Reverse((t, _, _))| *t);
            let wait = match next {
                Some(t) => Duration::from_nanos(t.saturating_sub(self.now()).min(50_000_000)),
                None => Duration::from_millis(50),
            };
            match self.transport.recv_timeout(wait) {
                Some(Incoming::Wire(from, wire)) => {
                    self.dispatch_wire(from, wire);
                    // drain the backlog until the channel is empty before
                    // recomputing timers; flush the frames once per cycle
                    let mut closed = false;
                    let mut drained = 1;
                    while drained < MAX_DRAIN {
                        match self.transport.recv_timeout(Duration::ZERO) {
                            Some(Incoming::Wire(f, w)) => {
                                self.dispatch_wire(f, w);
                                drained += 1;
                            }
                            Some(Incoming::Closed) => {
                                closed = true;
                                break;
                            }
                            None => break,
                        }
                    }
                    self.flush_outgoing();
                    if closed {
                        break;
                    }
                }
                Some(Incoming::Closed) => break,
                None => {}
            }
        }
        self.node
    }
}

/// Convenience: spawn a runtime on its own thread; returns a join handle
/// yielding the node when stopped.
pub fn spawn<T: Transport + 'static>(
    node: Box<dyn Node>,
    transport: T,
    stop: Arc<AtomicBool>,
    on_deliver: Option<DeliverFn>,
) -> std::thread::JoinHandle<Box<dyn Node>> {
    let name = format!("wbam-node-{}", node.pid().0);
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let mut rt = NodeRuntime::new(node, transport);
            if let Some(f) = on_deliver {
                rt.on_deliver(f);
            }
            rt.run(stop)
        })
        .expect("spawn node thread")
}

/// A whole in-process cluster: group members + clients on threads.
pub struct Cluster {
    pub stop: Arc<AtomicBool>,
    pub handles: Vec<std::thread::JoinHandle<Box<dyn Node>>>,
}

impl Cluster {
    /// Launch `nodes` over a fresh in-proc mesh. `on_deliver` is invoked
    /// for every local delivery on any node.
    pub fn launch(nodes: Vec<Box<dyn Node>>, on_deliver: Option<Arc<std::sync::Mutex<DeliverFn>>>) -> Cluster {
        let mesh = crate::net::InProcMesh::new();
        let stop = Arc::new(AtomicBool::new(false));
        // register all endpoints before starting any node so early sends
        // have somewhere to go
        let endpoints: Vec<_> = nodes.iter().map(|n| mesh.endpoint(n.pid())).collect();
        let mut handles = Vec::new();
        for (node, ep) in nodes.into_iter().zip(endpoints) {
            let cb: Option<DeliverFn> = on_deliver.as_ref().map(|f| {
                let f = Arc::clone(f);
                Box::new(move |pid: Pid, m: MsgId, gts: Ts, t: u64| {
                    (f.lock().unwrap())(pid, m, gts, t);
                }) as DeliverFn
            });
            handles.push(spawn(node, ep, Arc::clone(&stop), cb));
        }
        Cluster { stop, handles }
    }

    /// Stop all node threads and collect the nodes.
    pub fn shutdown(self) -> Vec<Box<dyn Node>> {
        self.stop.store(true, Ordering::Relaxed);
        self.handles.into_iter().map(|h| h.join().expect("node thread panicked")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, ClientCfg};
    use crate::protocols::wbcast::{WbConfig, WbNode};
    use crate::types::Topology;
    use std::sync::Mutex;

    #[test]
    fn inproc_cluster_runs_wbcast_end_to_end() {
        let topo = Topology::new(2, 1);
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        let wb = WbConfig { hb_interval: 20_000_000, ..WbConfig::default() };
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(WbNode::new(p, topo.clone(), wb)));
            }
        }
        for c in 0..4u32 {
            let pid = Pid(topo.first_client_pid().0 + c);
            let cfg = ClientCfg {
                dest_groups: 2,
                max_requests: Some(25),
                resend_after: 200_000_000,
                ..Default::default()
            };
            nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, 77 + c as u64)));
        }
        let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));
        let dv = Arc::clone(&deliveries);
        let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid, m, gts, _t| {
            dv.lock().unwrap().push((pid, m, gts));
        })));
        let cluster = Cluster::launch(nodes, Some(cb));

        // wait until all 100 requests completed at every member (6 nodes
        // x 100 deliveries), with a deadline
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let n = deliveries.lock().unwrap().len();
            if n >= 600 {
                break;
            }
            assert!(Instant::now() < deadline, "timeout: {n}/600 deliveries");
            std::thread::sleep(Duration::from_millis(20));
        }
        let nodes = cluster.shutdown();

        // per-pid gts must be strictly increasing (Ordering)
        let dels = deliveries.lock().unwrap();
        let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
        for &(pid, _m, gts) in dels.iter() {
            per_pid.entry(pid).or_default().push(gts);
        }
        for (pid, seq) in &per_pid {
            for w in seq.windows(2) {
                assert!(w[0] < w[1], "{pid:?} delivered out of order");
            }
        }
        // clients completed their quotas
        for n in nodes {
            let any: &dyn Node = &*n;
            if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
                assert_eq!(c.completed.len(), 25);
            }
        }
    }
}
