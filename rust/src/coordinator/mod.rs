//! Coordinator runtime: drives the same [`Node`] state machines that run
//! under the simulator on *real threads* over a [`Transport`]
//! (in-process or TCP). One `NodeRuntime` per process; the leader's
//! commit path can offload batched global-timestamp resolution to the
//! XLA engine service ([`crate::runtime::service`]).
//!
//! Event loop: poll the transport with a timeout bounded by the next
//! armed timer; dispatch wires/timers into the node; apply the resulting
//! actions (sends → transport, timers → local heap, deliveries → the
//! registered callback).

use crate::net::{Incoming, Transport};
use crate::protocols::{Action, Node, TimerKind};
use crate::types::{MsgId, Pid, Ts};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Delivery callback: `(pid, message, gts, elapsed_ns)`.
pub type DeliverFn = Box<dyn FnMut(Pid, MsgId, Ts, u64) + Send>;

/// Runs one protocol node over a transport until stopped.
pub struct NodeRuntime<T: Transport> {
    node: Box<dyn Node>,
    transport: T,
    timers: BinaryHeap<Reverse<(u64, u64, TimerKind)>>,
    timer_seq: u64,
    epoch: Instant,
    on_deliver: Option<DeliverFn>,
    /// statistics
    pub wires_in: u64,
    pub wires_out: u64,
    pub delivered: u64,
}

impl<T: Transport> NodeRuntime<T> {
    pub fn new(node: Box<dyn Node>, transport: T) -> Self {
        NodeRuntime {
            node,
            transport,
            timers: BinaryHeap::new(),
            timer_seq: 0,
            epoch: Instant::now(),
            on_deliver: None,
            wires_in: 0,
            wires_out: 0,
            delivered: 0,
        }
    }

    pub fn on_deliver(&mut self, f: DeliverFn) {
        self.on_deliver = Some(f);
    }

    fn now(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn apply(&mut self, acts: Vec<Action>) {
        let now = self.now();
        for a in acts {
            match a {
                Action::Send(to, wire) => {
                    self.wires_out += 1;
                    if to == self.node.pid() {
                        // self-send: loop straight back through the node
                        let acts = self.node.on_wire(to, wire, now);
                        self.apply(acts);
                    } else {
                        self.transport.send(to, &wire);
                    }
                }
                Action::Deliver(m, gts) => {
                    self.delivered += 1;
                    if let Some(f) = &mut self.on_deliver {
                        f(self.node.pid(), m, gts, now);
                    }
                }
                Action::Timer(kind, after) => {
                    self.timer_seq += 1;
                    self.timers.push(Reverse((now + after, self.timer_seq, kind)));
                }
            }
        }
    }

    /// Run until `stop` is raised. Returns the node back for inspection.
    pub fn run(mut self, stop: Arc<AtomicBool>) -> Box<dyn Node> {
        let acts = self.node.on_start(self.now());
        self.apply(acts);
        while !stop.load(Ordering::Relaxed) {
            // fire due timers
            let now = self.now();
            while let Some(Reverse((t, _, _))) = self.timers.peek() {
                if *t > now {
                    break;
                }
                let Reverse((_, _, kind)) = self.timers.pop().unwrap();
                let acts = self.node.on_timer(kind, now);
                self.apply(acts);
            }
            // poll bounded by the next timer (or a coarse idle tick)
            let next = self.timers.peek().map(|Reverse((t, _, _))| *t);
            let wait = match next {
                Some(t) => Duration::from_nanos(t.saturating_sub(self.now()).min(50_000_000)),
                None => Duration::from_millis(50),
            };
            match self.transport.recv_timeout(wait) {
                Some(Incoming::Wire(from, wire)) => {
                    self.wires_in += 1;
                    let now = self.now();
                    let acts = self.node.on_wire(from, wire, now);
                    self.apply(acts);
                }
                Some(Incoming::Closed) => break,
                None => {}
            }
        }
        self.node
    }
}

/// Convenience: spawn a runtime on its own thread; returns a join handle
/// yielding the node when stopped.
pub fn spawn<T: Transport + 'static>(
    node: Box<dyn Node>,
    transport: T,
    stop: Arc<AtomicBool>,
    on_deliver: Option<DeliverFn>,
) -> std::thread::JoinHandle<Box<dyn Node>> {
    let name = format!("wbam-node-{}", node.pid().0);
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let mut rt = NodeRuntime::new(node, transport);
            if let Some(f) = on_deliver {
                rt.on_deliver(f);
            }
            rt.run(stop)
        })
        .expect("spawn node thread")
}

/// A whole in-process cluster: group members + clients on threads.
pub struct Cluster {
    pub stop: Arc<AtomicBool>,
    pub handles: Vec<std::thread::JoinHandle<Box<dyn Node>>>,
}

impl Cluster {
    /// Launch `nodes` over a fresh in-proc mesh. `on_deliver` is invoked
    /// for every local delivery on any node.
    pub fn launch(nodes: Vec<Box<dyn Node>>, on_deliver: Option<Arc<std::sync::Mutex<DeliverFn>>>) -> Cluster {
        let mesh = crate::net::InProcMesh::new();
        let stop = Arc::new(AtomicBool::new(false));
        // register all endpoints before starting any node so early sends
        // have somewhere to go
        let endpoints: Vec<_> = nodes.iter().map(|n| mesh.endpoint(n.pid())).collect();
        let mut handles = Vec::new();
        for (node, ep) in nodes.into_iter().zip(endpoints) {
            let cb: Option<DeliverFn> = on_deliver.as_ref().map(|f| {
                let f = Arc::clone(f);
                Box::new(move |pid: Pid, m: MsgId, gts: Ts, t: u64| {
                    (f.lock().unwrap())(pid, m, gts, t);
                }) as DeliverFn
            });
            handles.push(spawn(node, ep, Arc::clone(&stop), cb));
        }
        Cluster { stop, handles }
    }

    /// Stop all node threads and collect the nodes.
    pub fn shutdown(self) -> Vec<Box<dyn Node>> {
        self.stop.store(true, Ordering::Relaxed);
        self.handles.into_iter().map(|h| h.join().expect("node thread panicked")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, ClientCfg};
    use crate::protocols::wbcast::{WbConfig, WbNode};
    use crate::types::Topology;
    use std::sync::Mutex;

    #[test]
    fn inproc_cluster_runs_wbcast_end_to_end() {
        let topo = Topology::new(2, 1);
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        let wb = WbConfig { hb_interval: 20_000_000, ..WbConfig::default() };
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(WbNode::new(p, topo.clone(), wb)));
            }
        }
        for c in 0..4u32 {
            let pid = Pid(topo.first_client_pid().0 + c);
            let cfg = ClientCfg {
                dest_groups: 2,
                max_requests: Some(25),
                resend_after: 200_000_000,
                ..Default::default()
            };
            nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, 77 + c as u64)));
        }
        let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));
        let dv = Arc::clone(&deliveries);
        let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid, m, gts, _t| {
            dv.lock().unwrap().push((pid, m, gts));
        })));
        let cluster = Cluster::launch(nodes, Some(cb));

        // wait until all 100 requests completed at every member (6 nodes
        // x 100 deliveries), with a deadline
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let n = deliveries.lock().unwrap().len();
            if n >= 600 {
                break;
            }
            assert!(Instant::now() < deadline, "timeout: {n}/600 deliveries");
            std::thread::sleep(Duration::from_millis(20));
        }
        let nodes = cluster.shutdown();

        // per-pid gts must be strictly increasing (Ordering)
        let dels = deliveries.lock().unwrap();
        let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
        for &(pid, _m, gts) in dels.iter() {
            per_pid.entry(pid).or_default().push(gts);
        }
        for (pid, seq) in &per_pid {
            for w in seq.windows(2) {
                assert!(w[0] < w[1], "{pid:?} delivered out of order");
            }
        }
        // clients completed their quotas
        for n in nodes {
            let any: &dyn Node = &*n;
            if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
                assert_eq!(c.completed.len(), 25);
            }
        }
    }
}
