//! Coordinator runtime: drives the same [`Node`] state machines that run
//! under the simulator on *real threads* over a [`Transport`]
//! (in-process mesh, threaded TCP, or the Linux epoll event loop —
//! both runtime shapes below are transport-generic, so the ablation in
//! the `hotpath` bench swaps transports without touching protocol or
//! runtime code).
//!
//! One [`ShardedRuntime`] per transport endpoint. An endpoint hosting
//! **exactly one node** — every client, the CLI `serve` of an unsharded
//! member, the [`NodeRuntime`] convenience wrapper — runs the **inline
//! fast path**: dispatch, timer wheel and flush all execute on the
//! receive thread, with no worker/flusher threads and no channel hops
//! between receiving a frame and writing its responses. An endpoint
//! hosting `S > 1` shard nodes (laid out by
//! [`ShardMap`](crate::types::ShardMap)) uses the threaded pipeline:
//!
//! * one **shard worker thread** per hosted node, owning the node, its
//!   timer wheel and its reusable [`Outbox`]. Self-sends loop straight
//!   back through the node; sends to *other locally hosted pids* are
//!   routed in-process over the sibling shard's channel, never touching
//!   the transport; remote sends accumulate per event-loop cycle and are
//!   handed to the flusher as one batch.
//! * one **flusher thread** owning the transport's send half and the
//!   shared [`LinkCoalescer`]: it folds all shards' pending sends into
//!   [`Wire::Batch`](crate::types::Wire::Batch) frames per link (one
//!   encode + one write each), preserving per-link FIFO order.
//! * the **caller's thread** runs the receive loop: poll the transport,
//!   route each addressed frame to its shard worker.
//!
//! Both paths (and the simulator) flush through the same
//! [`LinkCoalescer`] under a configurable
//! [`FlushPolicy`](crate::types::FlushPolicy) — by default one coalesced
//! frame per link per cycle, optionally an adaptive delay/byte window —
//! so simulated batching behaviour stays predictive of the real
//! transports.

use crate::net::{Incoming, Transport, TransportTx};
use crate::obs::{CoreMetrics, FlightEvent};
use crate::protocols::{LinkCoalescer, Node, Outbox, TimerKind};
use crate::storage::Storage;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use crate::sync::{thread, Arc, Mutex};
use crate::types::{FlushPolicy, MsgId, Pid, Ts, Wire};
use crate::util::FxHashMap;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

/// Delivery callback: `(pid, message, gts, elapsed_ns)`.
pub type DeliverFn = Box<dyn FnMut(Pid, MsgId, Ts, u64) + Send>;

/// A directed transport link (source shard pid, destination pid).
type Link = (Pid, Pid);

/// Upper bound on *inner* wires dispatched per drain cycle (batch frames
/// count their contents, not 1), so a firehose peer cannot starve a
/// shard's timer wheel forever.
const MAX_DRAIN: usize = 4096;

/// Idle poll tick: the upper bound on how long any loop sleeps before
/// rechecking its stop flag.
const IDLE_TICK: Duration = Duration::from_millis(50);

/// Runtime counters, shared across the runtime's threads (read them via
/// the handle returned by [`ShardedRuntime::stats`]).
#[derive(Default)]
pub struct CoordStats {
    /// protocol wires fed into local nodes (batch frames count their
    /// inner messages)
    pub wires_in: AtomicU64,
    /// wires handed to the transport flush (excludes in-process routing)
    pub wires_out: AtomicU64,
    /// wires routed in-process: self-sends and cross-shard sends between
    /// locally hosted pids — these never reach the transport
    pub self_wires: AtomicU64,
    /// local deliveries
    pub delivered: AtomicU64,
    /// incoming frames addressed to a pid this endpoint does not host —
    /// warned and dropped; zero on a healthy deployment
    pub dropped_frames: AtomicU64,
}

/// Append the records a node handler just journaled (buffered; the
/// group commit happens once per cycle via [`commit_records`], before
/// the cycle's frames reach the transport). A failed append poisons the
/// storage itself (logged there): the node carries on in-memory,
/// degrading to the crash-stop guarantees the protocol already
/// tolerates — and the poisoned directory refuses any future restore.
fn append_records(storage: &mut Option<Storage>, outbox: &mut Outbox) {
    if outbox.records.is_empty() {
        return;
    }
    if let Some(store) = storage.as_mut() {
        for rec in &outbox.records {
            if store.append(rec).is_err() {
                break; // poisoned; later records are discarded anyway
            }
        }
    }
    outbox.records.clear();
}

/// The group-commit point: flush + fsync per the [`SyncPolicy`]. Run
/// (a) before deliver callbacks fire (deliveries are app-visible
/// output) and (b) at each cycle's flush, before frames reach the
/// transport — so one fsync under `SyncPolicy::Always` covers every
/// record the cycle produced.
fn commit_records(storage: &mut Option<Storage>) {
    if let Some(store) = storage.as_mut() {
        // commit errors poison the storage and are logged there
        let _ = store.commit();
    }
}

/// One shard's event loop state (runs on its own worker thread).
struct ShardWorker {
    node: Box<dyn Node>,
    /// per-shard durable WAL (None: durability off for this node)
    storage: Option<Storage>,
    rx: Receiver<(Pid, Pid, Wire)>,
    /// channels of every locally hosted shard (cross-shard in-process
    /// routing); includes our own pid, which is short-circuited inline.
    /// Each worker owns its clone of the (small) map, so no cross-thread
    /// sharing of the senders is needed.
    peers: FxHashMap<Pid, Sender<(Pid, Pid, Wire)>>,
    /// batched hand-off to the flusher thread
    out_tx: Sender<Vec<(Link, Wire)>>,
    outbox: Outbox,
    scratch: Vec<(Pid, Wire)>,
    outgoing: Vec<(Link, Wire)>,
    timers: BinaryHeap<Reverse<(u64, u64, TimerKind)>>,
    timer_seq: u64,
    epoch: Instant,
    on_deliver: Option<Arc<Mutex<DeliverFn>>>,
    stats: Arc<CoordStats>,
    obs: Option<Arc<CoreMetrics>>,
    stop: Arc<AtomicBool>,
    halt: Arc<AtomicBool>,
}

impl ShardWorker {
    fn now(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn stopping(&self) -> bool {
        self.stop.load(Ordering::Relaxed) || self.halt.load(Ordering::Relaxed)
    }

    /// Feed one transport wire into the node, unpacking batch frames (the
    /// node only ever sees inner messages), then settle the outbox.
    /// Returns the number of inner wires dispatched.
    fn dispatch_wire(&mut self, from: Pid, wire: Wire) -> usize {
        let now = self.now();
        let me = self.node.pid();
        let n = match wire {
            Wire::Batch(inner) => {
                let n = inner.len();
                for w in inner {
                    if let Some(cm) = &self.obs {
                        cm.flight.push(FlightEvent::wire_in(now, me, from, &w));
                    }
                    self.node.on_wire(from, w, now, &mut self.outbox);
                }
                n
            }
            w => {
                if let Some(cm) = &self.obs {
                    cm.flight.push(FlightEvent::wire_in(now, me, from, &w));
                }
                self.node.on_wire(from, w, now, &mut self.outbox);
                1
            }
        };
        self.stats.wires_in.fetch_add(n as u64, Ordering::Relaxed);
        self.drain_effects();
        n
    }

    /// Settle the outbox: deliveries and timers directly; self-sends loop
    /// back through the node (repeating until the outbox is quiet);
    /// cross-shard local sends go over the sibling's channel; remote
    /// sends accumulate in `outgoing` for the next flush hand-off.
    fn drain_effects(&mut self) {
        let me = self.node.pid();
        loop {
            let now = self.now();
            // journal records first: appended ahead of this iteration's
            // other effects, committed before anything app-visible
            if !self.outbox.records.is_empty() {
                if let Some(cm) = &self.obs {
                    cm.flight.push(FlightEvent::journal(now, me));
                }
            }
            append_records(&mut self.storage, &mut self.outbox);
            if !self.outbox.delivers.is_empty() {
                // output commit: the delivery callback is app-visible
                commit_records(&mut self.storage);
                if let Some(cm) = &self.obs {
                    for d in &self.outbox.delivers {
                        cm.on_deliver(d);
                        cm.flight.push(FlightEvent::deliver(now, me, d.m, d.gts, d.path));
                    }
                }
                if let Some(cb) = &self.on_deliver {
                    let mut f = cb.lock().unwrap();
                    for i in 0..self.outbox.delivers.len() {
                        let d = self.outbox.delivers[i];
                        f(me, d.m, d.gts, now);
                    }
                }
                self.stats.delivered.fetch_add(self.outbox.delivers.len() as u64, Ordering::Relaxed);
                self.outbox.delivers.clear();
            }
            for i in 0..self.outbox.timers.len() {
                let (kind, after) = self.outbox.timers[i];
                self.timer_seq += 1;
                self.timers.push(Reverse((now + after, self.timer_seq, kind)));
            }
            self.outbox.timers.clear();
            if self.outbox.sends.is_empty() {
                break;
            }
            std::mem::swap(&mut self.outbox.sends, &mut self.scratch);
            for (to, wire) in self.scratch.drain(..) {
                if to == me {
                    // self-send: straight back through the node
                    self.stats.self_wires.fetch_add(1, Ordering::Relaxed);
                    self.node.on_wire(me, wire, now, &mut self.outbox);
                } else if let Some(tx) = self.peers.get(&to) {
                    // cross-shard, same endpoint: in-process routing
                    self.stats.self_wires.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send((me, to, wire));
                } else {
                    self.stats.wires_out.fetch_add(1, Ordering::Relaxed);
                    if let Some(cm) = &self.obs {
                        cm.flight.push(FlightEvent::wire_out(now, me, to, &wire));
                    }
                    self.outgoing.push(((me, to), wire));
                }
            }
        }
    }

    /// Hand the cycle's remote sends to the flusher (one channel message
    /// per cycle; the flusher coalesces per link), after group-committing
    /// the records that back them.
    fn flush(&mut self) {
        commit_records(&mut self.storage);
        if !self.outgoing.is_empty() {
            let batch = std::mem::take(&mut self.outgoing);
            let _ = self.out_tx.send(batch);
        }
    }

    fn run(mut self) -> Box<dyn Node> {
        let now0 = self.now();
        self.node.on_start(now0, &mut self.outbox);
        self.drain_effects();
        self.flush();
        while !self.stopping() {
            // fire due timers
            let mut fired = false;
            loop {
                let now = self.now();
                match self.timers.peek() {
                    Some(&Reverse((t, _, _))) if t <= now => {}
                    _ => break,
                }
                let Reverse((_, _, kind)) = self.timers.pop().expect("peeked timer");
                self.node.on_timer(kind, now, &mut self.outbox);
                self.drain_effects();
                fired = true;
            }
            if fired {
                self.flush();
            }
            // wait for traffic, bounded by the next timer and the stop tick
            let next = self.timers.peek().map(|&Reverse((t, _, _))| t);
            let wait = match next {
                Some(t) => Duration::from_nanos(t.saturating_sub(self.now())).min(IDLE_TICK),
                None => IDLE_TICK,
            };
            match self.rx.recv_timeout(wait) {
                Ok((from, _to, wire)) => {
                    // drain the backlog before recomputing timers, bounded
                    // by dispatched inner wires; flush once per cycle
                    let mut drained = self.dispatch_wire(from, wire);
                    while drained < MAX_DRAIN {
                        match self.rx.try_recv() {
                            Ok((f, _t, w)) => drained += self.dispatch_wire(f, w),
                            Err(_) => break,
                        }
                    }
                    self.flush();
                }
                Err(RecvTimeoutError::Timeout) => {
                    // idle tick: let an IntervalUs policy fsync the tail
                    // of a burst once traffic stops
                    commit_records(&mut self.storage);
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // shutdown drain: anything the last cycle queued still goes to
        // the flusher (which drains its channel to empty before exiting)
        self.flush();
        self.node
    }
}

/// Flusher loop: collect the shard workers' outgoing batches and fold
/// them into coalesced per-link frames under `policy` — one transport
/// send (→ one encode + one write) per frame.
///
/// Exit is driven solely by channel disconnection (every worker dropping
/// its sender): `recv_timeout` yields every queued batch before it
/// reports `Disconnected`, and the final `flush_all` ships whatever the
/// coalescer still holds — a shutdown can no longer strand sends that
/// workers already queued (they are all counted in
/// [`CoordStats::wires_out`]).
fn run_flusher(mut tx: Box<dyn TransportTx>, rx: Receiver<Vec<(Link, Wire)>>, policy: FlushPolicy) {
    let mut links: LinkCoalescer<Link> = LinkCoalescer::new(policy);
    let epoch = Instant::now();
    loop {
        let now = epoch.elapsed().as_nanos() as u64;
        let wait = match links.next_deadline() {
            Some(d) => Duration::from_nanos(d.saturating_sub(now)).min(IDLE_TICK),
            None => IDLE_TICK,
        };
        match rx.recv_timeout(wait) {
            Ok(batch) => {
                let mut emit = |(from, to): Link, frame: Wire| tx.send(from, to, frame);
                let now = epoch.elapsed().as_nanos() as u64;
                for (link, wire) in batch {
                    links.push(now, link, wire, &mut emit);
                }
                // opportunistic cycle: everything already queued flushes
                // together (more cross-shard coalescing under load)
                while let Ok(more) = rx.try_recv() {
                    for (link, wire) in more {
                        links.push(now, link, wire, &mut emit);
                    }
                }
                links.flush_cycle(now, true, &mut emit);
            }
            Err(RecvTimeoutError::Timeout) => {
                let mut emit = |(from, to): Link, frame: Wire| tx.send(from, to, frame);
                links.flush_cycle(epoch.elapsed().as_nanos() as u64, true, &mut emit);
            }
            Err(RecvTimeoutError::Disconnected) => {
                let mut emit = |(from, to): Link, frame: Wire| tx.send(from, to, frame);
                links.flush_all(&mut emit);
                break;
            }
        }
    }
}

/// The inline single-shard event loop: dispatch, timer wheel and flush
/// all on the receive thread. No worker or flusher threads, no channel
/// hops — an incoming frame's responses hit the transport before the
/// loop polls again.
struct InlineLoop<T: Transport> {
    me: Pid,
    node: Box<dyn Node>,
    /// durable WAL of the hosted node (None: durability off)
    storage: Option<Storage>,
    transport: T,
    outbox: Outbox,
    scratch: Vec<(Pid, Wire)>,
    timers: BinaryHeap<Reverse<(u64, u64, TimerKind)>>,
    timer_seq: u64,
    links: LinkCoalescer<Pid>,
    epoch: Instant,
    on_deliver: Option<Arc<Mutex<DeliverFn>>>,
    stats: Arc<CoordStats>,
    /// live-observability sink (None: metrics off — the hot path pays
    /// one branch)
    obs: Option<Arc<CoreMetrics>>,
    stop: Arc<AtomicBool>,
}

impl<T: Transport> InlineLoop<T> {
    fn now(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Feed one addressed frame in. Frames for a pid we do not host are
    /// counted and dropped (a 1-node endpoint hosts exactly `me`).
    /// Returns the number of inner wires dispatched (misaddressed frames
    /// count 1 toward the drain bound).
    fn route(&mut self, from: Pid, to: Pid, wire: Wire) -> usize {
        if to != self.me {
            self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
            log::warn!("frame {from:?}->{to:?} at an endpoint hosting only {:?}", self.me);
            return 1;
        }
        let now = self.now();
        let me = self.me;
        let n = match wire {
            Wire::Batch(inner) => {
                let n = inner.len();
                for w in inner {
                    if let Some(cm) = &self.obs {
                        cm.flight.push(FlightEvent::wire_in(now, me, from, &w));
                    }
                    self.node.on_wire(from, w, now, &mut self.outbox);
                }
                n
            }
            w => {
                if let Some(cm) = &self.obs {
                    cm.flight.push(FlightEvent::wire_in(now, me, from, &w));
                }
                self.node.on_wire(from, w, now, &mut self.outbox);
                1
            }
        };
        self.stats.wires_in.fetch_add(n as u64, Ordering::Relaxed);
        self.drain_effects();
        n
    }

    /// Settle the outbox: deliveries and timers directly; self-sends loop
    /// back through the node; remote sends go straight into the link
    /// coalescer (overflowing links hit the transport immediately).
    fn drain_effects(&mut self) {
        let me = self.me;
        loop {
            let now = self.now();
            // journal records first: appended ahead of this iteration's
            // other effects, committed before anything app-visible (the
            // cycle's transport frames commit at `flush`; the one
            // pre-commit escape is a >8 MiB link overflowing out of the
            // coalescer mid-drain, which no protocol cycle approaches)
            if !self.outbox.records.is_empty() {
                if let Some(cm) = &self.obs {
                    cm.flight.push(FlightEvent::journal(now, me));
                }
            }
            append_records(&mut self.storage, &mut self.outbox);
            if !self.outbox.delivers.is_empty() {
                // output commit: the delivery callback is app-visible
                commit_records(&mut self.storage);
                if let Some(cm) = &self.obs {
                    for d in &self.outbox.delivers {
                        cm.on_deliver(d);
                        cm.flight.push(FlightEvent::deliver(now, me, d.m, d.gts, d.path));
                    }
                }
                if let Some(cb) = &self.on_deliver {
                    let mut f = cb.lock().unwrap();
                    for i in 0..self.outbox.delivers.len() {
                        let d = self.outbox.delivers[i];
                        f(me, d.m, d.gts, now);
                    }
                }
                self.stats.delivered.fetch_add(self.outbox.delivers.len() as u64, Ordering::Relaxed);
                self.outbox.delivers.clear();
            }
            for i in 0..self.outbox.timers.len() {
                let (kind, after) = self.outbox.timers[i];
                self.timer_seq += 1;
                self.timers.push(Reverse((now + after, self.timer_seq, kind)));
            }
            self.outbox.timers.clear();
            if self.outbox.sends.is_empty() {
                break;
            }
            std::mem::swap(&mut self.outbox.sends, &mut self.scratch);
            let links = &mut self.links;
            let transport = &mut self.transport;
            let obs = &self.obs;
            for (to, wire) in self.scratch.drain(..) {
                if to == me {
                    self.stats.self_wires.fetch_add(1, Ordering::Relaxed);
                    self.node.on_wire(me, wire, now, &mut self.outbox);
                } else {
                    self.stats.wires_out.fetch_add(1, Ordering::Relaxed);
                    if let Some(cm) = obs {
                        cm.flight.push(FlightEvent::wire_out(now, me, to, &wire));
                    }
                    links.push(now, to, wire, &mut |to, frame| transport.send(me, to, frame));
                }
            }
        }
    }

    /// The cycle's flush point (same [`LinkCoalescer`] semantics as the
    /// sharded flusher thread and the simulator): group-commit the
    /// cycle's records, then emit its frames.
    fn flush(&mut self, quiet: bool) {
        commit_records(&mut self.storage);
        let now = self.now();
        let me = self.me;
        let links = &mut self.links;
        let transport = &mut self.transport;
        links.flush_cycle(now, quiet, &mut |to, frame| transport.send(me, to, frame));
    }

    fn run(mut self) -> Box<dyn Node> {
        let now0 = self.now();
        self.node.on_start(now0, &mut self.outbox);
        self.drain_effects();
        self.flush(true);
        let mut closed = false;
        while !closed && !self.stop.load(Ordering::Relaxed) {
            // fire due timers
            let mut fired = false;
            loop {
                let now = self.now();
                match self.timers.peek() {
                    Some(&Reverse((t, _, _))) if t <= now => {}
                    _ => break,
                }
                let Reverse((_, _, kind)) = self.timers.pop().expect("peeked timer");
                self.node.on_timer(kind, now, &mut self.outbox);
                self.drain_effects();
                fired = true;
            }
            if fired {
                self.flush(true);
            }
            // wait for traffic, bounded by the next timer, the flush
            // deadline of any held link, and the stop tick
            let now = self.now();
            let mut wait = IDLE_TICK;
            if let Some(&Reverse((t, _, _))) = self.timers.peek() {
                wait = wait.min(Duration::from_nanos(t.saturating_sub(now)));
            }
            if let Some(d) = self.links.next_deadline() {
                wait = wait.min(Duration::from_nanos(d.saturating_sub(now)));
            }
            match self.transport.recv_timeout(wait) {
                Some(Incoming::Wire(from, to, wire)) => {
                    // drain the backlog before recomputing timers, bounded
                    // by dispatched inner wires; one flush per cycle
                    let mut quiet = true;
                    let mut drained = self.route(from, to, wire);
                    while drained < MAX_DRAIN {
                        match self.transport.recv_timeout(Duration::ZERO) {
                            Some(Incoming::Wire(f, t, w)) => drained += self.route(f, t, w),
                            Some(Incoming::Closed) => {
                                closed = true;
                                break;
                            }
                            None => break,
                        }
                    }
                    if drained >= MAX_DRAIN {
                        quiet = false; // more input is likely pending
                    }
                    self.flush(quiet);
                }
                Some(Incoming::Closed) => break,
                // idle tick / flush deadline — `flush` also lets an
                // IntervalUs policy fsync the tail of a burst once
                // traffic stops
                None => self.flush(true),
            }
        }
        // shutdown drain: ship anything still coalescing (records first;
        // the storage fsyncs once more when it drops with the loop)
        commit_records(&mut self.storage);
        let me = self.me;
        let links = &mut self.links;
        let transport = &mut self.transport;
        links.flush_all(&mut |to, frame| transport.send(me, to, frame));
        self.node
    }
}

/// Runs `S` protocol nodes (shards) over one transport endpoint until
/// stopped; a 1-node endpoint takes the inline fast path. See the module
/// docs for the thread layout.
pub struct ShardedRuntime<T: Transport> {
    transport: T,
    nodes: Vec<Box<dyn Node>>,
    /// per-hosted-pid durable WALs ([`ShardedRuntime::attach_storage`])
    storage: FxHashMap<Pid, Storage>,
    on_deliver: Option<Arc<Mutex<DeliverFn>>>,
    stats: Arc<CoordStats>,
    /// live-observability sink shared by every hosted shard (None:
    /// metrics off)
    obs: Option<Arc<CoreMetrics>>,
    epoch: Instant,
    flush: FlushPolicy,
    force_threaded: bool,
}

impl<T: Transport> ShardedRuntime<T> {
    /// Host `nodes` (at least one) on `transport`. Nothing runs until
    /// [`ShardedRuntime::run`]; configure callbacks, storage and the
    /// flush policy in between.
    pub fn new(nodes: Vec<Box<dyn Node>>, transport: T) -> Self {
        assert!(!nodes.is_empty(), "an endpoint must host at least one node");
        ShardedRuntime {
            transport,
            nodes,
            storage: FxHashMap::default(),
            on_deliver: None,
            stats: Arc::new(CoordStats::default()),
            obs: None,
            epoch: Instant::now(),
            flush: FlushPolicy::default(),
            force_threaded: false,
        }
    }

    /// Attach a durable WAL for hosted pid `p` (one log per shard; see
    /// [`crate::storage`]). The owning event loop appends the node's
    /// journal records and group-commits them ahead of each cycle's
    /// sends; on shutdown the log is fsynced.
    pub fn attach_storage(&mut self, p: Pid, store: Storage) {
        self.storage.insert(p, store);
    }

    /// Install the delivery callback (invoked from shard worker threads,
    /// or from the receive thread on the inline path).
    pub fn on_deliver(&mut self, f: DeliverFn) {
        self.on_deliver = Some(Arc::new(Mutex::new(f)));
    }

    /// Install a callback already shared with other endpoints (e.g. the
    /// cluster-wide handle [`Cluster`] holds) — one lock layer, no
    /// re-wrapping.
    pub fn on_deliver_shared(&mut self, f: Arc<Mutex<DeliverFn>>) {
        self.on_deliver = Some(f);
    }

    /// Set the wire-coalescing [`FlushPolicy`] (default: one frame per
    /// link per cycle).
    pub fn flush_policy(&mut self, p: FlushPolicy) {
        self.flush = p;
    }

    /// Attach the live-observability sink: every delivered multicast
    /// records its path split / latency histograms into `cm`, and the
    /// event loops feed `cm.flight` (wire in/out, journal appends,
    /// deliveries). Off by default — with no sink attached the hot path
    /// pays one untaken branch per effect batch.
    pub fn attach_metrics(&mut self, cm: Arc<CoreMetrics>) {
        self.obs = Some(cm);
    }

    /// Run a 1-node endpoint through the threaded worker/flusher pipeline
    /// instead of the inline fast path. Only useful for comparing the two
    /// (the `hotpath` bench and the pinned latency test); never faster.
    pub fn force_threaded(&mut self) {
        self.force_threaded = true;
    }

    /// Shared counters handle (clone before `run` to observe afterwards).
    pub fn stats(&self) -> Arc<CoordStats> {
        Arc::clone(&self.stats)
    }

    /// Run until `stop` is raised (or the transport closes). Returns the
    /// nodes back for inspection, in their original order.
    pub fn run(mut self, stop: Arc<AtomicBool>) -> Vec<Box<dyn Node>> {
        if self.nodes.len() == 1 && !self.force_threaded {
            let node = self.nodes.pop().expect("one node");
            let me = node.pid();
            let inline = InlineLoop {
                me,
                node,
                storage: self.storage.remove(&me),
                transport: self.transport,
                outbox: Outbox::new(),
                scratch: Vec::new(),
                timers: BinaryHeap::new(),
                timer_seq: 0,
                links: LinkCoalescer::new(self.flush),
                epoch: self.epoch,
                on_deliver: self.on_deliver.take(),
                stats: Arc::clone(&self.stats),
                obs: self.obs.take(),
                stop,
            };
            return vec![inline.run()];
        }
        self.run_threaded(stop)
    }

    fn run_threaded(mut self, stop: Arc<AtomicBool>) -> Vec<Box<dyn Node>> {
        // endpoint-local halt: a transport close must stop this runtime's
        // helper threads without touching the caller's (possibly shared)
        // stop flag
        let halt = Arc::new(AtomicBool::new(false));
        let cb = self.on_deliver.take();

        let (out_tx, out_rx) = mpsc::channel::<Vec<(Link, Wire)>>();
        let flusher = {
            let tx = self.transport.sender();
            let policy = self.flush;
            thread::Builder::new()
                .name("wbam-flush".into())
                .spawn(move || run_flusher(tx, out_rx, policy))
                .expect("spawn flusher thread")
        };

        // one channel per shard, registered before any worker starts so
        // cross-shard routing never races a missing peer
        let mut peers: FxHashMap<Pid, Sender<(Pid, Pid, Wire)>> = FxHashMap::default();
        let mut inboxes = Vec::new();
        for node in &self.nodes {
            let (tx, rx) = mpsc::channel();
            peers.insert(node.pid(), tx.clone());
            inboxes.push((tx, rx));
        }

        let mut workers = Vec::new();
        let mut senders: FxHashMap<Pid, Sender<(Pid, Pid, Wire)>> = FxHashMap::default();
        let nodes = std::mem::take(&mut self.nodes);
        let mut storage = std::mem::take(&mut self.storage);
        for (node, (tx, rx)) in nodes.into_iter().zip(inboxes) {
            let pid = node.pid();
            senders.insert(pid, tx);
            let worker = ShardWorker {
                node,
                storage: storage.remove(&pid),
                rx,
                peers: peers.clone(),
                out_tx: out_tx.clone(),
                outbox: Outbox::new(),
                scratch: Vec::new(),
                outgoing: Vec::new(),
                timers: BinaryHeap::new(),
                timer_seq: 0,
                epoch: self.epoch,
                on_deliver: cb.clone(),
                stats: Arc::clone(&self.stats),
                obs: self.obs.clone(),
                stop: Arc::clone(&stop),
                halt: Arc::clone(&halt),
            };
            workers.push(
                thread::Builder::new()
                    .name(format!("wbam-shard-{}", pid.0))
                    .spawn(move || worker.run())
                    .expect("spawn shard worker"),
            );
        }
        drop(out_tx); // flusher exits once every worker is gone
        drop(peers); // workers own their clones; ours would pin the channels

        // receive loop: demux addressed frames to shard workers
        while !stop.load(Ordering::Relaxed) && !halt.load(Ordering::Relaxed) {
            match self.transport.recv_timeout(IDLE_TICK) {
                Some(Incoming::Wire(from, to, wire)) => match senders.get(&to) {
                    Some(tx) => {
                        let _ = tx.send((from, to, wire));
                    }
                    None => {
                        self.stats.dropped_frames.fetch_add(1, Ordering::Relaxed);
                        log::warn!("frame {from:?}->{to:?} at an endpoint not hosting {to:?}");
                    }
                },
                Some(Incoming::Closed) => break,
                None => {}
            }
        }
        halt.store(true, Ordering::Relaxed);
        drop(senders); // workers also exit on channel disconnect
        let nodes: Vec<Box<dyn Node>> =
            workers.into_iter().map(|h| h.join().expect("shard worker panicked")).collect();
        let _ = flusher.join();
        nodes
    }
}

/// The single-node runtime (clients, CLI `serve`): the inline fast path
/// of [`ShardedRuntime`].
pub struct NodeRuntime<T: Transport> {
    inner: ShardedRuntime<T>,
}

impl<T: Transport> NodeRuntime<T> {
    /// Host one `node` on `transport` (the inline fast path).
    pub fn new(node: Box<dyn Node>, transport: T) -> Self {
        NodeRuntime { inner: ShardedRuntime::new(vec![node], transport) }
    }

    /// Attach the node's durable WAL (see
    /// [`ShardedRuntime::attach_storage`]).
    pub fn attach_storage(&mut self, store: Storage) {
        let pid = self.inner.nodes[0].pid();
        self.inner.attach_storage(pid, store);
    }

    /// Install the delivery callback (see
    /// [`ShardedRuntime::on_deliver`]).
    pub fn on_deliver(&mut self, f: DeliverFn) {
        self.inner.on_deliver(f);
    }

    /// Set the wire-coalescing [`FlushPolicy`].
    pub fn flush_policy(&mut self, p: FlushPolicy) {
        self.inner.flush_policy(p);
    }

    /// Attach the live-observability sink (see
    /// [`ShardedRuntime::attach_metrics`]).
    pub fn attach_metrics(&mut self, cm: Arc<CoreMetrics>) {
        self.inner.attach_metrics(cm);
    }

    /// Run through the threaded pipeline instead of the inline fast path
    /// (comparison benches only).
    pub fn force_threaded(&mut self) {
        self.inner.force_threaded();
    }

    /// Shared counters handle (see [`ShardedRuntime::stats`]).
    pub fn stats(&self) -> Arc<CoordStats> {
        self.inner.stats()
    }

    /// Run until `stop` is raised. Returns the node back for inspection.
    pub fn run(self, stop: Arc<AtomicBool>) -> Box<dyn Node> {
        let mut nodes = self.inner.run(stop);
        nodes.pop().expect("single node")
    }
}

/// Convenience: spawn a single-node runtime on its own thread; returns a
/// join handle yielding the node when stopped.
pub fn spawn<T: Transport + 'static>(
    node: Box<dyn Node>,
    transport: T,
    stop: Arc<AtomicBool>,
    on_deliver: Option<DeliverFn>,
) -> thread::JoinHandle<Box<dyn Node>> {
    let name = format!("wbam-node-{}", node.pid().0);
    thread::Builder::new()
        .name(name)
        .spawn(move || {
            let mut rt = NodeRuntime::new(node, transport);
            if let Some(f) = on_deliver {
                rt.on_deliver(f);
            }
            rt.run(stop)
        })
        .expect("spawn node thread")
}

/// Spawn one endpoint hosting several shard nodes; yields the nodes back
/// when stopped.
pub fn spawn_sharded<T: Transport + 'static>(
    nodes: Vec<Box<dyn Node>>,
    transport: T,
    stop: Arc<AtomicBool>,
    on_deliver: Option<DeliverFn>,
) -> thread::JoinHandle<Vec<Box<dyn Node>>> {
    let name = format!("wbam-host-{}", nodes.first().map(|n| n.pid().0).unwrap_or(0));
    thread::Builder::new()
        .name(name)
        .spawn(move || {
            let mut rt = ShardedRuntime::new(nodes, transport);
            if let Some(f) = on_deliver {
                rt.on_deliver(f);
            }
            rt.run(stop)
        })
        .expect("spawn host thread")
}

/// Round-trip latency micro-harness shared by the pinned latency test
/// and the `hotpath` bench: a pinger and an echo node on their own
/// 1-node endpoints over a fresh in-process mesh, closed loop for
/// `trips` round trips. `threaded` forces the worker/flusher pipeline
/// instead of the inline fast path (the comparison the inline path's
/// ≥20% acceptance bar is measured against). Returns ns per round trip;
/// panics if the ping-pong stalls.
pub fn one_shard_round_trip_ns(trips: u64, threaded: bool) -> f64 {
    use crate::types::Ballot;

    struct Pinger {
        pid: Pid,
        peer: Pid,
        limit: u64,
        rounds: Arc<AtomicU64>,
    }
    impl Node for Pinger {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _n: u64, out: &mut Outbox) {
            out.send(self.peer, Wire::Heartbeat { bal: Ballot::new(1, self.pid) });
        }
        fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, out: &mut Outbox) {
            let n = self.rounds.fetch_add(1, Ordering::Relaxed) + 1;
            if n < self.limit {
                out.send(self.peer, Wire::Heartbeat { bal: Ballot::new(1, self.pid) });
            }
        }
        fn on_timer(&mut self, _t: TimerKind, _n: u64, _o: &mut Outbox) {}
    }
    struct EchoBack {
        pid: Pid,
    }
    impl Node for EchoBack {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _n: u64, _o: &mut Outbox) {}
        fn on_wire(&mut self, from: Pid, wire: Wire, _n: u64, out: &mut Outbox) {
            out.send(from, wire);
        }
        fn on_timer(&mut self, _t: TimerKind, _n: u64, _o: &mut Outbox) {}
    }

    let rounds = Arc::new(AtomicU64::new(0));
    let mesh = crate::net::InProcMesh::new();
    let ep_a = mesh.endpoint(Pid(1));
    let ep_b = mesh.endpoint(Pid(2));
    let stop = Arc::new(AtomicBool::new(false));
    let spawn_one = move |node: Box<dyn Node>, ep: crate::net::InProcTransport, stop: Arc<AtomicBool>| {
        thread::spawn(move || {
            let mut rt = ShardedRuntime::new(vec![node], ep);
            if threaded {
                rt.force_threaded();
            }
            rt.run(stop)
        })
    };
    let t0 = Instant::now();
    let a = spawn_one(
        Box::new(Pinger { pid: Pid(1), peer: Pid(2), limit: trips, rounds: Arc::clone(&rounds) }),
        ep_a,
        Arc::clone(&stop),
    );
    let b = spawn_one(Box::new(EchoBack { pid: Pid(2) }), ep_b, Arc::clone(&stop));
    let deadline = Instant::now() + Duration::from_secs(120);
    while rounds.load(Ordering::Relaxed) < trips {
        assert!(
            Instant::now() < deadline,
            "ping-pong stalled at {} rounds (threaded={threaded})",
            rounds.load(Ordering::Relaxed)
        );
        thread::yield_now();
    }
    let elapsed = t0.elapsed();
    stop.store(true, Ordering::Relaxed);
    a.join().expect("pinger runtime");
    b.join().expect("echo runtime");
    elapsed.as_nanos() as f64 / trips as f64
}

/// A whole cluster on threads: endpoints (each hosting one or more
/// nodes), by default over a fresh in-process
/// [`InProcMesh`](crate::net::InProcMesh), or over any [`Transport`]
/// via [`Cluster::launch_hosts_over`] (real TCP / epoll sockets).
pub struct Cluster {
    /// raise to stop every endpoint (what [`Cluster::shutdown`] does)
    pub stop: Arc<AtomicBool>,
    /// one join handle per endpoint, yielding its nodes back
    pub handles: Vec<thread::JoinHandle<Vec<Box<dyn Node>>>>,
    /// transport counters: mesh-wide for in-process launches
    /// (`dropped_frames` is zero on a healthy run — only disconnects
    /// make the mesh drop); the first endpoint's for
    /// [`Cluster::launch_hosts_over`] launches, where each endpoint has
    /// its own counters — see [`Cluster::nets`]
    pub net: Arc<crate::net::NetStats>,
    /// per-endpoint transport counters, in host order (all clones of
    /// one mesh-wide handle for in-process launches)
    pub nets: Vec<Arc<crate::net::NetStats>>,
}

impl Cluster {
    /// Launch `nodes`, one endpoint each (every endpoint takes the inline
    /// fast path). `on_deliver` is invoked for every local delivery on
    /// any node.
    pub fn launch(nodes: Vec<Box<dyn Node>>, on_deliver: Option<Arc<Mutex<DeliverFn>>>) -> Cluster {
        Self::launch_hosts(nodes.into_iter().map(|n| vec![n]).collect(), on_deliver)
    }

    /// Launch a sharded deployment: `hosts[i]` is the set of nodes
    /// sharing endpoint `i` (e.g. one machine's shard counterparts per
    /// [`crate::types::ShardMap::hosted_by`], clients as singleton
    /// hosts).
    ///
    /// ```
    /// use wbam::coordinator::Cluster;
    /// use wbam::protocols::{Node, Outbox, TimerKind};
    /// use wbam::types::{Ballot, Pid, Wire};
    ///
    /// // a minimal Node: greets its peer once at startup
    /// struct Hello {
    ///     pid: Pid,
    ///     peer: Pid,
    /// }
    /// impl Node for Hello {
    ///     fn pid(&self) -> Pid {
    ///         self.pid
    ///     }
    ///     fn on_start(&mut self, _now: u64, out: &mut Outbox) {
    ///         out.send(self.peer, Wire::Heartbeat { bal: Ballot::new(1, self.pid) });
    ///     }
    ///     fn on_wire(&mut self, _from: Pid, _w: Wire, _now: u64, _out: &mut Outbox) {}
    ///     fn on_timer(&mut self, _t: TimerKind, _now: u64, _out: &mut Outbox) {}
    /// }
    ///
    /// // two single-node hosts over a fresh in-process mesh
    /// let hosts: Vec<Vec<Box<dyn Node>>> = vec![
    ///     vec![Box::new(Hello { pid: Pid(1), peer: Pid(2) })],
    ///     vec![Box::new(Hello { pid: Pid(2), peer: Pid(1) })],
    /// ];
    /// let cluster = Cluster::launch_hosts(hosts, None);
    /// std::thread::sleep(std::time::Duration::from_millis(100));
    /// let nodes = cluster.shutdown();
    /// assert_eq!(nodes.len(), 2); // the nodes come back for inspection
    /// ```
    pub fn launch_hosts(
        hosts: Vec<Vec<Box<dyn Node>>>,
        on_deliver: Option<Arc<Mutex<DeliverFn>>>,
    ) -> Cluster {
        Self::launch_hosts_with(hosts, on_deliver, FlushPolicy::default())
    }

    /// [`Cluster::launch_hosts`] with an explicit wire-coalescing
    /// [`FlushPolicy`] applied to every endpoint.
    pub fn launch_hosts_with(
        hosts: Vec<Vec<Box<dyn Node>>>,
        on_deliver: Option<Arc<Mutex<DeliverFn>>>,
        flush: FlushPolicy,
    ) -> Cluster {
        let mesh = crate::net::InProcMesh::new();
        let net = mesh.net_stats();
        let mut cluster = Self::launch_hosts_over(hosts, on_deliver, flush, |pids| mesh.endpoint_hosting(pids));
        cluster.net = net; // mesh-wide counters, even with zero hosts
        cluster
    }

    /// The transport-generic launcher behind the in-process variants:
    /// `endpoint(&pids)` builds the transport for each host (the slice
    /// holds the pids that host serves), so the same deployment code
    /// runs over the mesh, threaded TCP or epoll sockets — the
    /// `hotpath` bench's transport ablation and the epoll parity e2e
    /// use exactly this. Every endpoint is created (bound, listening)
    /// before any node starts, so early sends have somewhere to go.
    pub fn launch_hosts_over<T, F>(
        hosts: Vec<Vec<Box<dyn Node>>>,
        on_deliver: Option<Arc<Mutex<DeliverFn>>>,
        flush: FlushPolicy,
        mut endpoint: F,
    ) -> Cluster
    where
        T: Transport + 'static,
        F: FnMut(&[Pid]) -> T,
    {
        let stop = Arc::new(AtomicBool::new(false));
        // create all endpoints before starting any node
        let endpoints: Vec<T> = hosts
            .iter()
            .map(|ns| {
                let pids: Vec<Pid> = ns.iter().map(|n| n.pid()).collect();
                endpoint(&pids)
            })
            .collect();
        let nets: Vec<Arc<crate::net::NetStats>> = endpoints.iter().map(|e| e.net_stats()).collect();
        let net = nets.first().cloned().unwrap_or_default();
        let mut handles = Vec::new();
        for (ns, ep) in hosts.into_iter().zip(endpoints) {
            // hand every endpoint the same shared callback handle: one
            // lock layer cluster-wide, no per-endpoint re-wrapping
            let cb = on_deliver.clone();
            let stop2 = Arc::clone(&stop);
            let name = format!("wbam-host-{}", ns.first().map(|n| n.pid().0).unwrap_or(0));
            handles.push(
                thread::Builder::new()
                    .name(name)
                    .spawn(move || {
                        let mut rt = ShardedRuntime::new(ns, ep);
                        rt.flush_policy(flush);
                        if let Some(f) = cb {
                            rt.on_deliver_shared(f);
                        }
                        rt.run(stop2)
                    })
                    .expect("spawn host thread"),
            );
        }
        Cluster { stop, handles, net, nets }
    }

    /// Stop all endpoint threads and collect the nodes.
    pub fn shutdown(self) -> Vec<Box<dyn Node>> {
        self.stop.store(true, Ordering::Relaxed);
        self.handles
            .into_iter()
            .flat_map(|h| h.join().expect("node thread panicked"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, ClientCfg};
    use crate::protocols::wbcast::{WbConfig, WbNode};
    use crate::types::{Ballot, ShardMap, Topology};

    /// Two shards on one endpoint plus a remote sink: sends between the
    /// hosted pids must be routed in-process (`self_wires`), only the
    /// remote-bound wires may reach the transport (`wires_out`).
    #[test]
    fn cross_shard_routing_stays_in_process() {
        struct Chatter {
            pid: Pid,
            sibling: Pid,
            remote: Pid,
            heard: u32,
        }
        impl Node for Chatter {
            fn pid(&self) -> Pid {
                self.pid
            }
            fn on_start(&mut self, _now: u64, out: &mut Outbox) {
                out.send(self.sibling, Wire::Heartbeat { bal: Ballot::new(1, self.pid) });
                out.send(self.remote, Wire::Heartbeat { bal: Ballot::new(1, self.pid) });
            }
            fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, _o: &mut Outbox) {
                self.heard += 1;
            }
            fn on_timer(&mut self, _t: TimerKind, _n: u64, _o: &mut Outbox) {}
        }

        let mesh = crate::net::InProcMesh::new();
        let ep = mesh.endpoint_hosting(&[Pid(1), Pid(2)]);
        let mut remote = mesh.endpoint(Pid(9));
        let stop = Arc::new(AtomicBool::new(false));
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Chatter { pid: Pid(1), sibling: Pid(2), remote: Pid(9), heard: 0 }),
            Box::new(Chatter { pid: Pid(2), sibling: Pid(1), remote: Pid(9), heard: 0 }),
        ];
        let mut rt = ShardedRuntime::new(nodes, ep);
        let stats = rt.stats();
        let stop2 = Arc::clone(&stop);
        let handle = thread::spawn(move || rt.run(stop2));

        // exactly the two remote-bound heartbeats reach the transport
        for _ in 0..2 {
            match remote.recv_timeout(Duration::from_secs(5)) {
                Some(Incoming::Wire(_, Pid(9), Wire::Heartbeat { .. })) => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        // both cross-shard heartbeats arrive through the in-process route
        let deadline = Instant::now() + Duration::from_secs(5);
        while stats.wires_in.load(Ordering::Relaxed) < 2 {
            assert!(Instant::now() < deadline, "cross-shard wires never delivered");
            thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
        let nodes = handle.join().expect("runtime thread");
        for n in &nodes {
            let any: &dyn Node = &**n;
            let c = (any as &dyn std::any::Any).downcast_ref::<Chatter>().expect("chatter");
            assert_eq!(c.heard, 1, "{:?} missed its sibling's heartbeat", c.pid);
        }
        assert_eq!(stats.self_wires.load(Ordering::Relaxed), 2, "cross-shard sends must stay off the transport");
        assert_eq!(stats.wires_out.load(Ordering::Relaxed), 2, "remote sends must reach the transport");
        assert_eq!(stats.wires_in.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn inproc_cluster_runs_wbcast_end_to_end() {
        let topo = Topology::new(2, 1);
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        let wb = WbConfig { hb_interval: 20_000_000, ..WbConfig::default() };
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(WbNode::new(p, topo.clone(), wb)));
            }
        }
        for c in 0..4u32 {
            let pid = Pid(topo.first_client_pid().0 + c);
            let cfg = ClientCfg {
                dest_groups: 2,
                max_requests: Some(25),
                resend_after: 200_000_000,
                ..Default::default()
            };
            nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, 77 + c as u64)));
        }
        let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));
        let dv = Arc::clone(&deliveries);
        let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid, m, gts, _t| {
            dv.lock().unwrap().push((pid, m, gts));
        })));
        let cluster = Cluster::launch(nodes, Some(cb));
        let net = Arc::clone(&cluster.net);

        // wait until all 100 requests completed at every member (6 nodes
        // x 100 deliveries), with a deadline
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let n = deliveries.lock().unwrap().len();
            if n >= 600 {
                break;
            }
            assert!(Instant::now() < deadline, "timeout: {n}/600 deliveries");
            thread::sleep(Duration::from_millis(20));
        }
        // happy path: no frame was ever dropped by the transport (checked
        // before shutdown — endpoints exiting in arbitrary order may
        // legitimately drop a final heartbeat to an already-gone peer)
        assert_eq!(net.dropped_frames.load(Ordering::Relaxed), 0, "transport dropped frames");
        let nodes = cluster.shutdown();

        // per-pid gts must be strictly increasing (Ordering)
        let dels = deliveries.lock().unwrap();
        let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
        for &(pid, _m, gts) in dels.iter() {
            per_pid.entry(pid).or_default().push(gts);
        }
        for (pid, seq) in &per_pid {
            for w in seq.windows(2) {
                assert!(w[0] < w[1], "{pid:?} delivered out of order");
            }
        }
        // clients completed their quotas
        for n in nodes {
            let any: &dyn Node = &*n;
            if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
                assert_eq!(c.completed.len(), 25);
            }
        }
    }

    /// Acceptance: a 2-group topology with 4 shards per leader delivers a
    /// multi-group workload end to end, per-pid gts ordering green, and
    /// cross-shard traffic stays off the transport.
    #[test]
    fn sharded_runtime_end_to_end() {
        let map = ShardMap::new(2, 1, 4);
        let wb = WbConfig { hb_interval: 20_000_000, ..WbConfig::default() };
        let mut hosts: Vec<Vec<Box<dyn Node>>> = Vec::new();
        // 6 member endpoints, each hosting its 4 shard counterparts
        for e in map.endpoints() {
            let mut ns: Vec<Box<dyn Node>> = Vec::new();
            for p in map.hosted_by(e) {
                let s = map.shard_of(p).expect("hosted pid is a member");
                ns.push(Box::new(WbNode::new(p, map.topo(s), wb)));
            }
            hosts.push(ns);
        }
        // 8 clients, partitioned round-robin over the 4 shards
        let n_clients = 8u32;
        let requests = 15usize;
        for c in 0..n_clients {
            let pid = Pid(map.first_client_pid().0 + c);
            let s = map.client_shard(pid);
            let cfg = ClientCfg {
                dest_groups: 2,
                max_requests: Some(requests as u32),
                resend_after: 200_000_000,
                ..Default::default()
            };
            hosts.push(vec![Box::new(Client::new(pid, map.topo(s), cfg, 31 + c as u64))]);
        }

        let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));
        let dv = Arc::clone(&deliveries);
        let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid, m, gts, _t| {
            dv.lock().unwrap().push((pid, m, gts));
        })));
        let cluster = Cluster::launch_hosts(hosts, Some(cb));

        // 8 clients x 15 requests x 2 groups x 3 replicas = 720 deliveries
        let expected = n_clients as usize * requests * 2 * 3;
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let n = deliveries.lock().unwrap().len();
            if n >= expected {
                break;
            }
            assert!(Instant::now() < deadline, "timeout: {n}/{expected} deliveries");
            thread::sleep(Duration::from_millis(20));
        }
        let nodes = cluster.shutdown();

        let dels = deliveries.lock().unwrap();
        // per-pid gts strictly increasing (Ordering, per shard node), and
        // every delivering pid is a member of the shard it claims
        let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
        for &(pid, m, gts) in dels.iter() {
            assert_eq!(
                map.client_shard(Pid(m.client())),
                map.shard_of(pid).expect("delivery at a member"),
                "message crossed shards"
            );
            per_pid.entry(pid).or_default().push(gts);
        }
        // all 24 shard nodes participated
        assert_eq!(per_pid.len(), map.num_members(), "idle shard nodes");
        for (pid, seq) in &per_pid {
            for w in seq.windows(2) {
                assert!(w[0] < w[1], "{pid:?} delivered out of gts order");
            }
        }
        // gts agreement per message across its shard's replicas
        let mut gts_of: std::collections::HashMap<MsgId, Ts> = Default::default();
        for &(_pid, m, gts) in dels.iter() {
            let e = gts_of.entry(m).or_insert(gts);
            assert_eq!(*e, gts, "gts disagreement for {m:?}");
        }
        // clients all completed
        for n in nodes {
            let any: &dyn Node = &*n;
            if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
                assert_eq!(c.completed.len(), requests);
            }
        }
    }

    /// Acceptance (inline fast path): the inline 1-shard runtime beats
    /// the threaded 1-shard pipeline on single-message round-trip latency
    /// by >= 20% (it removes two channel hops and two thread wakeups per
    /// message). Pinned alongside the sim-side >= 1.5x sharding check
    /// (`harness::tests::sharding_lifts_saturation_throughput`); the
    /// `hotpath` bench prints the same comparison via the shared
    /// [`one_shard_round_trip_ns`] harness.
    #[test]
    fn inline_single_shard_beats_threaded_on_latency() {
        let threaded = one_shard_round_trip_ns(2_000, true);
        let inline = one_shard_round_trip_ns(2_000, false);
        assert!(
            inline <= 0.8 * threaded,
            "inline 1-shard path must beat the threaded pipeline by >=20% on round-trip latency: \
             inline {inline:.0} ns vs threaded {threaded:.0} ns"
        );
    }

    /// Regression (flusher shutdown loss): stopping an endpoint under
    /// load must drain everything already queued toward the transport —
    /// every wire counted `wires_out` reaches the mesh, none strand in
    /// the worker -> flusher pipeline or in the coalescer.
    #[test]
    fn shutdown_under_load_drains_every_queued_send() {
        struct Pumper {
            pid: Pid,
            to: Pid,
        }
        impl Node for Pumper {
            fn pid(&self) -> Pid {
                self.pid
            }
            fn on_start(&mut self, _n: u64, out: &mut Outbox) {
                out.timer(TimerKind::LssTick, 200_000);
            }
            fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, _o: &mut Outbox) {}
            fn on_timer(&mut self, _t: TimerKind, _n: u64, out: &mut Outbox) {
                for i in 0..32u32 {
                    out.send(self.to, Wire::Heartbeat { bal: Ballot::new(i + 1, self.pid) });
                }
                out.timer(TimerKind::LssTick, 200_000);
            }
        }

        let mesh = crate::net::InProcMesh::new();
        let ep = mesh.endpoint_hosting(&[Pid(1), Pid(2)]);
        let mut sink = mesh.endpoint(Pid(9));
        let stop = Arc::new(AtomicBool::new(false));
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(Pumper { pid: Pid(1), to: Pid(9) }),
            Box::new(Pumper { pid: Pid(2), to: Pid(9) }),
        ];
        let mut rt = ShardedRuntime::new(nodes, ep); // 2 shards: threaded path
        let stats = rt.stats();
        let stop2 = Arc::clone(&stop);
        let h = thread::spawn(move || rt.run(stop2));

        // let the pumpers build up in-flight traffic, then stop mid-stream
        thread::sleep(Duration::from_millis(120));
        stop.store(true, Ordering::Relaxed);
        h.join().expect("runtime thread");

        let mut received = 0u64;
        while let Some(Incoming::Wire(_, _, w)) = sink.recv_timeout(Duration::from_millis(50)) {
            received += match w {
                Wire::Batch(inner) => inner.len() as u64,
                _ => 1,
            };
        }
        let out = stats.wires_out.load(Ordering::Relaxed);
        assert!(out > 0, "pumpers never produced load");
        assert_eq!(received, out, "sends lost in the worker->flusher shutdown path");
    }

    /// The full WbCast workload on single-node endpoints — every endpoint
    /// on the inline fast path — under an adaptive flush policy with the
    /// quiet-flush disabled: correctness must be unchanged, and the mesh
    /// must drop nothing.
    #[test]
    fn inline_cluster_adaptive_flush_end_to_end() {
        let topo = Topology::new(2, 1);
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        let wb = WbConfig { hb_interval: 20_000_000, ..WbConfig::default() };
        for g in topo.gids() {
            for &p in topo.members(g) {
                nodes.push(Box::new(WbNode::new(p, topo.clone(), wb)));
            }
        }
        for c in 0..4u32 {
            let pid = Pid(topo.first_client_pid().0 + c);
            let cfg = ClientCfg {
                dest_groups: 2,
                max_requests: Some(15),
                resend_after: 400_000_000,
                ..Default::default()
            };
            nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, 7 + c as u64)));
        }
        let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));
        let dv = Arc::clone(&deliveries);
        let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid, m, gts, _t| {
            dv.lock().unwrap().push((pid, m, gts));
        })));
        let policy = FlushPolicy { max_delay_us: 200, max_bytes: 1 << 16, flush_on_quiet: false };
        let cluster = Cluster::launch_hosts_with(nodes.into_iter().map(|n| vec![n]).collect(), Some(cb), policy);
        let net = Arc::clone(&cluster.net);

        // 4 clients x 15 requests x 2 groups x 3 replicas = 360 deliveries
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let n = deliveries.lock().unwrap().len();
            if n >= 360 {
                break;
            }
            assert!(Instant::now() < deadline, "timeout: {n}/360 deliveries");
            thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(net.dropped_frames.load(Ordering::Relaxed), 0, "mesh dropped frames");
        let nodes = cluster.shutdown();

        let dels = deliveries.lock().unwrap();
        let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
        for &(pid, _m, gts) in dels.iter() {
            per_pid.entry(pid).or_default().push(gts);
        }
        for (pid, seq) in &per_pid {
            for w in seq.windows(2) {
                assert!(w[0] < w[1], "{pid:?} delivered out of gts order under adaptive flush");
            }
        }
        for n in nodes {
            let any: &dyn Node = &*n;
            if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
                assert_eq!(c.completed.len(), 15);
            }
        }
    }
}

/// Exhaustive interleaving tests for the flusher hand-off, run under the
/// in-tree model checker: `RUSTFLAGS="--cfg loom" cargo test --release loom_`.
/// See `crate::sync::model` for the exploration bounds.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use crate::sync::model;
    use crate::types::Ballot;
    use std::sync::atomic::{AtomicU64 as RawU64, Ordering as RawOrdering};

    /// Counts every *inner* wire handed to the transport. The tally is a
    /// raw `std` atomic on purpose: it is the test's measurement, not
    /// part of the modeled race, so it must not add scheduling points.
    struct CountingTx(Arc<RawU64>);

    impl TransportTx for CountingTx {
        fn send(&mut self, _from: Pid, _to: Pid, wire: Wire) {
            let n = match &wire {
                Wire::Batch(inner) => inner.len() as u64,
                _ => 1,
            };
            self.0.fetch_add(n, RawOrdering::Relaxed);
        }
    }

    fn hb(n: u64) -> Wire {
        Wire::Heartbeat { bal: Ballot::new(n, Pid(1)) }
    }

    /// Invariant: once every queue handle is dropped, `run_flusher`'s
    /// disconnect path flushes everything still coalesced — no schedule
    /// may lose a queued send at shutdown.
    #[test]
    fn loom_flusher_shutdown_drains_every_queued_send() {
        model(|| {
            let sent = Arc::new(RawU64::new(0));
            let (tx, rx) = mpsc::channel::<Vec<(Link, Wire)>>();
            let tally = sent.clone();
            let flusher = thread::spawn(move || {
                run_flusher(Box::new(CountingTx(tally)), rx, FlushPolicy::default())
            });
            let link: Link = (Pid(1), Pid(9));
            tx.send(vec![(link, hb(1)), (link, hb(2))]).unwrap();
            tx.send(vec![(link, hb(3))]).unwrap();
            drop(tx);
            flusher.join().unwrap();
            assert_eq!(
                sent.load(RawOrdering::Relaxed),
                3,
                "flusher lost queued sends at shutdown"
            );
        });
    }

    /// Model-checked mirror of the threaded-runtime regression
    /// `shutdown_under_load_drains_every_queued_send`: two shard threads
    /// hand batches to one flusher while everything shuts down; every
    /// schedule must still deliver all queued wires to the transport.
    #[test]
    fn loom_shutdown_under_load_drains_every_queued_send() {
        model(|| {
            let sent = Arc::new(RawU64::new(0));
            let (tx, rx) = mpsc::channel::<Vec<(Link, Wire)>>();
            let tally = sent.clone();
            let flusher = thread::spawn(move || {
                run_flusher(Box::new(CountingTx(tally)), rx, FlushPolicy::default())
            });
            let shard_tx = tx.clone();
            let shard = thread::spawn(move || {
                let link: Link = (Pid(2), Pid(9));
                shard_tx.send(vec![(link, hb(10))]).unwrap();
                shard_tx.send(vec![(link, hb(11))]).unwrap();
            });
            let link: Link = (Pid(1), Pid(9));
            tx.send(vec![(link, hb(1))]).unwrap();
            drop(tx);
            shard.join().unwrap();
            flusher.join().unwrap();
            assert_eq!(
                sent.load(RawOrdering::Relaxed),
                3,
                "a queued send was lost during shutdown under load"
            );
        });
    }
}
