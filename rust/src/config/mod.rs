//! Configuration: a minimal INI/TOML-subset parser plus a CLI argument
//! helper (the offline image has no serde/clap). Used by the `wbam`
//! launcher binary and the examples.
//!
//! Accepted file syntax:
//!
//! ```text
//! # comment
//! [section]
//! key = value          # integers, floats, bools, strings
//! name = "quoted ok"
//! ```

use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum ConfigError {
    #[error("line {0}: malformed entry: {1}")]
    Malformed(usize, String),
    #[error("missing key: {0}")]
    Missing(String),
    #[error("key {0}: cannot parse {1:?} as {2}")]
    BadValue(String, String, &'static str),
}

/// Parsed config: `section.key -> value` (top-level keys have no prefix).
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(idx) => &raw[..idx],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(ConfigError::Malformed(i + 1, raw.to_string()));
            };
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ConfigError::Malformed(i + 1, raw.to_string()));
            }
            let mut val = line[eq + 1..].trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::BadValue(path.into(), e.to_string(), "readable file"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn u64(&self, key: &str, default: u64) -> Result<u64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::BadValue(key.into(), v.into(), "u64")),
        }
    }
    pub fn usize(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        Ok(self.u64(key, default as u64)? as usize)
    }
    pub fn f64(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::BadValue(key.into(), v.into(), "f64")),
        }
    }
    pub fn bool(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(ConfigError::BadValue(key.into(), v.into(), "bool")),
        }
    }
}

/// Tiny CLI helper: `--key value`, `--flag`, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn u64_opt(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn usize_opt(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn str_opt(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_quotes() {
        let cfg = Config::parse(
            r#"
            # top comment
            workers = 4
            [net]
            kind = "wan"          # inline comment
            delta_us = 1000
            [wb]
            gc = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.usize("workers", 0).unwrap(), 4);
        assert_eq!(cfg.str("net.kind", ""), "wan");
        assert_eq!(cfg.u64("net.delta_us", 0).unwrap(), 1000);
        assert!(cfg.bool("wb.gc", false).unwrap());
        assert_eq!(cfg.u64("absent", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("not a kv line").is_err());
        assert!(Config::parse("= novalue").is_err());
    }

    #[test]
    fn bad_typed_values_error() {
        let cfg = Config::parse("x = abc").unwrap();
        assert!(cfg.u64("x", 0).is_err());
        assert!(cfg.bool("x", false).is_err());
    }

    #[test]
    fn args_forms() {
        let a = Args::parse(
            ["bench", "--clients", "100", "--net=wan", "--verbose", "--groups", "10"].map(String::from),
        );
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.u64_opt("clients", 0), 100);
        assert_eq!(a.str_opt("net", ""), "wan");
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_opt("groups", 0), 10);
        assert_eq!(a.u64_opt("absent", 9), 9);
    }
}
