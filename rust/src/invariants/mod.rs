//! Runtime checker for the paper's correctness properties (§II) and the
//! observable consequences of Invariants 3–4 (Fig. 6), evaluated over a
//! full run trace:
//!
//! * **Agreement / uniqueness** (Invariants 3b, 4): every process that
//!   delivers a message observes the same global timestamp, and no two
//!   messages share one.
//! * **Integrity**: no process delivers a message twice.
//! * **Validity**: only multicast messages are delivered, only at their
//!   destination groups.
//! * **Ordering**: per process, deliveries are strictly increasing in
//!   global timestamp, and each process's delivered set is downward-closed
//!   within the messages addressed to its group that were delivered
//!   anywhere. (Together with agreement + uniqueness this is equivalent to
//!   the existence of the total order ≺ of §II.)
//! * **Termination** (quiescent, crash-aware): every multicast message is
//!   delivered by a quorum of correct processes in every destination
//!   group.

use crate::sim::Trace;
use crate::types::{MsgId, Pid, Ts};
use std::collections::{HashMap, HashSet};

/// A violation found in a trace.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.rule, self.detail)
    }
}

/// Check safety properties over a (full-resolution) trace.
/// Returns all violations found; empty = clean run.
pub fn check_safety(trace: &Trace) -> Vec<Violation> {
    assert!(trace.record_full, "safety checking needs record_full = true");
    // shards are independent ordering domains (gts uniqueness only holds
    // within one) — check each projection, see [`assert_correct_sharded`]
    assert_eq!(trace.shards(), 1, "check sharded traces per shard via Trace::shard_view");
    let mut v = Vec::new();
    let topo = trace.topo().clone();

    // --- agreement + uniqueness of global timestamps ---
    let mut gts_of: HashMap<MsgId, Ts> = HashMap::new();
    let mut msg_of: HashMap<Ts, MsgId> = HashMap::new();
    for d in &trace.deliveries {
        match gts_of.get(&d.m) {
            None => {
                gts_of.insert(d.m, d.gts);
                if let Some(other) = msg_of.insert(d.gts, d.m) {
                    if other != d.m {
                        v.push(Violation {
                            rule: "gts-unique",
                            detail: format!("{:?} and {:?} both delivered with gts {:?}", other, d.m, d.gts),
                        });
                    }
                }
            }
            Some(&g) if g != d.gts => v.push(Violation {
                rule: "gts-agreement",
                detail: format!("{:?} delivered with gts {:?} at {:?} but {:?} elsewhere", d.m, d.gts, d.pid, g),
            }),
            _ => {}
        }
    }

    // --- integrity + validity ---
    let mut seen: HashSet<(Pid, MsgId)> = HashSet::new();
    for d in &trace.deliveries {
        if !seen.insert((d.pid, d.m)) {
            v.push(Violation { rule: "integrity", detail: format!("{:?} delivered {:?} twice", d.pid, d.m) });
        }
        match trace.multicasts.get(&d.m) {
            None => v.push(Violation {
                rule: "validity",
                detail: format!("{:?} delivered never-multicast {:?}", d.pid, d.m),
            }),
            Some((_, dest)) => {
                let Some(g) = topo.group_of(d.pid) else {
                    v.push(Violation {
                        rule: "validity",
                        detail: format!("non-member {:?} delivered {:?}", d.pid, d.m),
                    });
                    continue;
                };
                if !dest.contains(g) {
                    v.push(Violation {
                        rule: "validity",
                        detail: format!("{:?} in {:?} delivered {:?} not addressed to it", d.pid, g, d.m),
                    });
                }
            }
        }
    }

    // --- ordering: strictly increasing gts per process ---
    let mut per_pid: HashMap<Pid, Vec<(u64, MsgId, Ts)>> = HashMap::new();
    for d in &trace.deliveries {
        per_pid.entry(d.pid).or_default().push((d.time, d.m, d.gts));
    }
    for (pid, seq) in &per_pid {
        for w in seq.windows(2) {
            if w[1].2 <= w[0].2 {
                v.push(Violation {
                    rule: "ordering-monotone",
                    detail: format!(
                        "{:?} delivered {:?} (gts {:?}) after {:?} (gts {:?})",
                        pid, w[1].1, w[1].2, w[0].1, w[0].2
                    ),
                });
            }
        }
    }

    // --- ordering: downward-closedness of each process's delivered set ---
    // For pid p in group g: among messages addressed to g that were
    // delivered anywhere (thus have a gts), p's delivered set must be a
    // prefix under gts order.
    let mut addressed: HashMap<u32, Vec<(Ts, MsgId)>> = HashMap::new(); // gid -> [(gts, m)]
    for (&m, &(_t, dest)) in &trace.multicasts {
        if let Some(&gts) = gts_of.get(&m) {
            for g in dest.iter() {
                addressed.entry(g.0).or_default().push((gts, m));
            }
        }
    }
    for v_ in addressed.values_mut() {
        v_.sort_unstable();
    }
    for (pid, seq) in &per_pid {
        let Some(g) = topo.group_of(*pid) else { continue };
        let Some(all) = addressed.get(&g.0) else { continue };
        let delivered: HashSet<MsgId> = seq.iter().map(|&(_, m, _)| m).collect();
        let max_gts = seq.iter().map(|&(_, _, gts)| gts).max().unwrap_or(Ts::BOT);
        for &(gts, m) in all.iter() {
            if gts >= max_gts {
                break;
            }
            if !delivered.contains(&m) {
                v.push(Violation {
                    rule: "ordering-gap",
                    detail: format!(
                        "{:?} skipped {:?} (gts {:?}) but delivered up to gts {:?}",
                        pid, m, gts, max_gts
                    ),
                });
            }
        }
    }

    v
}

/// Check Termination over a quiescent trace: every multicast message must
/// be delivered by a quorum of *correct* (non-crashed) processes in every
/// destination group. Messages multicast by crashed clients are exempt
/// unless delivered somewhere (§II Termination).
pub fn check_termination(trace: &Trace) -> Vec<Violation> {
    assert!(trace.record_full);
    assert_eq!(trace.shards(), 1, "check sharded traces per shard via Trace::shard_view");
    let mut v = Vec::new();
    let topo = trace.topo().clone();
    let crashed: HashSet<Pid> = trace.crashes.iter().map(|&(_, p)| p).collect();

    let mut delivered_at: HashMap<MsgId, HashSet<Pid>> = HashMap::new();
    for d in &trace.deliveries {
        delivered_at.entry(d.m).or_default().insert(d.pid);
    }

    for (&m, &(_t, dest)) in &trace.multicasts {
        let delivered_somewhere = delivered_at.contains_key(&m);
        let sender_crashed = crashed.contains(&Pid(m.client()));
        if sender_crashed && !delivered_somewhere {
            continue;
        }
        for g in dest.iter() {
            let correct_delivered = topo
                .members(g)
                .iter()
                .filter(|p| !crashed.contains(p) && delivered_at.get(&m).is_some_and(|s| s.contains(p)))
                .count();
            if correct_delivered < topo.quorum() {
                v.push(Violation {
                    rule: "termination",
                    detail: format!(
                        "{:?} delivered by only {}/{} correct processes in {:?}",
                        m,
                        correct_delivered,
                        topo.quorum(),
                        g
                    ),
                });
            }
        }
    }
    v
}

/// Check safety + termination without panicking, shard-aware: the full
/// strict suite over a quiescent trace, returning every violation found
/// (empty = clean run). This is the swarm campaign's per-schedule check
/// — identical strictness to [`assert_correct`], but failures come back
/// as data so the runner can save the schedule and minimize it.
pub fn check_correct(trace: &Trace) -> Vec<Violation> {
    if trace.shards() > 1 {
        let mut v = Vec::new();
        for s in 0..trace.shards() {
            let view = trace.shard_view(s);
            v.extend(check_safety(&view));
            v.extend(check_termination(&view));
        }
        v
    } else {
        let mut v = check_safety(trace);
        v.extend(check_termination(trace));
        v
    }
}

/// Assert a clean trace; pretty-panic otherwise (test helper).
pub fn assert_safe(trace: &Trace) {
    let vs = check_safety(trace);
    if !vs.is_empty() {
        let head: Vec<String> = vs.iter().take(10).map(|v| v.to_string()).collect();
        panic!("{} safety violations:\n{}", vs.len(), head.join("\n"));
    }
}

/// Assert safety + termination (quiescent runs).
pub fn assert_correct(trace: &Trace) {
    assert_safe(trace);
    let vs = check_termination(trace);
    if !vs.is_empty() {
        let head: Vec<String> = vs.iter().take(10).map(|v| v.to_string()).collect();
        panic!("{} termination violations:\n{}", vs.len(), head.join("\n"));
    }
}

/// Assert safety + termination of a sharded run, shard by shard (each
/// shard is its own ordering domain; see [`Trace::shard_view`]).
pub fn assert_correct_sharded(trace: &Trace) {
    for s in 0..trace.shards() {
        assert_correct(&trace.shard_view(s));
    }
}

/// Like [`assert_correct`] / [`assert_correct_sharded`] (picked by the
/// trace's shard count), but when a [`FlightRecorder`] rode along
/// ([`crate::sim::World::enable_flight`]) its tail is dumped to stderr
/// *before* the panic propagates — a failed invariant arrives with the
/// wire/journal/delivery history that led to it instead of a bare
/// assertion message.
// stderr by contract: this runs mid-panic in test harnesses, where the
// log capture is already unwinding (same audited exception as
// `WbNode::debug_dump`; see the crate-root lint note).
#[allow(clippy::print_stderr)]
pub fn assert_correct_with_flight(trace: &Trace, flight: Option<&crate::obs::FlightRecorder>) {
    let checks = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if trace.shards() > 1 {
            assert_correct_sharded(trace);
        } else {
            assert_correct(trace);
        }
    }));
    if let Err(cause) = checks {
        if let Some(fl) = flight {
            eprintln!(
                "=== invariant failure: flight recorder tail ({} of {} events) ===\n{}",
                fl.len(),
                fl.pushed(),
                fl.render()
            );
        }
        std::panic::resume_unwind(cause);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Gid, GidSet, Topology};

    fn base_trace() -> Trace {
        Trace::new(Topology::new(2, 0), true)
    }

    #[test]
    fn clean_trace_passes() {
        let mut tr = base_trace();
        let m1 = MsgId::new(9, 1);
        let m2 = MsgId::new(9, 2);
        let both = GidSet::from_iter([Gid(0), Gid(1)]);
        tr.on_multicast(0, m1, both);
        tr.on_multicast(0, m2, both);
        for pid in [Pid(0), Pid(1)] {
            tr.on_deliver(10, pid, m1, Ts::new(1, Gid(0)));
            tr.on_deliver(20, pid, m2, Ts::new(2, Gid(0)));
        }
        assert!(check_safety(&tr).is_empty());
        assert!(check_termination(&tr).is_empty());
    }

    #[test]
    fn detects_gts_disagreement() {
        let mut tr = base_trace();
        let m = MsgId::new(9, 1);
        tr.on_multicast(0, m, GidSet::from_iter([Gid(0), Gid(1)]));
        tr.on_deliver(10, Pid(0), m, Ts::new(1, Gid(0)));
        tr.on_deliver(10, Pid(1), m, Ts::new(2, Gid(0)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "gts-agreement"), "{vs:?}");
    }

    #[test]
    fn detects_duplicate_gts() {
        let mut tr = base_trace();
        let m1 = MsgId::new(9, 1);
        let m2 = MsgId::new(9, 2);
        tr.on_multicast(0, m1, GidSet::single(Gid(0)));
        tr.on_multicast(0, m2, GidSet::single(Gid(0)));
        tr.on_deliver(10, Pid(0), m1, Ts::new(1, Gid(0)));
        tr.on_deliver(20, Pid(0), m2, Ts::new(1, Gid(0)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "gts-unique"), "{vs:?}");
    }

    #[test]
    fn detects_double_delivery() {
        let mut tr = base_trace();
        let m = MsgId::new(9, 1);
        tr.on_multicast(0, m, GidSet::single(Gid(0)));
        tr.on_deliver(10, Pid(0), m, Ts::new(1, Gid(0)));
        tr.on_deliver(20, Pid(0), m, Ts::new(1, Gid(0)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "integrity"), "{vs:?}");
    }

    #[test]
    fn detects_unknown_or_misaddressed_delivery() {
        let mut tr = base_trace();
        let m = MsgId::new(9, 1);
        tr.on_deliver(10, Pid(0), m, Ts::new(1, Gid(0)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "validity"), "{vs:?}");

        let mut tr = base_trace();
        tr.on_multicast(0, m, GidSet::single(Gid(1)));
        tr.on_deliver(10, Pid(0), m, Ts::new(1, Gid(1)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "validity"), "{vs:?}");
    }

    #[test]
    fn detects_order_inversion_and_gap() {
        let mut tr = base_trace();
        let m1 = MsgId::new(9, 1);
        let m2 = MsgId::new(9, 2);
        let g0 = GidSet::single(Gid(0));
        tr.on_multicast(0, m1, g0);
        tr.on_multicast(0, m2, g0);
        // p0 delivers both out of order
        tr.on_deliver(10, Pid(0), m2, Ts::new(2, Gid(0)));
        tr.on_deliver(20, Pid(0), m1, Ts::new(1, Gid(0)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "ordering-monotone"), "{vs:?}");

        // p0 delivers only m2 while m1 (lower gts) was delivered at p1...
        let mut tr = Trace::new(Topology::new(1, 1), true);
        tr.on_multicast(0, m1, g0);
        tr.on_multicast(0, m2, g0);
        tr.on_deliver(10, Pid(1), m1, Ts::new(1, Gid(0)));
        tr.on_deliver(10, Pid(1), m2, Ts::new(2, Gid(0)));
        tr.on_deliver(10, Pid(0), m2, Ts::new(2, Gid(0)));
        let vs = check_safety(&tr);
        assert!(vs.iter().any(|v| v.rule == "ordering-gap"), "{vs:?}");
    }

    #[test]
    fn termination_requires_quorum_in_each_group() {
        let topo = Topology::new(2, 1); // quorum = 2
        let mut tr = Trace::new(topo, true);
        let m = MsgId::new(9, 1);
        tr.on_multicast(0, m, GidSet::from_iter([Gid(0), Gid(1)]));
        tr.on_deliver(10, Pid(0), m, Ts::new(1, Gid(0)));
        tr.on_deliver(10, Pid(1), m, Ts::new(1, Gid(0)));
        // group 1: only one member delivered
        tr.on_deliver(10, Pid(3), m, Ts::new(1, Gid(0)));
        let vs = check_termination(&tr);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, "termination");
    }

    #[test]
    fn crashed_sender_without_delivery_is_exempt() {
        let topo = Topology::new(1, 1);
        let mut tr = Trace::new(topo, true);
        let m = MsgId::new(9, 1);
        tr.on_multicast(0, m, GidSet::single(Gid(0)));
        tr.on_crash(5, Pid(9)); // client 9 crashed
        assert!(check_termination(&tr).is_empty());
    }
}
