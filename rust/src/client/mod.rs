//! Closed-loop multicast clients (the paper's workload: §VI, "client
//! processes ... initiate multicasts of 20-byte messages in a closed
//! loop").
//!
//! Each client keeps one request in flight: it multicasts a message to a
//! random set of `dest_groups` destination groups, waits until it has
//! received a `Delivered` notification from every destination group (the
//! partially-delivered point of §II), then immediately issues the next
//! request. Clients also implement the *message recovery* rule of §IV:
//! they retransmit `MULTICAST(m)` on a timer until the first delivery.

use crate::protocols::{Action, Node, TimerKind};
use crate::types::{Gid, GidSet, MsgId, MsgMeta, Pid, Topology, Wire};
#[cfg(test)]
use crate::types::Ts;
use crate::util::Rng;

/// Client configuration.
#[derive(Clone, Debug)]
pub struct ClientCfg {
    /// number of destination groups per multicast
    pub dest_groups: usize,
    /// payload size (paper: 20 bytes)
    pub payload: usize,
    /// stop after this many completed requests (None: run until the
    /// simulation horizon)
    pub max_requests: Option<u32>,
    /// retransmission interval for message recovery (0 disables)
    pub resend_after: u64,
    /// optional think time between requests (0 = pure closed loop)
    pub think_ns: u64,
}

impl Default for ClientCfg {
    fn default() -> Self {
        ClientCfg { dest_groups: 1, payload: 20, max_requests: None, resend_after: 0, think_ns: 0 }
    }
}

struct Pending {
    id: MsgId,
    dest: GidSet,
    acked: GidSet,
    sent_at: u64,
}

/// Latency sample recorded by a client: (request id, multicast time,
/// completion time).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub id: MsgId,
    pub sent_at: u64,
    pub done_at: u64,
}

/// A closed-loop client node.
pub struct Client {
    pid: Pid,
    topo: Topology,
    cfg: ClientCfg,
    rng: Rng,
    /// current leader guess per group (updated from Delivered senders)
    cur_leader: Vec<Pid>,
    seq: u32,
    pending: Option<Pending>,
    pub completed: Vec<Sample>,
}

impl Client {
    pub fn new(pid: Pid, topo: Topology, cfg: ClientCfg, seed: u64) -> Self {
        assert!(cfg.dest_groups >= 1 && cfg.dest_groups <= topo.num_groups());
        let cur_leader = topo.gids().map(|g| topo.initial_leader(g)).collect();
        Client { pid, topo, cfg, rng: Rng::new(seed), cur_leader, seq: 0, pending: None, completed: Vec::new() }
    }

    fn next_request(&mut self, now: u64) -> Vec<Action> {
        if let Some(max) = self.cfg.max_requests {
            if self.seq >= max {
                return vec![];
            }
        }
        self.seq += 1;
        let id = MsgId::new(self.pid.0, self.seq);
        let gidxs = self.rng.sample_indices(self.topo.num_groups(), self.cfg.dest_groups);
        let dest = GidSet::from_iter(gidxs.into_iter().map(|i| Gid(i as u32)));
        let meta = MsgMeta::new(id, dest, vec![0u8; self.cfg.payload]);
        self.pending = Some(Pending { id, dest, acked: GidSet::EMPTY, sent_at: now });
        let mut acts = self.multicast_to_leaders(&meta);
        if self.cfg.resend_after > 0 {
            acts.push(Action::Timer(TimerKind::ClientResend(id), self.cfg.resend_after));
        }
        acts
    }

    fn multicast_to_leaders(&self, meta: &MsgMeta) -> Vec<Action> {
        meta.dest
            .iter()
            .map(|g| Action::Send(self.cur_leader[g.0 as usize], Wire::Multicast { meta: meta.clone() }))
            .collect()
    }
}

impl Node for Client {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, now: u64) -> Vec<Action> {
        self.next_request(now)
    }

    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64) -> Vec<Action> {
        let Wire::Delivered { m, g, gts: _ } = wire else { return vec![] };
        // the sender delivered in g — use it as the leader guess for g
        if (g.0 as usize) < self.cur_leader.len() && self.topo.is_member(from, g) {
            self.cur_leader[g.0 as usize] = from;
        }
        let Some(p) = &mut self.pending else { return vec![] };
        if p.id != m || !p.dest.contains(g) {
            return vec![]; // stale or duplicate notification
        }
        p.acked.insert(g);
        if p.acked != p.dest {
            return vec![];
        }
        let sample = Sample { id: p.id, sent_at: p.sent_at, done_at: now };
        self.completed.push(sample);
        self.pending = None;
        if self.cfg.think_ns > 0 {
            vec![Action::Timer(TimerKind::ClientNext, self.cfg.think_ns)]
        } else {
            self.next_request(now)
        }
    }

    fn on_timer(&mut self, timer: TimerKind, now: u64) -> Vec<Action> {
        match timer {
            TimerKind::ClientNext => self.next_request(now),
            TimerKind::ClientResend(m) => {
                let Some(p) = &self.pending else { return vec![] };
                if p.id != m {
                    return vec![]; // request already completed
                }
                // message recovery (§IV): retransmit to current leader
                // guesses, and also to all members of not-yet-acked groups
                // in case our leader guess is stale.
                let meta = MsgMeta::new(p.id, p.dest, vec![0u8; self.cfg.payload]);
                let mut acts = self.multicast_to_leaders(&meta);
                for g in p.dest.iter() {
                    if !p.acked.contains(g) {
                        for &mem in self.topo.members(g) {
                            if mem != self.cur_leader[g.0 as usize] {
                                acts.push(Action::Send(mem, Wire::Multicast { meta: meta.clone() }));
                            }
                        }
                    }
                }
                acts.push(Action::Timer(TimerKind::ClientResend(m), self.cfg.resend_after));
                acts
            }
            _ => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Client {
        let topo = Topology::new(4, 1);
        Client::new(Pid(100), topo, ClientCfg { dest_groups: 2, resend_after: 1000, ..Default::default() }, 7)
    }

    #[test]
    fn first_request_targets_initial_leaders() {
        let mut c = mk();
        let acts = c.on_start(0);
        let sends: Vec<_> = acts.iter().filter(|a| matches!(a, Action::Send(..))).collect();
        assert_eq!(sends.len(), 2);
        for a in &acts {
            if let Action::Send(to, Wire::Multicast { meta }) = a {
                assert_eq!(meta.id, MsgId::new(100, 1));
                assert_eq!(meta.dest.len(), 2);
                assert_eq!(meta.payload.len(), 20);
                // initial leaders are the first member of each group
                assert_eq!(to.0 % 3, 0);
            }
        }
    }

    #[test]
    fn completes_only_after_all_groups_ack() {
        let mut c = mk();
        let acts = c.on_start(0);
        let dest: Vec<Gid> = match &acts[0] {
            Action::Send(_, Wire::Multicast { meta }) => meta.dest.iter().collect(),
            _ => panic!(),
        };
        let m = MsgId::new(100, 1);
        let leader0 = c.topo.initial_leader(dest[0]);
        let out = c.on_wire(leader0, Wire::Delivered { m, g: dest[0], gts: Ts::new(1, dest[0]) }, 50);
        assert!(out.is_empty());
        assert!(c.completed.is_empty());
        let leader1 = c.topo.initial_leader(dest[1]);
        let out = c.on_wire(leader1, Wire::Delivered { m, g: dest[1], gts: Ts::new(1, dest[0]) }, 80);
        assert_eq!(c.completed.len(), 1);
        assert_eq!(c.completed[0].done_at, 80);
        // closed loop: next request fired immediately
        assert!(out.iter().any(|a| matches!(a, Action::Send(_, Wire::Multicast { .. }))));
    }

    #[test]
    fn duplicate_and_stale_notifications_ignored() {
        let mut c = mk();
        let acts = c.on_start(0);
        let dest: Vec<Gid> = match &acts[0] {
            Action::Send(_, Wire::Multicast { meta }) => meta.dest.iter().collect(),
            _ => panic!(),
        };
        let m = MsgId::new(100, 1);
        let l0 = c.topo.initial_leader(dest[0]);
        c.on_wire(l0, Wire::Delivered { m, g: dest[0], gts: Ts::BOT }, 10);
        c.on_wire(l0, Wire::Delivered { m, g: dest[0], gts: Ts::BOT }, 11);
        assert!(c.completed.is_empty());
        // notification for a different message id
        c.on_wire(l0, Wire::Delivered { m: MsgId::new(100, 99), g: dest[1], gts: Ts::BOT }, 12);
        assert!(c.completed.is_empty());
    }

    #[test]
    fn resend_timer_retransmits_to_unacked_group_members() {
        let mut c = mk();
        let acts = c.on_start(0);
        let dest: Vec<Gid> = match &acts[0] {
            Action::Send(_, Wire::Multicast { meta }) => meta.dest.iter().collect(),
            _ => panic!(),
        };
        let m = MsgId::new(100, 1);
        let l0 = c.topo.initial_leader(dest[0]);
        c.on_wire(l0, Wire::Delivered { m, g: dest[0], gts: Ts::BOT }, 10);
        let acts = c.on_timer(TimerKind::ClientResend(m), 1000);
        // resends to 2 leader guesses + the 2 non-leader members of the
        // unacked group, + re-arms the timer
        let sends = acts.iter().filter(|a| matches!(a, Action::Send(..))).count();
        assert_eq!(sends, 4);
        assert!(acts.iter().any(|a| matches!(a, Action::Timer(TimerKind::ClientResend(_), _))));
    }

    #[test]
    fn max_requests_stops_the_loop() {
        let topo = Topology::new(1, 0);
        let mut c =
            Client::new(Pid(10), topo.clone(), ClientCfg { dest_groups: 1, max_requests: Some(1), ..Default::default() }, 1);
        c.on_start(0);
        let out = c.on_wire(Pid(0), Wire::Delivered { m: MsgId::new(10, 1), g: Gid(0), gts: Ts::BOT }, 5);
        assert!(out.is_empty());
        assert_eq!(c.completed.len(), 1);
    }

    #[test]
    fn leader_cache_updates_from_notification_sender() {
        let mut c = mk();
        c.on_start(0);
        // a different member of group 0 replies -> becomes the leader guess
        c.on_wire(Pid(2), Wire::Delivered { m: MsgId::new(100, 999), g: Gid(0), gts: Ts::BOT }, 5);
        assert_eq!(c.cur_leader[0], Pid(2));
        // a non-member cannot claim leadership of group 0
        c.on_wire(Pid(5), Wire::Delivered { m: MsgId::new(100, 999), g: Gid(0), gts: Ts::BOT }, 6);
        assert_eq!(c.cur_leader[0], Pid(2));
    }
}
