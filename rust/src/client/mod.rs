//! Closed-loop multicast clients (the paper's workload: §VI, "client
//! processes ... initiate multicasts of 20-byte messages in a closed
//! loop").
//!
//! Each client keeps one request in flight: it multicasts a message to a
//! random set of `dest_groups` destination groups, waits until it has
//! received a `Delivered` notification from every destination group (the
//! partially-delivered point of §II), then immediately issues the next
//! request. Clients also implement the *message recovery* rule of §IV:
//! they retransmit `MULTICAST(m)` on a timer until the first delivery.

use crate::protocols::{Node, Outbox, TimerKind};
use crate::types::{Gid, GidSet, MsgId, MsgMeta, Pid, Topology, Wire};
#[cfg(test)]
use crate::types::Ts;
use crate::util::Rng;

/// Client configuration.
#[derive(Clone, Debug)]
pub struct ClientCfg {
    /// number of destination groups per multicast
    pub dest_groups: usize,
    /// payload size (paper: 20 bytes)
    pub payload: usize,
    /// stop after this many completed requests (None: run until the
    /// simulation horizon)
    pub max_requests: Option<u32>,
    /// retransmission interval for message recovery (0 disables)
    pub resend_after: u64,
    /// optional think time between requests (0 = pure closed loop)
    pub think_ns: u64,
    /// stamp each multicast with the client's wall clock
    /// ([`crate::types::MsgMeta::submit_ns`]) so delivering nodes can
    /// export end-to-end latency through `/metrics`. Off by default:
    /// the simulator must stay deterministic, and unstamped messages
    /// are skipped by the exporter's latency histograms.
    pub stamp: bool,
}

impl Default for ClientCfg {
    fn default() -> Self {
        ClientCfg { dest_groups: 1, payload: 20, max_requests: None, resend_after: 0, think_ns: 0, stamp: false }
    }
}

struct Pending {
    id: MsgId,
    dest: GidSet,
    acked: GidSet,
    sent_at: u64,
    /// wall-clock stamp of the original submit (0 when unstamped);
    /// resends reuse it so the end-to-end measurement spans from the
    /// *first* attempt
    submit_ns: u64,
}

/// Latency sample recorded by a client: (request id, multicast time,
/// completion time).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub id: MsgId,
    pub sent_at: u64,
    pub done_at: u64,
}

/// A closed-loop client node.
pub struct Client {
    pid: Pid,
    topo: Topology,
    cfg: ClientCfg,
    rng: Rng,
    /// current leader guess per group (updated from Delivered senders)
    cur_leader: Vec<Pid>,
    seq: u32,
    pending: Option<Pending>,
    pub completed: Vec<Sample>,
}

impl Client {
    pub fn new(pid: Pid, topo: Topology, cfg: ClientCfg, seed: u64) -> Self {
        assert!(cfg.dest_groups >= 1 && cfg.dest_groups <= topo.num_groups());
        let cur_leader = topo.gids().map(|g| topo.initial_leader(g)).collect();
        Client { pid, topo, cfg, rng: Rng::new(seed), cur_leader, seq: 0, pending: None, completed: Vec::new() }
    }

    fn next_request(&mut self, now: u64, out: &mut Outbox) {
        if let Some(max) = self.cfg.max_requests {
            if self.seq >= max {
                return;
            }
        }
        self.seq += 1;
        let id = MsgId::new(self.pid.0, self.seq);
        let gidxs = self.rng.sample_indices(self.topo.num_groups(), self.cfg.dest_groups);
        let dest = GidSet::from_iter(gidxs.into_iter().map(|i| Gid(i as u32)));
        let mut meta = MsgMeta::new(id, dest, vec![0u8; self.cfg.payload]);
        let submit_ns = if self.cfg.stamp { crate::obs::wallclock_ns() } else { 0 };
        meta.submit_ns = submit_ns;
        self.pending = Some(Pending { id, dest, acked: GidSet::EMPTY, sent_at: now, submit_ns });
        self.multicast_to_leaders(&meta, out);
        if self.cfg.resend_after > 0 {
            out.timer(TimerKind::ClientResend(id), self.cfg.resend_after);
        }
    }

    fn multicast_to_leaders(&self, meta: &MsgMeta, out: &mut Outbox) {
        for g in meta.dest.iter() {
            out.stage(self.cur_leader[g.0 as usize]);
        }
        out.send_staged(Wire::Multicast { meta: meta.clone() });
    }
}

impl Node for Client {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn on_start(&mut self, now: u64, out: &mut Outbox) {
        self.next_request(now, out);
    }

    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
        let Wire::Delivered { m, g, gts: _ } = wire else { return };
        // the sender delivered in g — use it as the leader guess for g
        if (g.0 as usize) < self.cur_leader.len() && self.topo.is_member(from, g) {
            self.cur_leader[g.0 as usize] = from;
        }
        let Some(p) = &mut self.pending else { return };
        if p.id != m || !p.dest.contains(g) {
            return; // stale or duplicate notification
        }
        p.acked.insert(g);
        if p.acked != p.dest {
            return;
        }
        let sample = Sample { id: p.id, sent_at: p.sent_at, done_at: now };
        self.completed.push(sample);
        self.pending = None;
        if self.cfg.think_ns > 0 {
            out.timer(TimerKind::ClientNext, self.cfg.think_ns);
        } else {
            self.next_request(now, out);
        }
    }

    fn on_timer(&mut self, timer: TimerKind, now: u64, out: &mut Outbox) {
        match timer {
            TimerKind::ClientNext => self.next_request(now, out),
            TimerKind::ClientResend(m) => {
                let Some(p) = &self.pending else { return };
                if p.id != m {
                    return; // request already completed
                }
                // message recovery (§IV): retransmit to current leader
                // guesses, and also to all members of not-yet-acked groups
                // in case our leader guess is stale.
                let mut meta = MsgMeta::new(p.id, p.dest, vec![0u8; self.cfg.payload]);
                meta.submit_ns = p.submit_ns; // original stamp, not re-stamped
                let (dest, acked) = (p.dest, p.acked);
                self.multicast_to_leaders(&meta, out);
                for g in dest.iter() {
                    if !acked.contains(g) {
                        for &mem in self.topo.members(g) {
                            if mem != self.cur_leader[g.0 as usize] {
                                out.send(mem, Wire::Multicast { meta: meta.clone() });
                            }
                        }
                    }
                }
                out.timer(TimerKind::ClientResend(m), self.cfg.resend_after);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Client {
        let topo = Topology::new(4, 1);
        Client::new(Pid(100), topo, ClientCfg { dest_groups: 2, resend_after: 1000, ..Default::default() }, 7)
    }

    fn start(c: &mut Client) -> Outbox {
        let mut out = Outbox::new();
        c.on_start(0, &mut out);
        out
    }

    fn delivered(c: &mut Client, from: Pid, m: MsgId, g: Gid, gts: Ts, now: u64) -> Outbox {
        let mut out = Outbox::new();
        c.on_wire(from, Wire::Delivered { m, g, gts }, now, &mut out);
        out
    }

    #[test]
    fn first_request_targets_initial_leaders() {
        let mut c = mk();
        let out = start(&mut c);
        assert_eq!(out.sends().len(), 2);
        for (to, w) in out.sends() {
            let Wire::Multicast { meta } = w else { panic!("unexpected {w:?}") };
            assert_eq!(meta.id, MsgId::new(100, 1));
            assert_eq!(meta.dest.len(), 2);
            assert_eq!(meta.payload.len(), 20);
            // initial leaders are the first member of each group
            assert_eq!(to.0 % 3, 0);
        }
        // resend timer armed
        assert!(out.timers().iter().any(|(k, _)| matches!(k, TimerKind::ClientResend(_))));
    }

    #[test]
    fn completes_only_after_all_groups_ack() {
        let mut c = mk();
        let out = start(&mut c);
        let dest: Vec<Gid> = match &out.sends()[0] {
            (_, Wire::Multicast { meta }) => meta.dest.iter().collect(),
            _ => panic!(),
        };
        let m = MsgId::new(100, 1);
        let leader0 = c.topo.initial_leader(dest[0]);
        let out = delivered(&mut c, leader0, m, dest[0], Ts::new(1, dest[0]), 50);
        assert!(out.is_empty());
        assert!(c.completed.is_empty());
        let leader1 = c.topo.initial_leader(dest[1]);
        let out = delivered(&mut c, leader1, m, dest[1], Ts::new(1, dest[0]), 80);
        assert_eq!(c.completed.len(), 1);
        assert_eq!(c.completed[0].done_at, 80);
        // closed loop: next request fired immediately
        assert!(out.sends().iter().any(|(_, w)| matches!(w, Wire::Multicast { .. })));
    }

    #[test]
    fn duplicate_and_stale_notifications_ignored() {
        let mut c = mk();
        let out = start(&mut c);
        let dest: Vec<Gid> = match &out.sends()[0] {
            (_, Wire::Multicast { meta }) => meta.dest.iter().collect(),
            _ => panic!(),
        };
        let m = MsgId::new(100, 1);
        let l0 = c.topo.initial_leader(dest[0]);
        delivered(&mut c, l0, m, dest[0], Ts::BOT, 10);
        delivered(&mut c, l0, m, dest[0], Ts::BOT, 11);
        assert!(c.completed.is_empty());
        // notification for a different message id
        delivered(&mut c, l0, MsgId::new(100, 99), dest[1], Ts::BOT, 12);
        assert!(c.completed.is_empty());
    }

    #[test]
    fn resend_timer_retransmits_to_unacked_group_members() {
        let mut c = mk();
        let out = start(&mut c);
        let dest: Vec<Gid> = match &out.sends()[0] {
            (_, Wire::Multicast { meta }) => meta.dest.iter().collect(),
            _ => panic!(),
        };
        let m = MsgId::new(100, 1);
        let l0 = c.topo.initial_leader(dest[0]);
        delivered(&mut c, l0, m, dest[0], Ts::BOT, 10);
        let mut out = Outbox::new();
        c.on_timer(TimerKind::ClientResend(m), 1000, &mut out);
        // resends to 2 leader guesses + the 2 non-leader members of the
        // unacked group, + re-arms the timer
        assert_eq!(out.sends().len(), 4);
        assert!(out.timers().iter().any(|(k, _)| matches!(k, TimerKind::ClientResend(_))));
    }

    #[test]
    fn max_requests_stops_the_loop() {
        let topo = Topology::new(1, 0);
        let mut c =
            Client::new(Pid(10), topo.clone(), ClientCfg { dest_groups: 1, max_requests: Some(1), ..Default::default() }, 1);
        start(&mut c);
        let out = delivered(&mut c, Pid(0), MsgId::new(10, 1), Gid(0), Ts::BOT, 5);
        assert!(out.is_empty());
        assert_eq!(c.completed.len(), 1);
    }

    #[test]
    fn leader_cache_updates_from_notification_sender() {
        let mut c = mk();
        start(&mut c);
        // a different member of group 0 replies -> becomes the leader guess
        delivered(&mut c, Pid(2), MsgId::new(100, 999), Gid(0), Ts::BOT, 5);
        assert_eq!(c.cur_leader[0], Pid(2));
        // a non-member cannot claim leadership of group 0
        delivered(&mut c, Pid(5), MsgId::new(100, 999), Gid(0), Ts::BOT, 6);
        assert_eq!(c.cur_leader[0], Pid(2));
    }
}
