//! # wbam — White-Box Atomic Multicast
//!
//! A from-scratch reproduction of *"White-Box Atomic Multicast (Extended
//! Version)"* (Gotsman, Lefort, Chockler; 2019): a genuine atomic multicast
//! protocol with collision-free latency 3δ and failure-free latency 5δ,
//! obtained by weaving Skeen's timestamp protocol across groups together
//! with a Paxos-style quorum replication within each group.
//!
//! The repo-level `ARCHITECTURE.md` is the map of this crate: the layer
//! stack (types/codec → net → coordinator → protocols → storage →
//! sim/harness), a message-lifecycle walkthrough cross-referenced to
//! the paper's message-delay counts, and the runtime shapes. Perf
//! methodology and history live in `EXPERIMENTS.md`.
//!
//! The crate contains:
//!
//! * [`protocols`] — event-driven state machines for the paper's protocol
//!   (`wbcast`) and all baselines it is evaluated against: unreplicated
//!   Skeen (`skeen`), fault-tolerant Skeen over black-box Paxos
//!   (`ftskeen`), and FastCast (`fastcast`). Every node writes its
//!   effects into a runtime-owned, reusable
//!   [`Outbox`](protocols::Outbox) — the hot path does zero per-event
//!   effect allocations — and the runtimes coalesce same-destination
//!   sends into [`Wire::Batch`](types::Wire::Batch) frames
//!   ([`protocols::LinkCoalescer`]): one frame per destination per
//!   flush cycle by default, or an adaptive delay/byte window
//!   ([`types::FlushPolicy`]), amortising per-message receive, encode
//!   and syscall costs.
//!   The commit-side companion knob is
//!   [`WbConfig::batch_threshold`](protocols::wbcast::WbConfig).
//! * [`sim`] — a deterministic discrete-event simulator (virtual time,
//!   configurable delay models, crash/partition injection) used to
//!   regenerate every figure of the paper's evaluation and to validate the
//!   latency theorems of §V. Batch frames arrive as one event with one
//!   frame-level CPU charge ([`sim::SimConfig::coalesce`]).
//! * [`net`] + [`coordinator`] — real transports (in-process mesh,
//!   thread-per-connection TCP, a Linux epoll event-loop transport
//!   that serves every connection from one thread per endpoint, and a
//!   Linux io_uring completion-loop transport — multishot accept/recv,
//!   registered buffer rings, `SEND_ZC` for large frames — that batches
//!   all of an endpoint's IO through one `io_uring_enter` loop) and
//!   the runtimes that drive the same state machines on actual threads.
//!   A 1-node endpoint (every client, unsharded `serve`) runs an
//!   **inline fast path** — dispatch, timers and flush on the receive
//!   thread, no worker/flusher threads or channel hops. An endpoint
//!   hosting `S > 1` protocol shards ([`types::ShardMap`]; one
//!   [`ShardedRuntime`](coordinator::ShardedRuntime) worker thread per
//!   shard, clients partitioned by client id) demuxes incoming frames
//!   by destination pid and routes same-endpoint sends in-process; each
//!   shard drains its whole backlog per wake-up (bounded by inner
//!   wires, not frames), and a shared flusher folds all shards' sends
//!   into coalesced per-link frames. Both paths (and the sim) flush
//!   through the same [`protocols::LinkCoalescer`] under a configurable
//!   [`types::FlushPolicy`] — immediate per-cycle frames by default, or
//!   an adaptive delay/byte window. TCP encodes each frame once into a
//!   reused buffer, writes it with a single length-prefixed write,
//!   repairs dead connections with a reconnect-and-retry before
//!   (visibly) dropping a frame, and counts drops, dead-link verdicts
//!   and reconnects in [`net::NetStats`]. Received bursts decode
//!   zero-copy: the reassembler freezes each burst into one shared
//!   buffer and payloads become refcounted [`types::Payload`] views
//!   into it instead of per-message copies. The CLI picks the socket
//!   transport per endpoint (`--transport tcp|epoll|uring`; `uring`
//!   probes kernel support and falls back to epoll with a counted
//!   notice).
//! * [`runtime`] — the XLA/PJRT batch commit engine: loads the
//!   AOT-compiled JAX/Pallas `commit_batch` computation (global-timestamp
//!   resolution + delivery-frontier check) and executes it from the leader
//!   hot path; a bit-exact native fallback lives alongside it (and stands
//!   in entirely when built without the optional `xla` feature).
//! * [`storage`] — the durable per-node storage subsystem: a segmented,
//!   CRC-checksummed write-ahead log with a group-commit fsync policy
//!   ([`storage::SyncPolicy`]), compacted snapshots and torn-tail
//!   truncation on open. Behind `WbConfig::durability` a `WbNode`
//!   journals its ballot promises, acknowledged accepts, commits and
//!   deliveries *before* they are externally acknowledged; a killed
//!   process restores from log + snapshot
//!   (`WbNode::restore`) and rejoins its group through the existing
//!   recovery path. Wired through the coordinator (one log per hosted
//!   shard, `--data-dir`/`--sync` on `serve`) and the simulator
//!   ([`storage::MemWal`] + the `Restart` event), so crash-restart
//!   schedules run under the same invariant checks.
//! * [`paxos`], [`lss`] — substrates: multi-Paxos (for the black-box
//!   baselines) and an Ω-style leader selection service.
//! * [`client`], [`stats`], [`harness`] — closed-loop workload generator,
//!   metrics, and the experiment drivers behind `cargo bench`.
//! * [`invariants`] — a runtime checker for the paper's correctness
//!   properties (Validity, Integrity, Ordering) and key Invariants 1–5,
//!   wired into the randomized tests.
//! * [`sync`] — the concurrency facade every runtime module imports
//!   instead of `std::sync`/`std::thread`. A normal build re-exports
//!   `std`; under `--cfg loom` the same names resolve to an in-tree
//!   CHESS-style model checker ([`sync::model`]) and the `loom_` tests
//!   drive the flusher-shutdown, storage-poison and stats-accounting
//!   races through every bounded interleaving. The repo-invariant gate
//!   (`cargo xtask lint`) keeps migrated modules on the facade; see
//!   ARCHITECTURE.md §Correctness tooling.

// Library code reports through `log` / returned stats, never the process
// streams (which belong to the binaries). The two audited exceptions
// carry `#[allow]`s at the site: `WbNode::debug_dump` (a diagnostic
// printer by contract) and the simulator's opt-in WBAM_SIM_LOG trace.
// CI's `-D warnings` promotes these to errors.
#![cfg_attr(not(test), warn(clippy::print_stdout, clippy::print_stderr))]

pub mod client;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod invariants;
pub mod lss;
pub mod net;
pub mod obs;
pub mod paxos;
pub mod protocols;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod storage;
pub mod sync;
pub mod types;
pub mod util;

pub use types::{Ballot, Gid, GidSet, MsgId, Pid, ShardMap, Topology, Ts};
