//! Multi-Paxos substrate used as the *black-box consensus* by the
//! FT-Skeen and FastCast baselines (§IV "a straightforward way ... is to
//! use state-machine replication ... based on a consensus protocol such
//! as Paxos").
//!
//! Scope: the steady-state phase-2 path with a stable, deployment-time
//! leader (ballot `(1, leader(g))`) — exactly what the paper's baseline
//! evaluation exercises (the recovery experiment, Fig. 11, concerns only
//! the white-box protocol; see EXPERIMENTS.md §Substitutions). Commands are
//! decided by a quorum of `P2b`s at the leader and disseminated to
//! followers with `Learn`; every replica applies the log in slot order.

use crate::protocols::Outbox;
use crate::types::wire::{PaxosMsg, RsmCmd};
use crate::types::{Ballot, Gid, Pid, Topology, Wire};
use std::collections::{BTreeMap, HashMap, HashSet};

/// Per-group multi-Paxos instance embedded in a baseline protocol node.
pub struct Paxos {
    pid: Pid,
    gid: Gid,
    members: Vec<Pid>,
    quorum: usize,
    bal: Ballot,
    is_leader: bool,
    /// acceptor state: accepted (ballot, cmd) per slot
    accepted: BTreeMap<u64, (Ballot, RsmCmd)>,
    /// leader: next slot to assign
    next_slot: u64,
    /// leader: P2b tallies
    acks: HashMap<u64, HashSet<Pid>>,
    /// decided commands
    chosen: BTreeMap<u64, RsmCmd>,
    /// next slot to hand to the application (apply cursor)
    apply_at: u64,
    /// count of decided-but-unapplied gaps is implicit in `chosen`
    pub stats_proposed: u64,
}

impl Paxos {
    pub fn new(pid: Pid, topo: &Topology, gid: Gid) -> Self {
        let members = topo.members(gid).to_vec();
        let leader = topo.initial_leader(gid);
        Paxos {
            pid,
            gid,
            quorum: topo.quorum(),
            members,
            bal: Ballot::new(1, leader),
            is_leader: pid == leader,
            accepted: BTreeMap::new(),
            next_slot: 0,
            acks: HashMap::new(),
            chosen: BTreeMap::new(),
            apply_at: 0,
            stats_proposed: 0,
        }
    }

    pub fn is_leader(&self) -> bool {
        self.is_leader
    }
    pub fn ballot(&self) -> Ballot {
        self.bal
    }

    /// Leader: replicate `cmd` in the next log slot. The leader accepts
    /// its own proposal locally (no self-message).
    pub fn propose(&mut self, cmd: RsmCmd, out: &mut Outbox) {
        assert!(self.is_leader, "only the leader proposes");
        let slot = self.next_slot;
        self.next_slot += 1;
        self.stats_proposed += 1;
        self.accepted.insert(slot, (self.bal, cmd.clone()));
        self.acks.entry(slot).or_default().insert(self.pid);
        let msg = Wire::Paxos { g: self.gid, msg: PaxosMsg::P2a { bal: self.bal, slot, cmd } };
        let me = self.pid;
        out.send_to_many(self.members.iter().copied().filter(|&p| p != me), msg);
    }

    /// Handle a Paxos message; newly applicable commands (in slot order)
    /// are appended to `decided`.
    pub fn on_msg(&mut self, from: Pid, msg: PaxosMsg, out: &mut Outbox, decided: &mut Vec<RsmCmd>) {
        match msg {
            PaxosMsg::P2a { bal, slot, cmd } => {
                if bal < self.bal {
                    return; // stale proposer
                }
                self.bal = bal;
                self.accepted.insert(slot, (bal, cmd));
                // durability-ok: the black-box baselines are deliberately
                // in-memory (crash-stop, no restart path) — this P2b vote is
                // never journaled, unlike wbcast's woven AcceptAck promise
                out.send(from, Wire::Paxos { g: self.gid, msg: PaxosMsg::P2b { bal, slot } });
            }
            PaxosMsg::P2b { bal, slot } => {
                if !self.is_leader || bal != self.bal || self.chosen.contains_key(&slot) {
                    return;
                }
                let tally = self.acks.entry(slot).or_default();
                tally.insert(from);
                if tally.len() >= self.quorum {
                    self.acks.remove(&slot);
                    let cmd = self.accepted.get(&slot).expect("leader accepted own P2a").1.clone();
                    self.chosen.insert(slot, cmd.clone());
                    let learn = Wire::Paxos { g: self.gid, msg: PaxosMsg::Learn { slot, cmd } };
                    let me = self.pid;
                    out.send_to_many(self.members.iter().copied().filter(|&p| p != me), learn);
                    self.drain(decided);
                }
            }
            PaxosMsg::Learn { slot, cmd } => {
                if self.is_leader {
                    return; // leader already chose
                }
                self.chosen.insert(slot, cmd);
                self.drain(decided);
            }
            // phase-1 messages are out of scope for the baselines (stable
            // pre-agreed leader); see the module docs
            PaxosMsg::P1a { .. } | PaxosMsg::P1b { .. } => {}
        }
    }

    /// Pop decided commands in contiguous slot order.
    fn drain(&mut self, out: &mut Vec<RsmCmd>) {
        while let Some(cmd) = self.chosen.get(&self.apply_at) {
            out.push(cmd.clone());
            self.apply_at += 1;
        }
    }

    /// Decided-but-not-yet-applicable commands (waiting for a log gap).
    pub fn backlog(&self) -> usize {
        self.chosen.len() - self.chosen.keys().take_while(|&&s| s < self.apply_at).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{GidSet, MsgId, MsgMeta, Ts};

    fn cmd(n: u32) -> RsmCmd {
        RsmCmd::Commit { m: MsgId::new(1, n), gts: Ts::new(n as u64, Gid(0)) }
    }

    fn pump(nodes: &mut [Paxos], out: &mut Outbox, decided: &mut [Vec<RsmCmd>]) {
        // tiny synchronous network: deliver sends until quiescent (the
        // initial outbox was produced by the leader, node 0)
        let mut queue: Vec<(Pid, Pid, Wire)> = Vec::new();
        for (to, w) in out.sends() {
            queue.push((Pid(0), *to, w.clone()));
        }
        out.clear();
        while let Some((from, to, w)) = queue.pop() {
            let Wire::Paxos { msg, .. } = w else { continue };
            let idx = to.0 as usize;
            let mut step = Outbox::new();
            let mut d = Vec::new();
            nodes[idx].on_msg(from, msg, &mut step, &mut d);
            decided[idx].extend(d);
            for (to2, w2) in step.sends() {
                queue.push((to, *to2, w2.clone()));
            }
        }
    }

    #[test]
    fn commands_decided_in_slot_order_at_all_replicas() {
        let topo = Topology::new(1, 1);
        let mut nodes: Vec<Paxos> = (0..3).map(|i| Paxos::new(Pid(i), &topo, Gid(0))).collect();
        let mut decided: Vec<Vec<RsmCmd>> = vec![vec![], vec![], vec![]];
        for n in 0..5 {
            let mut out = Outbox::new();
            nodes[0].propose(cmd(n), &mut out);
            pump(&mut nodes, &mut out, &mut decided);
        }
        for o in &decided {
            assert_eq!(o.len(), 5);
            for (i, c) in o.iter().enumerate() {
                assert_eq!(*c, cmd(i as u32));
            }
        }
    }

    #[test]
    fn stale_ballot_p2a_rejected() {
        let topo = Topology::new(1, 1);
        let mut n = Paxos::new(Pid(1), &topo, Gid(0));
        let mut out = Outbox::new();
        let mut decided = Vec::new();
        let stale = Ballot::new(0, Pid(0));
        n.on_msg(
            Pid(0),
            PaxosMsg::P2a {
                bal: stale,
                slot: 0,
                cmd: RsmCmd::AssignLts { meta: MsgMeta::new(MsgId::new(1, 1), GidSet::single(Gid(0)), vec![]), lts: Ts::BOT },
            },
            &mut out,
            &mut decided,
        );
        assert!(out.is_empty(), "must not ack a stale ballot");
    }

    #[test]
    fn learn_applies_with_gaps_buffered() {
        let topo = Topology::new(1, 1);
        let mut n = Paxos::new(Pid(1), &topo, Gid(0));
        let mut out = Outbox::new();
        let mut decided = Vec::new();
        n.on_msg(Pid(0), PaxosMsg::Learn { slot: 1, cmd: cmd(1) }, &mut out, &mut decided);
        assert!(decided.is_empty(), "slot 0 missing: nothing applicable");
        assert_eq!(n.backlog(), 1);
        n.on_msg(Pid(0), PaxosMsg::Learn { slot: 0, cmd: cmd(0) }, &mut out, &mut decided);
        assert_eq!(decided, vec![cmd(0), cmd(1)]);
    }

    #[test]
    fn quorum_required_before_choose() {
        let topo = Topology::new(1, 2); // 5 members, quorum 3
        let mut leader = Paxos::new(Pid(0), &topo, Gid(0));
        let mut out = Outbox::new();
        leader.propose(cmd(0), &mut out);
        // leader's own acceptance comes through its self-addressed P2a
        let mut decided = Vec::new();
        leader.on_msg(Pid(0), PaxosMsg::P2a { bal: leader.ballot(), slot: 0, cmd: cmd(0) }, &mut out, &mut decided);
        let b = leader.ballot();
        leader.on_msg(Pid(0), PaxosMsg::P2b { bal: b, slot: 0 }, &mut out, &mut decided);
        leader.on_msg(Pid(1), PaxosMsg::P2b { bal: b, slot: 0 }, &mut out, &mut decided);
        assert!(decided.is_empty(), "2 < quorum of 3");
        leader.on_msg(Pid(2), PaxosMsg::P2b { bal: b, slot: 0 }, &mut out, &mut decided);
        assert_eq!(decided, vec![cmd(0)]);
    }
}
