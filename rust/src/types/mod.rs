//! Core protocol types: identifiers, lexicographic timestamps, ballots,
//! phases, group topology and the wire-message enum shared by all
//! protocol implementations.

pub mod wire;

pub use wire::{DeliveryPath, MsgMeta, PaxosMsg, Payload, Wire};

use std::fmt;

/// Process identifier, unique across the whole deployment (group members
/// and clients alike).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Pid(pub u32);

/// Group identifier.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Gid(pub u32);

/// Application-message identifier: `(client << 32) | sequence`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct MsgId(pub u64);

impl MsgId {
    pub fn new(client: u32, seq: u32) -> Self {
        MsgId(((client as u64) << 32) | seq as u64)
    }
    pub fn client(self) -> u32 {
        (self.0 >> 32) as u32
    }
    pub fn seq(self) -> u32 {
        self.0 as u32
    }
}

impl fmt::Debug for Pid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "p{}", self.0)
    }
}
impl fmt::Debug for Gid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "g{}", self.0)
    }
}
impl fmt::Debug for MsgId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}.{}", self.client(), self.seq())
    }
}
impl fmt::Display for Pid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}
impl fmt::Display for Gid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}
impl fmt::Display for MsgId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// A set of destination groups, encoded as a bitmask (≤ 64 groups, the
/// paper's deployments use 10).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct GidSet(pub u64);

impl GidSet {
    pub const EMPTY: GidSet = GidSet(0);

    pub fn single(g: Gid) -> Self {
        GidSet(1 << g.0)
    }
    pub fn from_iter<I: IntoIterator<Item = Gid>>(it: I) -> Self {
        let mut s = 0u64;
        for g in it {
            assert!(g.0 < 64, "GidSet supports at most 64 groups");
            s |= 1 << g.0;
        }
        GidSet(s)
    }
    pub fn contains(self, g: Gid) -> bool {
        g.0 < 64 && self.0 & (1 << g.0) != 0
    }
    pub fn insert(&mut self, g: Gid) {
        assert!(g.0 < 64);
        self.0 |= 1 << g.0;
    }
    pub fn len(self) -> usize {
        self.0.count_ones() as usize
    }
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }
    pub fn intersects(self, other: GidSet) -> bool {
        self.0 & other.0 != 0
    }
    pub fn iter(self) -> impl Iterator<Item = Gid> {
        let mut bits = self.0;
        std::iter::from_fn(move || {
            if bits == 0 {
                None
            } else {
                let g = bits.trailing_zeros();
                bits &= bits - 1;
                Some(Gid(g))
            }
        })
    }
}

impl fmt::Debug for GidSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, g) in self.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{g:?}")?;
        }
        write!(f, "}}")
    }
}

/// A multicast timestamp `(t, g)`, ordered lexicographically (§III).
/// `Ts::BOT` (`t = 0`) is the minimal timestamp ⊥; real timestamps always
/// have `t ≥ 1` because clocks are incremented before assignment.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Ts {
    pub t: u64,
    pub g: Gid,
}

impl Ts {
    pub const BOT: Ts = Ts { t: 0, g: Gid(0) };

    pub fn new(t: u64, g: Gid) -> Self {
        Ts { t, g }
    }
    pub fn time(self) -> u64 {
        self.t
    }
    pub fn is_bot(self) -> bool {
        self.t == 0
    }

    /// Encode as a single `i64` lane for the XLA batch engine:
    /// `t << 8 | g` preserves the lexicographic order for `g < 256`.
    pub fn encode(self) -> i64 {
        debug_assert!(self.g.0 < 256);
        debug_assert!(self.t < (1 << 55));
        ((self.t << 8) | self.g.0 as u64) as i64
    }
    pub fn decode(enc: i64) -> Ts {
        let enc = enc as u64;
        Ts { t: enc >> 8, g: Gid((enc & 0xFF) as u32) }
    }
}

impl fmt::Debug for Ts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_bot() {
            write!(f, "⊥")
        } else {
            write!(f, "({},{:?})", self.t, self.g)
        }
    }
}

/// A ballot `(n, p)` identifying a leadership period of process `p`
/// within its group, ordered lexicographically. `Ballot::BOT` is ⊥.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Ballot {
    pub n: u32,
    pub p: Pid,
}

impl Ballot {
    pub const BOT: Ballot = Ballot { n: 0, p: Pid(0) };

    pub fn new(n: u32, p: Pid) -> Self {
        Ballot { n, p }
    }
    pub fn leader(self) -> Pid {
        self.p
    }
    pub fn is_bot(self) -> bool {
        self.n == 0
    }
    /// The successor ballot led by `p`.
    pub fn next_for(self, p: Pid) -> Ballot {
        Ballot { n: self.n + 1, p }
    }
}

impl fmt::Debug for Ballot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_bot() {
            write!(f, "⊥b")
        } else {
            write!(f, "b({},{:?})", self.n, self.p)
        }
    }
}

/// Phase of an application message at a process (Fig. 3).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Default)]
pub enum Phase {
    #[default]
    Start,
    Proposed,
    Accepted,
    Committed,
}

/// Process status (Fig. 3).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Status {
    Leader,
    Follower,
    Recovering,
}

/// Static deployment topology: disjoint groups of `2f + 1` processes each.
/// Clients are processes outside all groups.
///
/// A topology may be *based*: its member pids start at `base` instead of
/// 0. Shard topologies (see [`ShardMap`]) are based so that `S`
/// independent protocol instances can coexist in one pid space.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Members of each group; `groups[g][0]` is the initial leader.
    pub groups: Vec<Vec<Pid>>,
    /// Fault threshold per group (`|group| = 2f + 1`).
    pub f: usize,
    /// First member pid (0 for plain topologies; shard `s` of a
    /// [`ShardMap`] starts at `s * members_per_shard`).
    pub base: u32,
}

impl Topology {
    /// Build a topology of `k` groups with `2f + 1` members each.
    /// Pids `0 .. k*(2f+1)` are group members (group-major); clients get
    /// pids from [`Topology::first_client_pid`] upward.
    pub fn new(k: usize, f: usize) -> Self {
        Self::with_base(k, f, 0)
    }

    /// Build a topology whose member pids start at `base` (group-major).
    pub fn with_base(k: usize, f: usize, base: u32) -> Self {
        assert!(k >= 1 && k <= 64);
        let gsize = 2 * f + 1;
        let groups = (0..k)
            .map(|g| (0..gsize).map(|i| Pid(base + (g * gsize + i) as u32)).collect())
            .collect();
        Topology { groups, f, base }
    }

    pub fn group_size(&self) -> usize {
        2 * self.f + 1
    }
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }
    /// Size of a quorum in any group (`f + 1`).
    pub fn quorum(&self) -> usize {
        self.f + 1
    }
    /// Total number of group-member processes.
    pub fn num_members(&self) -> usize {
        self.groups.len() * self.group_size()
    }
    /// First pid usable for clients. For sharded deployments use
    /// [`ShardMap::first_client_pid`], which accounts for every shard.
    pub fn first_client_pid(&self) -> Pid {
        Pid(self.base + self.num_members() as u32)
    }
    /// Group of a member pid, if any.
    pub fn group_of(&self, p: Pid) -> Option<Gid> {
        let n = self.num_members() as u32;
        if p.0 >= self.base && p.0 < self.base + n {
            Some(Gid((p.0 - self.base) / self.group_size() as u32))
        } else {
            None
        }
    }
    pub fn members(&self, g: Gid) -> &[Pid] {
        &self.groups[g.0 as usize]
    }
    /// Initial (ballot-⊥-successor) leader of a group.
    pub fn initial_leader(&self, g: Gid) -> Pid {
        self.groups[g.0 as usize][0]
    }
    pub fn is_member(&self, p: Pid, g: Gid) -> bool {
        self.group_of(p) == Some(g)
    }
    /// All group ids.
    pub fn gids(&self) -> impl Iterator<Item = Gid> + '_ {
        (0..self.groups.len() as u32).map(Gid)
    }
}

/// Shard map: one deployment hosting `shards` independent protocol
/// instances ("shards"), each a full [`Topology`] of `groups` groups with
/// `2f + 1` members. Every *physical endpoint* (machine / transport
/// endpoint) hosts one protocol node per shard — shard `s`'s counterpart
/// of the endpoint's shard-0 pid — so a group leader's work spreads over
/// `shards` cores behind a single endpoint.
///
/// Pid layout: shard `s` owns member pids
/// `[s * members_per_shard, (s + 1) * members_per_shard)`, group-major
/// within the shard. Clients take pids from
/// [`ShardMap::first_client_pid`] upward and are partitioned round-robin
/// over shards ([`ShardMap::client_shard`]). Messages never cross shards:
/// each shard orders its own clients' multicasts independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMap {
    pub groups: usize,
    pub f: usize,
    pub shards: usize,
}

impl ShardMap {
    pub fn new(groups: usize, f: usize, shards: usize) -> Self {
        assert!(shards >= 1, "ShardMap needs at least one shard");
        assert!(groups >= 1 && groups <= 64);
        ShardMap { groups, f, shards }
    }

    /// The single-shard map equivalent to a plain topology. (For a
    /// *based* topology the map's pid arithmetic does not apply; callers
    /// holding one route pid lookups through the topology itself.)
    pub fn solo(topo: &Topology) -> Self {
        ShardMap { groups: topo.num_groups(), f: topo.f, shards: 1 }
    }

    pub fn group_size(&self) -> usize {
        2 * self.f + 1
    }
    /// Member pids per shard (= pid stride between a pid's shard
    /// counterparts).
    pub fn members_per_shard(&self) -> usize {
        self.groups * self.group_size()
    }
    /// Total member pids across all shards.
    pub fn num_members(&self) -> usize {
        self.members_per_shard() * self.shards
    }
    /// First pid usable for clients (above every shard's members).
    pub fn first_client_pid(&self) -> Pid {
        Pid(self.num_members() as u32)
    }

    /// The topology of shard `s` (member pids offset by `s` strides).
    pub fn topo(&self, s: usize) -> Topology {
        assert!(s < self.shards, "shard {s} out of range");
        Topology::with_base(self.groups, self.f, (s * self.members_per_shard()) as u32)
    }

    /// Shard owning member pid `p` (None for clients / out-of-range pids).
    pub fn shard_of(&self, p: Pid) -> Option<usize> {
        if (p.0 as usize) < self.num_members() {
            Some(p.0 as usize / self.members_per_shard())
        } else {
            None
        }
    }

    /// Per-shard (local) group of member pid `p`.
    pub fn local_group_of(&self, p: Pid) -> Option<Gid> {
        self.shard_of(p)
            .map(|_| Gid(((p.0 as usize % self.members_per_shard()) / self.group_size()) as u32))
    }

    /// Shard serving client pid `c` (clients partitioned round-robin).
    pub fn client_shard(&self, c: Pid) -> usize {
        debug_assert!(c.0 as usize >= self.num_members(), "{c:?} is a member pid");
        (c.0 as usize - self.num_members()) % self.shards
    }

    /// The physical endpoint hosting member pid `p`, identified by the
    /// pid's shard-0 counterpart.
    pub fn endpoint_of(&self, p: Pid) -> Option<Pid> {
        self.shard_of(p).map(|s| Pid(p.0 - (s * self.members_per_shard()) as u32))
    }

    /// All member pids hosted by endpoint `e` (a shard-0 member pid):
    /// `e`'s counterpart in every shard, shard-major.
    pub fn hosted_by(&self, e: Pid) -> Vec<Pid> {
        assert!((e.0 as usize) < self.members_per_shard(), "{e:?} is not an endpoint (shard-0) pid");
        (0..self.shards).map(|s| Pid(e.0 + (s * self.members_per_shard()) as u32)).collect()
    }

    /// All physical member endpoints (the shard-0 member pids).
    pub fn endpoints(&self) -> impl Iterator<Item = Pid> {
        (0..self.members_per_shard() as u32).map(Pid)
    }
}

/// Adaptive per-link wire-coalescing policy, applied identically by the
/// inline single-shard runtime, the sharded runtime's flusher thread and
/// the simulator (so simulated traces stay predictive of real-transport
/// behaviour). Enforced by
/// [`LinkCoalescer`](crate::protocols::outbox::LinkCoalescer); configured
/// via `RunCfg::flush` / the `--flush-*` CLI flags.
///
/// The default is [`FlushPolicy::immediate`]: one coalesced frame per
/// destination per event-loop cycle, byte-identical to the fixed policy
/// the runtimes used before adaptive coalescing existed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlushPolicy {
    /// Longest a queued wire may wait for companions before its link is
    /// flushed, in microseconds. `0` disables the delay window entirely:
    /// every flush cycle emits everything (the classic
    /// one-frame-per-cycle policy).
    pub max_delay_us: u64,
    /// Flush a link as soon as its pending wires reach this many
    /// estimated encoded bytes. Clamped to
    /// [`MAX_FRAME_BYTES`](crate::protocols::outbox::MAX_FRAME_BYTES) by
    /// the coalescer; frames above that cap are split regardless.
    pub max_bytes: usize,
    /// Flush every pending link whenever the event loop goes quiet (no
    /// further input immediately available), even before `max_delay_us`
    /// expires. Off trades latency for strictly time/size-driven batching.
    pub flush_on_quiet: bool,
}

impl Default for FlushPolicy {
    fn default() -> Self {
        Self::immediate()
    }
}

impl FlushPolicy {
    /// Flush everything at every cycle (the pre-adaptive behaviour).
    pub fn immediate() -> Self {
        FlushPolicy { max_delay_us: 0, max_bytes: usize::MAX, flush_on_quiet: true }
    }

    /// A time-windowed policy: links may coalesce for up to
    /// `max_delay_us`, but still flush early when the loop goes quiet.
    pub fn adaptive(max_delay_us: u64) -> Self {
        FlushPolicy { max_delay_us, max_bytes: usize::MAX, flush_on_quiet: true }
    }

    /// True when the delay window is disabled (every cycle flushes all).
    pub fn is_immediate(&self) -> bool {
        self.max_delay_us == 0
    }

    /// The delay window in the nanosecond clock the runtimes use.
    pub fn max_delay_ns(&self) -> u64 {
        self.max_delay_us.saturating_mul(1000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ts_lex_order() {
        let a = Ts::new(1, Gid(5));
        let b = Ts::new(2, Gid(0));
        let c = Ts::new(2, Gid(1));
        assert!(Ts::BOT < a);
        assert!(a < b);
        assert!(b < c);
    }

    #[test]
    fn ts_encode_roundtrip_and_order() {
        let cases = [
            Ts::BOT,
            Ts::new(1, Gid(0)),
            Ts::new(1, Gid(63)),
            Ts::new(2, Gid(0)),
            Ts::new(1 << 40, Gid(9)),
        ];
        for &a in &cases {
            assert_eq!(Ts::decode(a.encode()), a);
            for &b in &cases {
                assert_eq!(a.cmp(&b), a.encode().cmp(&b.encode()), "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn ballot_order_and_next() {
        let b1 = Ballot::new(1, Pid(3));
        let b2 = Ballot::new(1, Pid(4));
        let b3 = Ballot::new(2, Pid(0));
        assert!(Ballot::BOT < b1);
        assert!(b1 < b2);
        assert!(b2 < b3);
        assert_eq!(b1.next_for(Pid(7)), Ballot::new(2, Pid(7)));
    }

    #[test]
    fn gidset_ops() {
        let s = GidSet::from_iter([Gid(0), Gid(3), Gid(63)]);
        assert_eq!(s.len(), 3);
        assert!(s.contains(Gid(3)));
        assert!(!s.contains(Gid(2)));
        let gids: Vec<Gid> = s.iter().collect();
        assert_eq!(gids, vec![Gid(0), Gid(3), Gid(63)]);
        assert!(s.intersects(GidSet::single(Gid(3))));
        assert!(!s.intersects(GidSet::single(Gid(5))));
    }

    #[test]
    fn msgid_parts() {
        let m = MsgId::new(7, 42);
        assert_eq!(m.client(), 7);
        assert_eq!(m.seq(), 42);
    }

    #[test]
    fn topology_layout() {
        let t = Topology::new(3, 1);
        assert_eq!(t.group_size(), 3);
        assert_eq!(t.quorum(), 2);
        assert_eq!(t.num_members(), 9);
        assert_eq!(t.members(Gid(1)), &[Pid(3), Pid(4), Pid(5)]);
        assert_eq!(t.group_of(Pid(5)), Some(Gid(1)));
        assert_eq!(t.group_of(Pid(9)), None);
        assert_eq!(t.initial_leader(Gid(2)), Pid(6));
        assert_eq!(t.first_client_pid(), Pid(9));
    }

    #[test]
    fn shard_map_layout() {
        let map = ShardMap::new(2, 1, 4); // 2 groups x 3 members x 4 shards
        assert_eq!(map.members_per_shard(), 6);
        assert_eq!(map.num_members(), 24);
        assert_eq!(map.first_client_pid(), Pid(24));

        // shard 2's topology is offset by two strides and self-consistent
        let t2 = map.topo(2);
        assert_eq!(t2.base, 12);
        assert_eq!(t2.members(Gid(1)), &[Pid(15), Pid(16), Pid(17)]);
        assert_eq!(t2.initial_leader(Gid(0)), Pid(12));
        assert_eq!(t2.group_of(Pid(15)), Some(Gid(1)));
        assert_eq!(t2.group_of(Pid(11)), None); // shard 1's pid
        assert_eq!(t2.group_of(Pid(24)), None); // client

        // pid -> (shard, local group, endpoint)
        assert_eq!(map.shard_of(Pid(15)), Some(2));
        assert_eq!(map.local_group_of(Pid(15)), Some(Gid(1)));
        assert_eq!(map.endpoint_of(Pid(15)), Some(Pid(3)));
        assert_eq!(map.shard_of(Pid(24)), None);

        // endpoint 3 hosts its counterpart in every shard
        assert_eq!(map.hosted_by(Pid(3)), vec![Pid(3), Pid(9), Pid(15), Pid(21)]);
        assert_eq!(map.endpoints().count(), 6);

        // clients partition round-robin
        assert_eq!(map.client_shard(Pid(24)), 0);
        assert_eq!(map.client_shard(Pid(27)), 3);
        assert_eq!(map.client_shard(Pid(28)), 0);
    }

    #[test]
    fn phase_ordering_matches_protocol() {
        assert!(Phase::Start < Phase::Proposed);
        assert!(Phase::Proposed < Phase::Accepted);
        assert!(Phase::Accepted < Phase::Committed);
    }
}
