//! Wire-level protocol messages, shared by all protocol implementations
//! (WbCast, Skeen, FT-Skeen, FastCast) and both runtimes (simulator and
//! real transports). Binary serialization lives in [`crate::codec`].

use super::{Ballot, Gid, GidSet, MsgId, Phase, Ts};
use std::sync::Arc;

/// A cheaply-cloneable view of a byte range inside a shared, immutable
/// buffer. This is the zero-copy payload type: the transports freeze each
/// received read burst into one `Arc<[u8]>` and the codec hands out
/// `Payload` windows into it ([`crate::codec::decode_shared`]), so a
/// message's payload bytes are copied **zero** times between the socket
/// read buffer and the protocol layer. Locally constructed payloads
/// (client submit, tests) wrap their own `Vec` with `off == 0`.
///
/// Equality is by the viewed bytes, not by buffer identity — two views of
/// different buffers with equal contents compare equal, which keeps
/// `MsgMeta`/`Wire` equality (and every existing round-trip test) exact.
#[derive(Clone)]
pub struct Payload {
    buf: Arc<[u8]>,
    off: usize,
    len: usize,
}

impl Payload {
    /// View `buf[off..off + len]`. Panics if the range is out of bounds —
    /// callers (the codec) have already bounds-checked the range.
    pub fn view(buf: Arc<[u8]>, off: usize, len: usize) -> Self {
        assert!(off + len <= buf.len(), "payload view out of bounds");
        Payload { buf, off, len }
    }
    /// The viewed bytes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }
    /// True if this view shares its backing buffer with `other` (i.e. the
    /// decode path did **not** copy). Test/bench introspection only.
    pub fn shares_buffer_with(&self, other: &Payload) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }
    /// Bytes held alive by the backing buffer (≥ `len()` for a window
    /// into a multi-message frame). Test/bench introspection only.
    pub fn backing_len(&self) -> usize {
        self.buf.len()
    }
}

impl std::ops::Deref for Payload {
    type Target = [u8];
    #[inline]
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Payload {
    #[inline]
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl Eq for Payload {}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Same rendering as the old `Arc<[u8]>` payload: the byte list.
        std::fmt::Debug::fmt(self.as_slice(), f)
    }
}

impl Default for Payload {
    fn default() -> Self {
        Payload { buf: Arc::from(&[][..]), off: 0, len: 0 }
    }
}

impl From<Vec<u8>> for Payload {
    fn from(v: Vec<u8>) -> Self {
        let len = v.len();
        Payload { buf: v.into(), off: 0, len }
    }
}

impl From<&[u8]> for Payload {
    fn from(v: &[u8]) -> Self {
        Payload { buf: Arc::from(v), off: 0, len: v.len() }
    }
}

/// Which latency path a delivery took — the white-box classification of
/// the paper's headline claim (3δ collision-free vs 5δ under
/// concurrency) that a black-box implementation cannot report.
///
/// Classified by the delivering leader ([`crate::protocols::wbcast`])
/// and propagated to followers inside [`Wire::Deliver`]:
///
/// * [`Fast`](DeliveryPath::Fast) — delivered in the same handler
///   invocation that committed it: the delivery frontier never blocked
///   it, the collision-free 3δ path of Fig. 4.
/// * [`Concurrent`](DeliveryPath::Concurrent) — committed earlier but
///   held back by the delivery frontier (a concurrent multicast with a
///   smaller pending timestamp): the 5δ path.
/// * [`Recovery`](DeliveryPath::Recovery) — delivered via the leader
///   recovery / crash-restore path; its latency says nothing about δ.
/// * [`Unclassified`](DeliveryPath::Unclassified) — protocols that do
///   not classify (the baselines) and legacy effects.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
#[repr(u8)]
pub enum DeliveryPath {
    Fast = 0,
    Concurrent = 1,
    Recovery = 2,
    #[default]
    Unclassified = 3,
}

impl DeliveryPath {
    /// Decode a wire byte; unknown bytes map to `Unclassified` (the
    /// classification is advisory, never worth rejecting a frame over).
    pub fn from_u8(b: u8) -> DeliveryPath {
        match b {
            0 => DeliveryPath::Fast,
            1 => DeliveryPath::Concurrent,
            2 => DeliveryPath::Recovery,
            _ => DeliveryPath::Unclassified,
        }
    }
    /// Stable label used in metric names and dumps.
    pub fn as_str(self) -> &'static str {
        match self {
            DeliveryPath::Fast => "fast",
            DeliveryPath::Concurrent => "concurrent",
            DeliveryPath::Recovery => "recovery",
            DeliveryPath::Unclassified => "unclassified",
        }
    }
}

/// Metadata of an application message: identity, destination groups and
/// payload. The protocols order `MsgMeta`s; the payload is opaque.
/// The payload is reference-counted: protocol fan-out clones a `MsgMeta`
/// up to `3d` times per multicast, and the shared [`Payload`] buffer
/// keeps those clones allocation-free (EXPERIMENTS.md §Perf iteration 2);
/// since the zero-copy decode path it is also copy-free on receive.
#[derive(Clone, PartialEq, Eq, Debug, Default)]
pub struct MsgMeta {
    pub id: MsgId,
    pub dest: GidSet,
    pub payload: Payload,
    /// Client submit wall-clock timestamp (`obs::wallclock_ns`), or 0
    /// when the client does not stamp ([`crate::client::ClientCfg`];
    /// the simulator never stamps — virtual time stays deterministic).
    /// Rides the meta end to end so the *delivering* node can record
    /// true submit → deliver latency without per-message allocation.
    pub submit_ns: u64,
}

impl MsgMeta {
    pub fn new(id: MsgId, dest: GidSet, payload: Vec<u8>) -> Self {
        MsgMeta { id, dest, payload: payload.into(), submit_ns: 0 }
    }
    /// Exact encoded size: id (8) + dest mask (8) + submit stamp (8) +
    /// length-prefixed payload (4 + len). Also the simulator cost
    /// model's byte count.
    pub fn size(&self) -> usize {
        28 + self.payload.len()
    }
}

/// Per-message state snapshot exchanged during WbCast leader recovery
/// (carried by `NEWLEADER_ACK` and `NEW_STATE`).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct MsgState {
    pub meta: MsgMeta,
    pub phase: Phase,
    pub lts: Ts,
    pub gts: Ts,
}

/// Commands replicated through black-box Paxos by the FT-Skeen and
/// FastCast baselines (their group-local state machine).
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum RsmCmd {
    /// Persist the local timestamp chosen for `meta` (Fig. 1 line 10).
    AssignLts { meta: MsgMeta, lts: Ts },
    /// Persist the global timestamp and the clock advance (Fig. 1
    /// lines 14–15).
    Commit { m: MsgId, gts: Ts },
}

/// Black-box Paxos messages (used by the baselines), scoped to one group.
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum PaxosMsg {
    /// Phase-1a: leader candidate solicits votes.
    P1a { bal: Ballot },
    /// Phase-1b: vote + all accepted entries `(slot, bal, cmd)`.
    P1b { bal: Ballot, log: Vec<(u64, Ballot, RsmCmd)> },
    /// Phase-2a: replicate `cmd` at `slot`.
    P2a { bal: Ballot, slot: u64, cmd: RsmCmd },
    /// Phase-2b: acknowledgement.
    P2b { bal: Ballot, slot: u64 },
    /// Learn a chosen command (leader → followers).
    Learn { slot: u64, cmd: RsmCmd },
}

/// All protocol messages. One enum for every protocol keeps the codec,
/// the simulator and the transports uniform; each protocol uses its own
/// subset.
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum Wire {
    // ---------- client <-> protocol ----------
    /// Client submits `meta` for multicast (sent to the leader of every
    /// destination group; Fig. 4 line 1).
    Multicast { meta: MsgMeta },
    /// Delivery notification to the multicasting client (used for the
    /// closed loop and latency accounting; "the first process that
    /// delivers a message can ... reply to the client", §II).
    Delivered { m: MsgId, g: Gid, gts: Ts },

    // ---------- Skeen (Fig. 1) ----------
    /// Local-timestamp proposal of group `g` for `m`.
    Propose { m: MsgId, g: Gid, lts: Ts },

    // ---------- WbCast normal operation (Fig. 4) ----------
    /// Leader of `g` proposes local timestamp `lts` at ballot `bal`
    /// to *all* processes in `dest(m)` ("2a"-like; line 9).
    Accept { meta: MsgMeta, g: Gid, bal: Ballot, lts: Ts },
    /// Destination process in group `g` acknowledges the accepted local
    /// timestamps for `m` under the ballot vector `bals` ("2b"-like;
    /// line 16). `bals` is sorted by `Gid`.
    AcceptAck { m: MsgId, g: Gid, bals: Vec<(Gid, Ballot)> },
    /// Leader replicates the committed (lts, gts) pair and orders
    /// delivery (line 23). `path` carries the leader's white-box
    /// latency-path classification so followers count deliveries under
    /// the same label (see [`DeliveryPath`]).
    Deliver { m: MsgId, bal: Ballot, lts: Ts, gts: Ts, path: DeliveryPath },

    // ---------- WbCast leader recovery (Fig. 4, lines 35-66) ----------
    /// "1a": ask group members to join ballot `bal`.
    NewLeader { bal: Ballot },
    /// "1b": vote + full state snapshot.
    NewLeaderAck { bal: Ballot, cbal: Ballot, clock: u64, state: Vec<MsgState> },
    /// New leader pushes its recovered state to followers.
    NewState { bal: Ballot, clock: u64, state: Vec<MsgState> },
    /// Follower confirms synchronisation with ballot `bal`.
    NewStateAck { bal: Ballot },

    // ---------- FastCast ----------
    /// Leader of `g` confirms that consensus on `m`'s local timestamp in
    /// `g` has decided (the post-consensus exchange of §VI).
    Confirm { m: MsgId, g: Gid },

    // ---------- baselines' black-box consensus ----------
    Paxos { g: Gid, msg: PaxosMsg },

    // ---------- liveness plumbing ----------
    /// Leader heartbeat for the leader-selection service.
    Heartbeat { bal: Ballot },
    /// Follower → leader: highest delivered global timestamp, used to
    /// advance the garbage-collection watermark (§VI: "a mechanism to
    /// garbage collect delivered messages").
    GcReport { max_gts: Ts },

    // ---------- transport framing ----------
    /// Destination-coalesced frame: every protocol message a flush cycle
    /// produced for one destination, in FIFO order. Produced only by the
    /// runtime flush ([`crate::protocols::LinkCoalescer`]) and unpacked
    /// by the receiving runtime — protocol nodes never see one. Never
    /// nested, never empty (the codec rejects both).
    Batch(Vec<Wire>),
}

impl Wire {
    /// Wire size (bytes): exactly what [`crate::codec::encode`] produces,
    /// variant by variant, and therefore a safe **upper bound** for the
    /// [`MAX_FRAME_BYTES`](crate::protocols::outbox::MAX_FRAME_BYTES)
    /// frame-splitting logic (the TCP receiver rejects frames over
    /// 64 MiB). Also the simulator's bandwidth/CPU byte count. A property
    /// test (`tests/properties.rs`) holds this and the codec together.
    pub fn size(&self) -> usize {
        const TS: usize = 12; // u64 time + u32 gid
        const BAL: usize = 8; // u32 round + u32 pid
        fn cmd_size(c: &RsmCmd) -> usize {
            1 + match c {
                RsmCmd::AssignLts { meta, .. } => meta.size() + TS,
                RsmCmd::Commit { .. } => 8 + TS,
            }
        }
        fn state_size(s: &MsgState) -> usize {
            s.meta.size() + 1 + 2 * TS
        }
        match self {
            Wire::Multicast { meta } => 1 + meta.size(),
            Wire::Delivered { .. } => 1 + 8 + 4 + TS,
            Wire::Propose { .. } => 1 + 8 + 4 + TS,
            Wire::Accept { meta, .. } => 1 + meta.size() + 4 + BAL + TS,
            Wire::AcceptAck { bals, .. } => 1 + 8 + 4 + 4 + bals.len() * (4 + BAL),
            Wire::Deliver { .. } => 1 + 8 + BAL + 2 * TS + 1,
            Wire::NewLeader { .. } => 1 + BAL,
            Wire::NewLeaderAck { state, .. } => {
                1 + 2 * BAL + 8 + 4 + state.iter().map(state_size).sum::<usize>()
            }
            Wire::NewState { state, .. } => 1 + BAL + 8 + 4 + state.iter().map(state_size).sum::<usize>(),
            Wire::NewStateAck { .. } => 1 + BAL,
            Wire::Confirm { .. } => 1 + 8 + 4,
            Wire::Paxos { msg, .. } => {
                1 + 4
                    + match msg {
                        PaxosMsg::P1a { .. } => 1 + BAL,
                        PaxosMsg::P1b { log, .. } => {
                            1 + BAL + 4 + log.iter().map(|(_, _, c)| 8 + BAL + cmd_size(c)).sum::<usize>()
                        }
                        PaxosMsg::P2a { cmd, .. } => 1 + BAL + 8 + cmd_size(cmd),
                        PaxosMsg::P2b { .. } => 1 + BAL + 8,
                        PaxosMsg::Learn { cmd, .. } => 1 + 8 + cmd_size(cmd),
                    }
            }
            Wire::Heartbeat { .. } => 1 + BAL,
            Wire::GcReport { .. } => 1 + TS,
            // tag + u32 count + inner encodings
            Wire::Batch(inner) => 1 + 4 + inner.iter().map(|w| w.size()).sum::<usize>(),
        }
    }

    /// Short tag for logging / stats.
    pub fn tag(&self) -> &'static str {
        match self {
            Wire::Multicast { .. } => "MULTICAST",
            Wire::Delivered { .. } => "DELIVERED",
            Wire::Propose { .. } => "PROPOSE",
            Wire::Accept { .. } => "ACCEPT",
            Wire::AcceptAck { .. } => "ACCEPT_ACK",
            Wire::Deliver { .. } => "DELIVER",
            Wire::NewLeader { .. } => "NEWLEADER",
            Wire::NewLeaderAck { .. } => "NEWLEADER_ACK",
            Wire::NewState { .. } => "NEW_STATE",
            Wire::NewStateAck { .. } => "NEWSTATE_ACK",
            Wire::Confirm { .. } => "CONFIRM",
            Wire::Paxos { .. } => "PAXOS",
            Wire::Heartbeat { .. } => "HEARTBEAT",
            Wire::GcReport { .. } => "GC_REPORT",
            Wire::Batch(..) => "BATCH",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Pid;

    #[test]
    fn sizes_are_positive_and_scale_with_payload() {
        let small = Wire::Multicast { meta: MsgMeta::new(MsgId::new(1, 1), GidSet::single(Gid(0)), vec![0; 20]) };
        let big = Wire::Multicast { meta: MsgMeta::new(MsgId::new(1, 2), GidSet::single(Gid(0)), vec![0; 200]) };
        assert!(small.size() > 0);
        assert_eq!(big.size() - small.size(), 180);
    }

    #[test]
    fn tags_distinct() {
        let msgs = [
            Wire::NewLeader { bal: Ballot::new(1, Pid(0)) },
            Wire::NewStateAck { bal: Ballot::new(1, Pid(0)) },
            Wire::Heartbeat { bal: Ballot::new(1, Pid(0)) },
            Wire::Batch(vec![]),
        ];
        let tags: Vec<_> = msgs.iter().map(|m| m.tag()).collect();
        assert_eq!(tags, vec!["NEWLEADER", "NEWSTATE_ACK", "HEARTBEAT", "BATCH"]);
    }

    #[test]
    fn batch_size_is_header_plus_inner_sizes() {
        let a = Wire::Heartbeat { bal: Ballot::new(1, Pid(0)) };
        let b = Wire::Multicast { meta: MsgMeta::new(MsgId::new(1, 1), GidSet::single(Gid(0)), vec![0; 20]) };
        let batch = Wire::Batch(vec![a.clone(), b.clone()]);
        assert_eq!(batch.size(), 5 + a.size() + b.size());
    }
}
