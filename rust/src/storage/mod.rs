//! Durable per-node storage: a segmented, CRC-checksummed write-ahead
//! log with a group-commit fsync policy, periodic compacted snapshots,
//! and torn-tail truncation on open.
//!
//! The paper's WbCast assumes crash-stop processes: a crashed replica
//! never comes back, and the group survives through leader recovery over
//! the remaining quorum (Fig. 4 lines 35–66). Real deployments restart
//! processes. This module gives each [`WbNode`](crate::protocols::wbcast)
//! a journal of exactly the state the recovery protocol relies on — the
//! ballot promises made in `NEWLEADER_ACK`/`NEWSTATE_ACK`, the
//! `(lts, ballot)` pairs acknowledged in `ACCEPT_ACK`, committed
//! `(lts, gts)` pairs and local deliveries — so that a killed process
//! can be rebuilt from disk and rejoin its group through the *existing*
//! recovery path without violating Invariants 2/5.
//!
//! Layout of a storage directory (one per node):
//!
//! ```text
//! wal-{first_record_index:016x}.log    append-only record segments
//! snap-{record_index:016x}.snap        compacted snapshot covering all
//!                                      records with index < record_index
//! ```
//!
//! Every record (and the snapshot payload) is framed as
//! `u32 len ++ u32 crc32(payload) ++ payload`, with the payload encoded
//! by the same hand-rolled codec the wire protocol uses
//! ([`crate::codec`]). On open, the newest *valid* snapshot is loaded
//! and the tail of the log replayed over it; the first unreadable frame
//! (short header, bad length, CRC mismatch, undecodable payload — i.e. a
//! torn tail from a crash mid-write) truncates the log there, and any
//! later segments are discarded.
//!
//! Durability cost is governed by [`SyncPolicy`]: `Always` fsyncs at
//! every group-commit point (the runtimes call [`Storage::commit`] once
//! per event-loop flush cycle, so one fsync covers every record the
//! cycle produced), `IntervalUs` bounds data loss to a time window, and
//! `Never` leaves flushing to the OS. See EXPERIMENTS.md §Durability
//! cost for the measurement methodology.
//!
//! [`MemWal`] is the simulator's storage backend: the identical record
//! framing over an in-memory buffer, so crash-restart schedules
//! round-trip node state through the exact on-disk codec (and the
//! invariant checkers cover restarts; see `sim::World::enable_storage`).

use crate::codec::{self, Dec, Enc};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, OnceLock};
use crate::types::wire::MsgState;
use crate::types::{Ballot, MsgId, Phase, Ts};
use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Reject record frames claiming more than this (a corrupt length field
/// would otherwise allocate gigabytes before the CRC could object).
const MAX_RECORD_BYTES: usize = 64 << 20;

/// Rotate the active WAL segment once it exceeds this many bytes.
const DEFAULT_SEGMENT_BYTES: u64 = 4 << 20;

/// Write a compacted snapshot (and drop the now-covered segments) once
/// the live log exceeds this many bytes.
const DEFAULT_SNAPSHOT_AFTER: u64 = 16 << 20;

/// Group-commit fsync policy for [`Storage::commit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncPolicy {
    /// fsync at every commit point (every event-loop flush cycle): no
    /// acknowledged state is ever lost, at one `fdatasync` per cycle
    Always,
    /// fsync at most once per this many microseconds: bounded-window
    /// loss, near-`Never` throughput
    IntervalUs(u64),
    /// never fsync explicitly; buffered writes reach the OS at every
    /// commit point, the kernel flushes when it pleases
    Never,
}

impl SyncPolicy {
    /// Parse the CLI spelling: `always`, `never`, `interval` (5000 µs)
    /// or `interval:<µs>`.
    pub fn parse(s: &str) -> Option<SyncPolicy> {
        match s {
            "always" => Some(SyncPolicy::Always),
            "never" => Some(SyncPolicy::Never),
            "interval" => Some(SyncPolicy::IntervalUs(5_000)),
            _ => s.strip_prefix("interval:").and_then(|us| us.parse().ok()).map(SyncPolicy::IntervalUs),
        }
    }
}

/// One journal entry. Everything a [`WbNode`](crate::protocols::wbcast)
/// tells the outside world it will remember is recorded *before* the
/// promise leaves the process (the runtimes commit records ahead of the
/// same cycle's sends).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Record {
    /// Ballot promise (`NEWLEADER` vote, Fig. 4 line 37): `ballot` was
    /// promised while `cballot` was still current.
    Promote { ballot: Ballot, cballot: Ballot, clock: u64 },
    /// Upsert of one message's replicated state: the `(phase, lts, gts)`
    /// triple acknowledged in `ACCEPT_ACK` (phase = ACCEPTED) or
    /// resolved at commit (phase = COMMITTED). Reuses the [`MsgState`]
    /// snapshot the recovery protocol already exchanges.
    State { state: MsgState, clock: u64 },
    /// Local delivery of `m` (it must never be delivered twice, and the
    /// delivery watermark gates post-recovery `DELIVER` resends).
    Deliver { m: MsgId, lts: Ts, gts: Ts },
    /// Wholesale state replacement (`NEW_STATE` adoption / a new
    /// leader's merge, Fig. 4 lines 44–57): unlike [`Record::State`]
    /// upserts, entries absent from `state` are *dropped* — exactly the
    /// semantics of the in-memory adoption, so a restart cannot
    /// resurrect superseded local timestamps (Invariant 2).
    Adopt { ballot: Ballot, cballot: Ballot, clock: u64, state: Vec<MsgState> },
    /// Garbage-collection watermark: delivered entries at or below `wm`
    /// were trimmed (same retention rule as `WbNode::trim_below`).
    Trim { wm: Ts },
}

// ---------------- CRC-32 (IEEE, reflected) ----------------

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------- record codec ----------------

fn put_record(e: &mut Enc, rec: &Record) {
    match rec {
        Record::Promote { ballot, cballot, clock } => {
            e.u8(0);
            codec::put_ballot(e, *ballot);
            codec::put_ballot(e, *cballot);
            e.u64(*clock);
        }
        Record::State { state, clock } => {
            e.u8(1);
            codec::put_state(e, state);
            e.u64(*clock);
        }
        Record::Deliver { m, lts, gts } => {
            e.u8(2);
            e.u64(m.0);
            codec::put_ts(e, *lts);
            codec::put_ts(e, *gts);
        }
        Record::Adopt { ballot, cballot, clock, state } => {
            e.u8(3);
            codec::put_ballot(e, *ballot);
            codec::put_ballot(e, *cballot);
            e.u64(*clock);
            e.u32(state.len() as u32);
            for s in state {
                codec::put_state(e, s);
            }
        }
        Record::Trim { wm } => {
            e.u8(4);
            codec::put_ts(e, *wm);
        }
    }
}

fn get_record(d: &mut Dec) -> codec::Result<Record> {
    Ok(match d.u8()? {
        0 => Record::Promote { ballot: codec::get_ballot(d)?, cballot: codec::get_ballot(d)?, clock: d.u64()? },
        1 => Record::State { state: codec::get_state(d)?, clock: d.u64()? },
        2 => Record::Deliver { m: MsgId(d.u64()?), lts: codec::get_ts(d)?, gts: codec::get_ts(d)? },
        3 => {
            let ballot = codec::get_ballot(d)?;
            let cballot = codec::get_ballot(d)?;
            let clock = d.u64()?;
            let n = d.u32()? as usize;
            let mut state = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                state.push(codec::get_state(d)?);
            }
            Record::Adopt { ballot, cballot, clock, state }
        }
        4 => Record::Trim { wm: codec::get_ts(d)? },
        v => return Err(codec::CodecError::BadTag { what: "Record", value: v }),
    })
}

/// Encode one record's payload into a fresh buffer (tests, [`MemWal`]).
pub fn encode_record(rec: &Record) -> Vec<u8> {
    let mut e = Enc::new();
    put_record(&mut e, rec);
    e.buf
}

/// Decode one record payload, checking full consumption.
pub fn decode_record(buf: &[u8]) -> codec::Result<Record> {
    let mut d = Dec::new(buf);
    let r = get_record(&mut d)?;
    d.finish()?;
    Ok(r)
}

/// Append one `len ++ crc ++ payload` frame for `rec` to `out`.
pub fn append_frame(out: &mut Vec<u8>, rec: &Record) {
    let payload = encode_record(rec);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
}

/// Decode consecutive record frames from `buf`, stopping at the first
/// frame that cannot be fully validated (short header, oversized or
/// short payload, CRC mismatch, undecodable record — the torn-tail
/// cases). Returns the decoded prefix and the number of bytes it spans
/// (the truncation point for a file-backed log).
pub fn decode_frames(buf: &[u8]) -> (Vec<Record>, usize) {
    let mut recs = Vec::new();
    let mut pos = 0usize;
    loop {
        if buf.len() - pos < 8 {
            break;
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if len > MAX_RECORD_BYTES || buf.len() - pos - 8 < len {
            break;
        }
        let payload = &buf[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            break;
        }
        let Ok(rec) = decode_record(payload) else { break };
        recs.push(rec);
        pos += 8 + len;
    }
    (recs, pos)
}

// ---------------- folded snapshot ----------------

/// The compacted image of a node's journal: folding every [`Record`] in
/// order into an empty `Snapshot` yields the state a restarted node
/// resumes from (`WbNode::restore`). [`Storage`] maintains this fold
/// incrementally and writes it out as the on-disk snapshot when the log
/// grows past the compaction threshold.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    /// highest ballot promised (`NEWLEADER` votes included)
    pub ballot: Ballot,
    /// current ballot (last completed promotion)
    pub cballot: Ballot,
    /// Lamport clock lower bound
    pub clock: u64,
    /// delivery watermark (gates `DELIVER` application after restart)
    pub max_delivered_gts: Ts,
    /// replicated per-message state, keyed by message id
    pub state: BTreeMap<MsgId, MsgState>,
    /// delivered log: gts → message (post-recovery resend source)
    pub delivered: BTreeMap<Ts, MsgId>,
    /// per-client delivered-sequence watermark (GC duplicate detection)
    pub client_seq: BTreeMap<u32, u32>,
}

impl Snapshot {
    /// True when nothing was ever journaled (fresh node).
    pub fn is_blank(&self) -> bool {
        self.ballot.is_bot()
            && self.cballot.is_bot()
            && self.clock == 0
            && self.state.is_empty()
            && self.delivered.is_empty()
    }

    /// Fold one record into the image, in journal order.
    pub fn apply(&mut self, rec: &Record) {
        match rec {
            Record::Promote { ballot, cballot, clock } => {
                self.ballot = (*ballot).max(self.ballot);
                self.cballot = (*cballot).max(self.cballot);
                self.clock = self.clock.max(*clock);
            }
            Record::State { state, clock } => {
                self.clock = self.clock.max(*clock);
                match self.state.get_mut(&state.meta.id) {
                    Some(e) => {
                        e.phase = state.phase;
                        e.lts = state.lts;
                        e.gts = state.gts;
                        if e.meta.dest.is_empty() && !state.meta.dest.is_empty() {
                            e.meta = state.meta.clone();
                        }
                    }
                    None => {
                        self.state.insert(state.meta.id, state.clone());
                    }
                }
            }
            Record::Deliver { m, lts, gts } => {
                self.delivered.insert(*gts, *m);
                self.max_delivered_gts = self.max_delivered_gts.max(*gts);
                self.clock = self.clock.max(gts.time());
                let wm = self.client_seq.entry(m.client()).or_insert(0);
                *wm = (*wm).max(m.seq());
                // mirror the follower path: delivery implies COMMITTED,
                // creating the entry if the ACCEPT never reached us
                let e = self.state.entry(*m).or_insert_with(|| MsgState {
                    meta: crate::types::MsgMeta::new(*m, crate::types::GidSet::EMPTY, vec![]),
                    phase: Phase::Committed,
                    lts: *lts,
                    gts: *gts,
                });
                e.phase = Phase::Committed;
                e.lts = *lts;
                e.gts = *gts;
            }
            Record::Adopt { ballot, cballot, clock, state } => {
                self.ballot = (*ballot).max(self.ballot);
                self.cballot = (*cballot).max(self.cballot);
                self.clock = self.clock.max(*clock);
                // replacement, not upsert: entries the adoption dropped
                // must not be resurrected by a later restart
                self.state = state.iter().map(|s| (s.meta.id, s.clone())).collect();
            }
            Record::Trim { wm } => {
                let drop: Vec<(Ts, MsgId)> = self
                    .delivered
                    .range(..=*wm)
                    .filter(|&(_, &m)| self.client_seq.get(&m.client()).is_some_and(|&s| m.seq() < s))
                    .map(|(&g, &m)| (g, m))
                    .collect();
                for (g, m) in drop {
                    self.delivered.remove(&g);
                    self.state.remove(&m);
                }
            }
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        codec::put_ballot(&mut e, self.ballot);
        codec::put_ballot(&mut e, self.cballot);
        e.u64(self.clock);
        codec::put_ts(&mut e, self.max_delivered_gts);
        e.u32(self.state.len() as u32);
        for s in self.state.values() {
            codec::put_state(&mut e, s);
        }
        e.u32(self.delivered.len() as u32);
        for (&gts, &m) in &self.delivered {
            codec::put_ts(&mut e, gts);
            e.u64(m.0);
        }
        e.u32(self.client_seq.len() as u32);
        for (&c, &s) in &self.client_seq {
            e.u32(c);
            e.u32(s);
        }
        e.buf
    }

    fn decode(buf: &[u8]) -> codec::Result<Snapshot> {
        let mut d = Dec::new(buf);
        let ballot = codec::get_ballot(&mut d)?;
        let cballot = codec::get_ballot(&mut d)?;
        let clock = d.u64()?;
        let max_delivered_gts = codec::get_ts(&mut d)?;
        let mut state = BTreeMap::new();
        for _ in 0..d.u32()? {
            let s = codec::get_state(&mut d)?;
            state.insert(s.meta.id, s);
        }
        let mut delivered = BTreeMap::new();
        for _ in 0..d.u32()? {
            let gts = codec::get_ts(&mut d)?;
            delivered.insert(gts, MsgId(d.u64()?));
        }
        let mut client_seq = BTreeMap::new();
        for _ in 0..d.u32()? {
            let c = d.u32()?;
            client_seq.insert(c, d.u32()?);
        }
        d.finish()?;
        Ok(Snapshot { ballot, cballot, clock, max_delivered_gts, state, delivered, client_seq })
    }
}

// ---------------- in-memory WAL (simulator backend) ----------------

/// A disk fault armed on the next [`MemWal::append`] — the simulator's
/// nemesis schedules inject these (see `crate::sim::nemesis`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalFault {
    /// the next append is torn mid-frame: only a strict prefix of the
    /// frame reaches the buffer — the crash-mid-write tail that
    /// [`decode_frames`] truncates on recovery
    Torn,
    /// the next append fails outright: nothing is written and the log
    /// poisons itself — the [`Storage::poison`] analogue; a poisoned
    /// `MemWal` is refused by the simulated restart path exactly like a
    /// `POISONED` directory is refused by [`Storage::open`]
    Failed,
}

/// The simulator's storage backend: record frames appended to a byte
/// buffer with the identical framing the file-backed WAL uses, so a
/// simulated restart round-trips node state through the on-disk codec.
/// Nemesis schedules can arm torn/failing writes ([`MemWal::arm_fault`])
/// to exercise the same crash-mid-write and poison semantics the
/// file-backed [`Storage`] implements.
#[derive(Default)]
pub struct MemWal {
    buf: Vec<u8>,
    records: u64,
    /// fault armed for the next append (+ torn cut in basis points of
    /// the frame length) // nemesis-ok: fault-hook state, sim-injected
    armed: Option<(WalFault, u32)>,
    /// fault that fired and has not been observed yet ([`MemWal::take_fired`])
    fired: Option<WalFault>,
    /// a write failed: journaling stops and restore is refused
    poisoned: bool,
}

impl MemWal {
    /// An empty in-memory log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm `fault` for the next append. For [`WalFault::Torn`], `cut_bp`
    /// (basis points, 0..10000) picks the cut position within the torn
    /// frame — always at least one byte short of a complete frame.
    // nemesis-ok: definition site; callers live in sim/tests only
    pub fn arm_fault(&mut self, fault: WalFault, cut_bp: u32) {
        self.armed = Some((fault, cut_bp.min(9_999)));
    }

    /// The fault that fired since the last call, if any. The simulator
    /// crashes the owning process inside the same atomic event, so no
    /// post-failure acknowledgement can leave before the fault is seen.
    pub fn take_fired(&mut self) -> Option<WalFault> {
        self.fired.take()
    }

    /// True once a write failed; parity with [`Storage::is_poisoned`].
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Append one framed record. Memory is the disk here, so the append
    /// is infallible — unless a nemesis fault is armed: a torn write
    /// keeps only a prefix of the frame, a failed write keeps nothing
    /// and poisons the log (both leave the fault observable through
    /// [`MemWal::take_fired`] before any caller can acknowledge).
    pub fn append(&mut self, rec: &Record) {
        if self.poisoned || self.fired.is_some() {
            // post-poison journaling is discarded (Storage parity), and
            // nothing lands after an unobserved tear either — the write
            // stream ends at the torn frame, exactly like a real crash
            // mid-write (the owner crashes before the fault is taken)
            return;
        }
        match self.armed.take() {
            Some((WalFault::Failed, _)) => {
                self.poisoned = true;
                self.fired = Some(WalFault::Failed);
            }
            Some((WalFault::Torn, cut_bp)) => {
                let start = self.buf.len();
                append_frame(&mut self.buf, rec);
                let flen = self.buf.len() - start;
                // keep cut_bp/10000 of the frame, strictly short of whole
                let keep = ((flen as u64 * cut_bp as u64) / 10_000) as usize;
                self.buf.truncate(start + keep.min(flen.saturating_sub(1)));
                self.fired = Some(WalFault::Torn);
                // the torn record was never durable: not counted
            }
            None => {
                append_frame(&mut self.buf, rec);
                self.records += 1;
            }
        }
    }

    /// Truncate the raw log to `len` bytes — tests cut at arbitrary
    /// (including mid-frame) positions to exercise torn-tail recovery.
    pub fn truncate_to(&mut self, len: usize) {
        self.buf.truncate(len);
    }

    /// Number of records appended so far.
    pub fn len(&self) -> u64 {
        self.records
    }

    /// True when nothing was ever appended.
    pub fn is_empty(&self) -> bool {
        self.records == 0
    }

    /// The raw framed bytes (tests cut/corrupt these).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Decode + fold everything back into a [`Snapshot`] — the restart
    /// image. Goes through [`decode_frames`], i.e. the exact validation
    /// the file-backed log performs.
    pub fn recover(&self) -> Snapshot {
        let (recs, _) = decode_frames(&self.buf);
        let mut snap = Snapshot::default();
        for r in &recs {
            snap.apply(r);
        }
        snap
    }
}

// ---------------- file-backed segmented WAL ----------------

fn seg_path(dir: &Path, first: u64) -> PathBuf {
    dir.join(format!("wal-{first:016x}.log"))
}

fn snap_path(dir: &Path, upto: u64) -> PathBuf {
    dir.join(format!("snap-{upto:016x}.snap"))
}

/// Parse `prefix-{:016x}.suffix` file names back to their index.
fn parse_indexed(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    let rest = name.strip_prefix(prefix)?;
    let hex = rest.strip_suffix(suffix)?;
    u64::from_str_radix(hex, 16).ok()
}

/// fsync the directory itself: file-level `fdatasync` does not persist
/// directory entries, so segment creation, the snapshot rename and
/// compaction unlinks all need this for crash durability.
fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    File::open(dir)?.sync_all()
}

/// Durable per-node storage handle: the segmented WAL plus the
/// incrementally folded [`Snapshot`] image it compacts into.
///
/// Lifecycle: [`Storage::open`] replays snapshot + log (truncating any
/// torn tail); the owning runtime then appends records as its node
/// emits them ([`Storage::append`]) and calls [`Storage::commit`] once
/// per event-loop flush cycle — the group-commit point, *before* the
/// cycle's sends reach the transport. [`Storage::sync`] forces an fsync
/// (also run on drop).
pub struct Storage {
    dir: PathBuf,
    policy: SyncPolicy,
    segment_bytes: u64,
    snapshot_after: u64,
    /// index of the next record to be appended
    seq: u64,
    /// first record index not covered by the newest on-disk snapshot
    snap_seq: u64,
    /// active segment (buffered; `commit` flushes, policy fsyncs)
    file: std::io::BufWriter<File>,
    /// first record index of the active segment
    seg_start: u64,
    /// bytes written to the active segment
    seg_bytes: u64,
    /// live log bytes since the last snapshot (compaction trigger)
    wal_bytes: u64,
    image: Snapshot,
    enc: Enc,
    /// bytes appended since the last flush to the OS
    dirty: bool,
    /// bytes flushed to the OS but not yet fsynced (`IntervalUs`/`Never`)
    unsynced: bool,
    /// a write failed: journaling stopped, the directory carries a
    /// `POISONED` marker, and future [`Storage::open`]s refuse it
    poison_flag: PoisonFlag,
    /// live counters shared with the metrics exporter
    stats: Arc<StorageStats>,
    last_sync: Instant,
}

/// Marker file written when a journal write fails ([`Storage::poison`]).
const POISON_MARKER: &str = "POISONED";

/// Cross-thread poison latch. The `Storage` is owned by one worker
/// thread, but "did journaling fail?" must be observable from others —
/// shutdown paths, health checks, tests — *before* any post-failure
/// acknowledgement they receive from the worker: [`PoisonFlag::set`] is
/// a release store and [`PoisonFlag::get`] an acquire load, so
/// everything the worker did up to the poison (the marker file, the
/// last good commit) happens-before a positive observation. The loom
/// model (`loom_poison_visible_before_post_failure_ack`) checks exactly
/// this ordering across every interleaving.
#[derive(Clone, Debug, Default)]
pub struct PoisonFlag(Arc<AtomicBool>);

impl PoisonFlag {
    pub fn new() -> Self {
        Self::default()
    }

    /// Latch the flag (release; never cleared).
    pub fn set(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Observe the latch (acquire).
    pub fn get(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Storage/WAL counters, shared out of the owning worker thread behind
/// `Arc` (the same pattern as [`crate::coordinator::CoordStats`] /
/// [`crate::net::NetStats`]) so the metrics exporter
/// ([`crate::obs::export`]) reads them live. Relaxed ordering: these are
/// monitoring counters, not synchronisation.
#[derive(Debug, Default)]
pub struct StorageStats {
    /// records appended to the active segment
    pub records_appended: AtomicU64,
    /// frame bytes (header + payload) appended
    pub bytes_appended: AtomicU64,
    /// group-commit points that flushed buffered frames to the OS
    pub commits: AtomicU64,
    /// explicit `fdatasync` calls (policy-due commits, rotations,
    /// snapshots, shutdown syncs)
    pub fsyncs: AtomicU64,
    /// segment rotations
    pub rotations: AtomicU64,
    /// compacted snapshots written
    pub snapshots_written: AtomicU64,
    /// 1 once the journal poisoned itself (write failure)
    pub poisoned: AtomicU64,
}

impl Storage {
    /// Open (or create) the storage directory, replaying the newest
    /// valid snapshot plus the log tail and truncating torn frames.
    ///
    /// The directory must belong to exactly one live process: there is
    /// no file lock (the offline toolchain has no `flock` binding, and
    /// a `kill -9` survivor lockfile would block the restart this
    /// subsystem exists for), so two concurrent writers would interleave
    /// frames and corrupt each other. Deployments get this for free —
    /// each `serve` endpoint owns `DIR/p<pid>/` and must be stopped
    /// before its replacement starts.
    pub fn open(dir: impl AsRef<Path>, policy: SyncPolicy) -> std::io::Result<Storage> {
        Self::open_with(dir, policy, DEFAULT_SEGMENT_BYTES, DEFAULT_SNAPSHOT_AFTER)
    }

    /// [`Storage::open`] with explicit rotation/compaction thresholds
    /// (tests exercise rotation with tiny segments).
    pub fn open_with(
        dir: impl AsRef<Path>,
        policy: SyncPolicy,
        segment_bytes: u64,
        snapshot_after: u64,
    ) -> std::io::Result<Storage> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        // a poisoned journal has a hole at its tail (a write failed while
        // the process kept making promises): restoring from it could
        // violate Invariant 2, so refuse — the operator must wipe the
        // directory and bring the process back as a new deployment
        if dir.join(POISON_MARKER).exists() {
            return Err(std::io::Error::other(format!(
                "storage {dir:?} is poisoned (a journal write failed in a previous run); \
                 wipe the directory to start fresh"
            )));
        }

        // newest snapshot that validates wins; invalid ones are ignored
        let mut snaps: Vec<u64> = Vec::new();
        let mut segs: Vec<u64> = Vec::new();
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(i) = parse_indexed(name, "snap-", ".snap") {
                snaps.push(i);
            } else if let Some(i) = parse_indexed(name, "wal-", ".log") {
                segs.push(i);
            }
        }
        snaps.sort_unstable();
        segs.sort_unstable();

        let mut image = Snapshot::default();
        let mut snap_seq = 0u64;
        for &upto in snaps.iter().rev() {
            match Self::load_snapshot(&snap_path(&dir, upto)) {
                Some(s) => {
                    image = s;
                    snap_seq = upto;
                    break;
                }
                None => log::warn!("storage: ignoring invalid snapshot {upto:#x} in {dir:?}"),
            }
        }

        // replay segments in order, counting global record indices; only
        // records the snapshot does not cover are folded into the image.
        // `reached` tracks how far the contiguous record history extends:
        // a segment starting past it means a *hole* (a segment or the
        // snapshot meant to cover the gap is missing/corrupt) — restoring
        // across a hole could regress promises (Invariant 2), so refuse,
        // exactly like the POISONED tail-hole case.
        let mut reached = snap_seq;
        let mut last_seg: Option<(u64, u64)> = None; // (first index, valid bytes)
        let mut wal_bytes = 0u64; // live log across every retained segment
        for (k, &first) in segs.iter().enumerate() {
            if first > reached {
                return Err(std::io::Error::other(format!(
                    "storage {dir:?}: journal hole — segment {first:#x} starts past record \
                     {reached:#x} (missing/corrupt snapshot or segment); wipe the directory \
                     to start fresh"
                )));
            }
            let path = seg_path(&dir, first);
            let mut bytes = Vec::new();
            File::open(&path)?.read_to_end(&mut bytes)?;
            let (recs, valid) = decode_frames(&bytes);
            let mut idx = first;
            for r in &recs {
                if idx >= snap_seq {
                    image.apply(r);
                }
                idx += 1;
            }
            let torn = valid < bytes.len();
            if torn && idx < snap_seq {
                // a tear below the snapshot means appends would land in a
                // mislabelled segment and vanish from future replays
                return Err(std::io::Error::other(format!(
                    "storage {dir:?}: segment {first:#x} is torn below snapshot {snap_seq:#x}; \
                     wipe the directory to start fresh"
                )));
            }
            reached = reached.max(idx);
            wal_bytes += valid as u64;
            if torn {
                log::warn!(
                    "storage: truncating torn tail of {path:?} at {valid}/{} bytes",
                    bytes.len()
                );
                let f = OpenOptions::new().write(true).open(&path)?;
                f.set_len(valid as u64)?;
                f.sync_data()?;
            }
            last_seg = Some((first, valid as u64));
            if torn {
                // anything after a torn segment is unreachable garbage
                for &later in &segs[k + 1..] {
                    let _ = fs::remove_file(seg_path(&dir, later));
                }
                break;
            }
        }
        let seq = reached;

        // resume appending to the last segment (or start the first one)
        let (seg_start, seg_bytes) = match last_seg {
            Some((first, valid)) => (first, valid),
            None => (seq, 0),
        };
        let path = seg_path(&dir, seg_start);
        let file = OpenOptions::new().create(true).append(true).open(&path)?;

        Ok(Storage {
            dir,
            policy,
            segment_bytes: segment_bytes.max(1),
            snapshot_after: snapshot_after.max(1),
            seq,
            snap_seq,
            file: std::io::BufWriter::new(file),
            seg_start,
            seg_bytes,
            wal_bytes,
            image,
            enc: Enc::new(),
            dirty: false,
            unsynced: false,
            poison_flag: PoisonFlag::new(),
            stats: Arc::new(StorageStats::default()),
            last_sync: Instant::now(),
        })
    }

    /// A shared handle to this storage's live counters (the metrics
    /// exporter aggregates one per hosted shard).
    pub fn stats(&self) -> Arc<StorageStats> {
        self.stats.clone()
    }

    fn load_snapshot(path: &Path) -> Option<Snapshot> {
        let mut bytes = Vec::new();
        File::open(path).ok()?.read_to_end(&mut bytes).ok()?;
        if bytes.len() < 8 {
            return None;
        }
        let len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if bytes.len() - 8 < len {
            return None;
        }
        let payload = &bytes[8..8 + len];
        if crc32(payload) != crc {
            return None;
        }
        Snapshot::decode(payload).ok()
    }

    /// The recovered (and continuously folded) node image. Blank for a
    /// fresh directory — callers use this to choose `WbNode::new` vs
    /// `WbNode::restore`.
    pub fn image(&self) -> &Snapshot {
        &self.image
    }

    /// Records journaled so far (next record index).
    pub fn record_count(&self) -> u64 {
        self.seq
    }

    /// The storage directory this handle owns.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True once a journal write failed: appends are discarded, the
    /// directory is marked, and future opens refuse to restore from it.
    pub fn is_poisoned(&self) -> bool {
        self.poison_flag.get()
    }

    /// A clone of the poison latch, observable from other threads (the
    /// worker owning this `Storage` keeps journaling decisions local,
    /// but health checks may watch the latch without a channel hop).
    pub fn poison_flag(&self) -> PoisonFlag {
        self.poison_flag.clone()
    }

    /// A journal write failed: stop journaling (a WAL with a hole is
    /// worse than no WAL — restoring from it could resurrect dropped
    /// state or forget a promise) and leave a marker so a later restart
    /// refuses the directory instead of restoring inconsistent state.
    /// The running process carries on with its in-memory state — from
    /// the group's perspective it degrades to a crash-stop process (it
    /// just can never come back from this disk).
    pub fn poison(&mut self) {
        if self.poison_flag.get() {
            return;
        }
        self.poison_flag.set();
        self.stats.poisoned.store(1, Ordering::Relaxed);
        // the marker must itself be durable, or a crash after a failed
        // write could restore from the holed WAL the marker exists to
        // block — fsync the file and the directory entry
        let durable_marker = (|| {
            let mut f = File::create(self.dir.join(POISON_MARKER))?;
            f.write_all(b"journal write failed; do not restore\n")?;
            f.sync_all()?;
            fsync_dir(&self.dir)
        })();
        match durable_marker {
            Ok(()) => log::error!(
                "storage: journaling to {:?} stopped after a write failure; the directory is \
                 poisoned and will not be restored from",
                self.dir
            ),
            Err(e) => log::error!(
                "storage: journaling to {:?} stopped after a write failure AND the POISONED \
                 marker could not be made durable ({e}); wipe the directory before any restart",
                self.dir
            ),
        }
    }

    /// Append one record to the active segment (buffered; durability
    /// happens at [`Storage::commit`] per the [`SyncPolicy`]). On error
    /// the storage poisons itself — see [`Storage::poison`].
    pub fn append(&mut self, rec: &Record) -> std::io::Result<()> {
        if self.poison_flag.get() {
            return Ok(());
        }
        self.enc.buf.clear();
        put_record(&mut self.enc, rec);
        let payload = &self.enc.buf;
        let mut header = [0u8; 8];
        header[0..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        header[4..8].copy_from_slice(&crc32(payload).to_le_bytes());
        let write = (|| {
            self.file.write_all(&header)?;
            self.file.write_all(payload)
        })();
        if let Err(e) = write {
            self.poison();
            return Err(e);
        }
        let n = 8 + payload.len() as u64;
        self.seg_bytes += n;
        self.wal_bytes += n;
        self.seq += 1;
        self.image.apply(rec);
        self.dirty = true;
        self.stats.records_appended.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_appended.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }

    /// The group-commit point, called once per event-loop flush cycle
    /// *before* the cycle's sends reach the transport (and again on idle
    /// ticks, so an `IntervalUs` policy fsyncs the tail of a burst even
    /// when traffic stops): pushes buffered frames to the OS, fsyncs per
    /// the policy, then rotates/compacts if thresholds were crossed.
    /// On error the storage poisons itself.
    pub fn commit(&mut self) -> std::io::Result<()> {
        if self.poison_flag.get() || (!self.dirty && !self.unsynced) {
            return Ok(());
        }
        let r = self.commit_inner();
        if r.is_err() {
            self.poison();
        }
        r
    }

    fn commit_inner(&mut self) -> std::io::Result<()> {
        if self.dirty {
            self.file.flush()?;
            self.dirty = false;
            self.unsynced = true;
            self.stats.commits.fetch_add(1, Ordering::Relaxed);
        }
        let due = match self.policy {
            SyncPolicy::Always => true,
            SyncPolicy::IntervalUs(us) => self.last_sync.elapsed().as_micros() as u64 >= us,
            SyncPolicy::Never => false,
        };
        if due && self.unsynced {
            self.file.get_ref().sync_data()?;
            self.last_sync = Instant::now();
            self.unsynced = false;
            self.stats.fsyncs.fetch_add(1, Ordering::Relaxed);
        }
        if self.wal_bytes >= self.snapshot_after {
            self.write_snapshot()?;
        } else if self.seg_bytes >= self.segment_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    /// Force-flush and fsync everything (shutdown; also run on drop).
    pub fn sync(&mut self) -> std::io::Result<()> {
        if self.poison_flag.get() {
            return Ok(());
        }
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        self.last_sync = Instant::now();
        self.dirty = false;
        self.unsynced = false;
        self.stats.fsyncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn rotate(&mut self) -> std::io::Result<()> {
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        self.stats.fsyncs.fetch_add(1, Ordering::Relaxed);
        self.stats.rotations.fetch_add(1, Ordering::Relaxed);
        let path = seg_path(&self.dir, self.seq);
        self.file = std::io::BufWriter::new(OpenOptions::new().create(true).append(true).open(&path)?);
        // persist the new segment's directory entry: without this a
        // crash can lose the whole file even though its records were
        // fdatasync'd (breaking `SyncPolicy::Always`)
        fsync_dir(&self.dir)?;
        self.seg_start = self.seq;
        self.seg_bytes = 0;
        Ok(())
    }

    /// Write the folded image as a snapshot covering `[0, seq)`, start a
    /// fresh segment, and drop every older segment and snapshot.
    fn write_snapshot(&mut self) -> std::io::Result<()> {
        let payload = self.image.encode();
        let tmp = self.dir.join("snap.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&(payload.len() as u32).to_le_bytes())?;
            f.write_all(&crc32(&payload).to_le_bytes())?;
            f.write_all(&payload)?;
            f.sync_data()?;
        }
        fs::rename(&tmp, snap_path(&self.dir, self.seq))?;
        // the rename must hit disk before the covered segments go away,
        // or a crash mid-compaction could leave neither snapshot nor log
        fsync_dir(&self.dir)?;
        self.stats.snapshots_written.fetch_add(1, Ordering::Relaxed);
        self.snap_seq = self.seq;
        self.rotate()?; // new segment starts at seq; all older are covered
        self.wal_bytes = 0;
        // compaction: everything below the snapshot is dead weight
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(i) = parse_indexed(name, "wal-", ".log") {
                if i < self.snap_seq && i != self.seg_start {
                    let _ = fs::remove_file(entry.path());
                }
            } else if let Some(i) = parse_indexed(name, "snap-", ".snap") {
                if i < self.snap_seq {
                    let _ = fs::remove_file(entry.path());
                }
            }
        }
        fsync_dir(&self.dir)?;
        Ok(())
    }
}

impl Drop for Storage {
    fn drop(&mut self) {
        // always fsync on the way out: `Never`/`IntervalUs` policies may
        // have clean-shutdown writes sitting unfsynced in the OS
        if let Err(e) = self.sync() {
            log::warn!("storage: final sync of {:?} failed: {e}", self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Gid, GidSet, MsgMeta, Pid};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("wbam-storage-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn st(id: u64, phase: Phase, t: u64) -> MsgState {
        MsgState {
            meta: MsgMeta::new(MsgId(id), GidSet::single(Gid(0)), vec![7; 9]),
            phase,
            lts: Ts::new(t, Gid(0)),
            gts: if phase == Phase::Committed { Ts::new(t + 1, Gid(1)) } else { Ts::BOT },
        }
    }

    fn sample_records() -> Vec<Record> {
        vec![
            Record::Promote { ballot: Ballot::new(2, Pid(1)), cballot: Ballot::new(1, Pid(0)), clock: 3 },
            Record::State { state: st(1, Phase::Accepted, 4), clock: 4 },
            Record::State { state: st(1, Phase::Committed, 4), clock: 5 },
            Record::Deliver { m: MsgId(1), lts: Ts::new(4, Gid(0)), gts: Ts::new(5, Gid(1)) },
            Record::Adopt {
                ballot: Ballot::new(3, Pid(2)),
                cballot: Ballot::new(3, Pid(2)),
                clock: 9,
                state: vec![st(2, Phase::Accepted, 6)],
            },
            Record::Trim { wm: Ts::new(5, Gid(1)) },
        ]
    }

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn record_roundtrip() {
        for r in sample_records() {
            let bytes = encode_record(&r);
            assert_eq!(decode_record(&bytes).expect("decode"), r);
        }
    }

    #[test]
    fn frames_roundtrip_and_reject_corruption() {
        let recs = sample_records();
        let mut buf = Vec::new();
        for r in &recs {
            append_frame(&mut buf, r);
        }
        let (got, used) = decode_frames(&buf);
        assert_eq!(got, recs);
        assert_eq!(used, buf.len());
        // flip one byte inside the third frame's payload: decode stops
        // there, returning the prefix before it
        let mut bad = buf.clone();
        let off: usize = recs[..2].iter().map(|r| 8 + encode_record(r).len()).sum();
        bad[off + 8] ^= 0xFF;
        let (got, used) = decode_frames(&bad);
        assert_eq!(got, recs[..2]);
        assert_eq!(used, off);
    }

    #[test]
    fn snapshot_fold_matches_semantics() {
        let mut snap = Snapshot::default();
        for r in sample_records() {
            snap.apply(&r);
        }
        // Adopt replaced the state wholesale: message 1 is gone, 2 lives
        assert!(!snap.state.contains_key(&MsgId(1)));
        assert_eq!(snap.state[&MsgId(2)].phase, Phase::Accepted);
        assert_eq!(snap.ballot, Ballot::new(3, Pid(2)));
        assert_eq!(snap.cballot, Ballot::new(3, Pid(2)));
        assert_eq!(snap.clock, 9);
        // delivery bookkeeping survives adoption (local knowledge)
        assert_eq!(snap.max_delivered_gts, Ts::new(5, Gid(1)));
        assert_eq!(snap.delivered[&Ts::new(5, Gid(1))], MsgId(1));
        // snapshot body round-trips
        let enc = snap.encode();
        assert_eq!(Snapshot::decode(&enc).expect("snapshot decode"), snap);
    }

    #[test]
    fn memwal_recovers_the_fold() {
        let mut w = MemWal::new();
        let mut want = Snapshot::default();
        for r in sample_records() {
            w.append(&r);
            want.apply(&r);
        }
        assert_eq!(w.recover(), want);
        assert_eq!(w.len(), 6);
    }

    #[test]
    fn storage_reopen_replays_and_truncates_torn_tail() {
        let dir = tmpdir("torn");
        let recs = sample_records();
        {
            let mut s = Storage::open(&dir, SyncPolicy::Always).expect("open");
            assert!(s.image().is_blank());
            for r in &recs {
                s.append(r).unwrap();
            }
            s.commit().unwrap();
        }
        // clean reopen: image equals the fold
        let mut want = Snapshot::default();
        for r in &recs {
            want.apply(r);
        }
        {
            let s = Storage::open(&dir, SyncPolicy::Always).expect("reopen");
            assert_eq!(*s.image(), want);
            assert_eq!(s.record_count(), recs.len() as u64);
        }
        // tear the tail: append half a frame by hand
        let seg = seg_path(&dir, 0);
        let valid = fs::metadata(&seg).unwrap().len();
        {
            let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
            f.write_all(&[0x99; 11]).unwrap();
        }
        {
            let mut s = Storage::open(&dir, SyncPolicy::Always).expect("torn reopen");
            assert_eq!(*s.image(), want, "torn tail must not corrupt the image");
            // the torn bytes were truncated away
            assert_eq!(fs::metadata(&seg).unwrap().len(), valid);
            // and appending after a torn open keeps working
            s.append(&recs[0]).unwrap();
            s.commit().unwrap();
        }
        let s = Storage::open(&dir, SyncPolicy::Always).expect("final reopen");
        assert_eq!(s.record_count(), recs.len() as u64 + 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn storage_rotates_and_compacts_into_snapshots() {
        let dir = tmpdir("rotate");
        let recs = sample_records();
        {
            // tiny thresholds: every commit rotates, snapshots every ~3 frames
            let mut s = Storage::open_with(&dir, SyncPolicy::Never, 64, 220).expect("open");
            for _ in 0..10 {
                for r in &recs {
                    s.append(r).unwrap();
                    s.commit().unwrap();
                }
            }
            s.sync().unwrap();
            // compaction kept the file count bounded: one snapshot plus
            // the handful of segments appended since it
            let names: Vec<String> = fs::read_dir(&dir)
                .unwrap()
                .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
                .collect();
            assert!(names.len() <= 6, "compaction left {names:?}");
            assert!(names.iter().any(|n| n.starts_with("snap-")), "no snapshot written: {names:?}");
        }
        // the reopened image equals a straight fold of the whole history
        let mut want = Snapshot::default();
        for _ in 0..10 {
            for r in &recs {
                want.apply(r);
            }
        }
        let s = Storage::open(&dir, SyncPolicy::Never).expect("reopen");
        assert_eq!(*s.image(), want);
        assert_eq!(s.record_count(), recs.len() as u64 * 10);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_hole_refuses_to_open() {
        // a segment starting past the covered history (its predecessor —
        // or the snapshot covering the gap — is missing) must refuse to
        // restore rather than fold a suffix into a blank image
        let dir = tmpdir("hole");
        fs::create_dir_all(&dir).unwrap();
        let mut buf = Vec::new();
        append_frame(&mut buf, &sample_records()[0]);
        fs::write(seg_path(&dir, 0x10), &buf).unwrap();
        assert!(Storage::open(&dir, SyncPolicy::Never).is_err(), "gapped journal must refuse");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn poisoned_directory_refuses_to_open() {
        let dir = tmpdir("poison");
        {
            let mut s = Storage::open(&dir, SyncPolicy::Always).expect("open");
            s.append(&sample_records()[0]).unwrap();
            s.commit().unwrap();
            s.poison();
            assert!(s.is_poisoned());
            // post-poison journaling is discarded, never an error storm
            s.append(&sample_records()[1]).unwrap();
            s.commit().unwrap();
            s.sync().unwrap();
        }
        assert!(Storage::open(&dir, SyncPolicy::Always).is_err(), "poisoned dir must refuse restore");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn interval_policy_fsyncs_a_quiet_tail_on_idle_commit() {
        let dir = tmpdir("interval");
        let mut s = Storage::open(&dir, SyncPolicy::IntervalUs(1)).expect("open");
        s.append(&sample_records()[0]).unwrap();
        s.commit().unwrap(); // flushes; the 1 µs interval may or may not be due yet
        // an idle-tick commit after the interval elapsed must fsync the
        // tail even though nothing new was appended
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.commit().unwrap();
        assert!(!s.dirty && !s.unsynced, "idle commit left the tail unsynced");
        drop(s);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sync_policy_parse() {
        assert_eq!(SyncPolicy::parse("always"), Some(SyncPolicy::Always));
        assert_eq!(SyncPolicy::parse("never"), Some(SyncPolicy::Never));
        assert_eq!(SyncPolicy::parse("interval"), Some(SyncPolicy::IntervalUs(5_000)));
        assert_eq!(SyncPolicy::parse("interval:250"), Some(SyncPolicy::IntervalUs(250)));
        assert_eq!(SyncPolicy::parse("bogus"), None);
    }
}

/// Exhaustive interleaving tests for the poison latch, run under the
/// in-tree model checker:
/// `RUSTFLAGS="--cfg loom" cargo test --release loom_`.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::PoisonFlag;
    use crate::sync::{model, mpsc, thread};

    /// The invariant the coordinator relies on: a worker that poisons
    /// its storage and *then* acknowledges the cycle must have the
    /// poison visible to whoever receives that acknowledgement, in
    /// every interleaving (release store + acquire load + the channel's
    /// happens-before edge).
    #[test]
    fn loom_poison_visible_before_post_failure_ack() {
        model(|| {
            let latch = PoisonFlag::new();
            let observer = latch.clone();
            let (ack_tx, ack_rx) = mpsc::channel();
            let worker = thread::spawn(move || {
                // a journal write failed: latch first, ack second
                latch.set();
                ack_tx.send(()).unwrap();
            });
            ack_rx.recv().unwrap();
            assert!(observer.get(), "post-failure ack arrived before the poison was visible");
            worker.join().unwrap();
        });
    }
}
