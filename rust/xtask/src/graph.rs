//! `cargo xtask graph` — generated architecture diagrams.
//!
//! Emits two Graphviz DOT files under `target/analyze/`:
//!
//! * `message_flow.dot` — nodes are `Wire` variants (plus `client` and
//!   `timer` pseudo-nodes); an edge `V -> U [label="p"]` means protocol
//!   `p`'s `on_wire` handler for `V` can send `U` (directly or through
//!   its call graph, including `let`-bound wires). `on_timer` sends
//!   appear as `timer -> U`; the client's `multicast` entry appears as
//!   `client -> Multicast`.
//! * `lock_order.dot` — the held-while-acquiring graph from the
//!   lock-order analysis (see [`crate::analyze::locks`]); a clean tree
//!   shows the acquired locks as isolated nodes.
//!
//! The embedded message-flow figure in ARCHITECTURE.md §Correctness
//! tooling is this output, regenerated whenever the protocol set
//! changes.

use crate::analyze::{self, is_method, matching_paren, SENDS};
use crate::lexer::Kind;
use crate::parser::{calls_in, match_arms, path_variants, FnInfo, ParsedFile};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::process::ExitCode;

/// Protocol label for a file: `protocols/wbcast/mod.rs` -> `wbcast`,
/// `protocols/skeen.rs` -> `skeen`, `client/mod.rs` -> `client`.
fn proto_label(path: &str) -> String {
    let norm = path.replace('\\', "/");
    let parts: Vec<&str> = norm.split('/').collect();
    let last = parts.last().copied().unwrap_or("");
    let stem = last.strip_suffix(".rs").unwrap_or(last);
    if (stem == "mod" || stem == "recovery") && parts.len() >= 2 {
        parts[parts.len() - 2].to_string()
    } else {
        stem.to_string()
    }
}

/// `ident -> Wire variants` for every `let id = .. Wire::V ..;` in the
/// function body (any variant, unlike the journal analysis' ack-only
/// tracking).
fn wire_bindings(f: &ParsedFile, func: &FnInfo) -> BTreeMap<String, BTreeSet<String>> {
    let mut bound: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let toks = &f.toks;
    let (start, end) = func.body;
    let end = end.min(toks.len());
    let mut i = start;
    while i < end {
        if toks[i].kind == Kind::Ident && toks[i].text == "let" {
            let mut j = i + 1;
            if j < end && toks[j].text == "mut" {
                j += 1;
            }
            if j < end && toks[j].kind == Kind::Ident && j + 1 < end && toks[j + 1].text == "=" {
                let name = toks[j].text.clone();
                let mut k = j + 2;
                let mut d = 0i64;
                while k < end {
                    let t = toks[k].text.as_str();
                    if t == "(" || t == "[" || t == "{" {
                        d += 1;
                    } else if t == ")" || t == "]" || t == "}" {
                        d -= 1;
                    } else if t == ";" && d == 0 {
                        break;
                    }
                    k += 1;
                }
                let vs: BTreeSet<String> =
                    path_variants(toks, (j + 2, k), "Wire").into_iter().map(|(v, _)| v).collect();
                if !vs.is_empty() {
                    bound.entry(name).or_default().extend(vs);
                }
                i = k;
            }
        }
        i += 1;
    }
    bound
}

/// Wire variants sent by `.send*(..)` calls inside the token range,
/// resolving `let`-bound wire idents via `bound`.
fn sends_in(
    f: &ParsedFile,
    rng: (usize, usize),
    bound: &BTreeMap<String, BTreeSet<String>>,
) -> BTreeSet<String> {
    let toks = &f.toks;
    let mut sent = BTreeSet::new();
    if toks.is_empty() {
        return sent;
    }
    for i in rng.0..rng.1.min(toks.len() - 1) {
        let t = &toks[i];
        if t.kind == Kind::Ident
            && SENDS.contains(&t.text.as_str())
            && toks[i + 1].text == "("
            && is_method(toks, i)
        {
            let close = matching_paren(toks, i + 1);
            for (v, _) in path_variants(toks, (i + 1, close), "Wire") {
                sent.insert(v);
            }
            for k in (i + 2)..close {
                if toks[k].kind == Kind::Ident {
                    if let Some(vs) = bound.get(&toks[k].text) {
                        sent.extend(vs.iter().cloned());
                    }
                }
            }
        }
    }
    sent
}

type FnKey = (usize, usize);

/// Per-fn transitive sent-variant sets plus the per-name union.
fn send_closure(
    files: &[ParsedFile],
) -> (BTreeMap<FnKey, BTreeSet<String>>, BTreeMap<String, BTreeSet<String>>) {
    let mut direct: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut callees: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut by_name: BTreeMap<String, Vec<FnKey>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (fni, func) in f.fns.iter().enumerate() {
            if func.in_test {
                continue;
            }
            let key = (fi, fni);
            direct.insert(key, sends_in(f, func.body, &wire_bindings(f, func)));
            callees.insert(key, calls_in(&f.toks, func.body).into_iter().map(|(n, _)| n).collect());
            by_name.entry(func.name.clone()).or_default().push(key);
        }
    }
    let sends = analyze::close_over_calls(direct, &callees, &by_name);
    let mut name_sends: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for ((fi, fni), ss) in &sends {
        let nm = &files[*fi].fns[*fni].name;
        name_sends.entry(nm.clone()).or_default().extend(ss.iter().cloned());
    }
    (sends, name_sends)
}

/// `(from, to, protocol label)` edge set of the message-flow graph.
pub(crate) fn message_flow_edges(files: &[ParsedFile]) -> BTreeSet<(String, String, String)> {
    let (sends, name_sends) = send_closure(files);
    let mut edges: BTreeSet<(String, String, String)> = BTreeSet::new();
    for (fi, f) in files.iter().enumerate() {
        let label = proto_label(&f.path);
        for (fni, func) in f.fns.iter().enumerate() {
            if func.in_test {
                continue;
            }
            let key = (fi, fni);
            let empty = BTreeSet::new();
            let fn_sends = sends.get(&key).unwrap_or(&empty);
            if func.name == "on_wire" {
                let toks = &f.toks;
                let bound = wire_bindings(f, func);
                let mut arms_found = false;
                for i in func.body.0..func.body.1.min(toks.len()) {
                    if toks[i].kind == Kind::Ident && toks[i].text == "match" {
                        for arm in match_arms(toks, i, func.body.1) {
                            let pv: Vec<String> = path_variants(toks, arm.pat, "Wire")
                                .into_iter()
                                .map(|(v, _)| v)
                                .collect();
                            if pv.is_empty() {
                                continue;
                            }
                            arms_found = true;
                            let mut outs = sends_in(f, arm.body, &bound);
                            for (nm, _) in calls_in(toks, arm.body) {
                                if let Some(ss) = name_sends.get(&nm) {
                                    outs.extend(ss.iter().cloned());
                                }
                            }
                            for src in &pv {
                                for dst in &outs {
                                    edges.insert((src.clone(), dst.clone(), label.clone()));
                                }
                            }
                        }
                        break; // the dispatch match is the first one
                    }
                }
                if !arms_found {
                    // let-else dispatch (client): the whole body handles
                    // the bound variant
                    for i in func.body.0..func.body.1.min(toks.len()) {
                        if toks[i].kind == Kind::Ident
                            && toks[i].text == "let"
                            && i + 1 < toks.len()
                            && toks[i + 1].text == "Wire"
                        {
                            for (src, _) in path_variants(toks, (i + 1, i + 5), "Wire") {
                                for dst in fn_sends {
                                    edges.insert((src.clone(), dst.clone(), label.clone()));
                                }
                            }
                        }
                    }
                }
            } else if func.name == "on_timer" {
                for dst in fn_sends {
                    edges.insert(("timer".to_string(), dst.clone(), label.clone()));
                }
            } else if func.name == "multicast" && fn_sends.contains("Multicast") {
                edges.insert(("client".to_string(), "Multicast".to_string(), label.clone()));
            }
        }
    }
    edges
}

/// Render an edge set as Graphviz DOT, deterministically ordered.
pub(crate) fn dot(
    name: &str,
    edges: &BTreeSet<(String, String, String)>,
    extra_nodes: &[String],
) -> String {
    let mut lines = vec![format!("digraph {name} {{"), "  rankdir=LR;".to_string()];
    let mut nodes: BTreeSet<&str> = extra_nodes.iter().map(|s| s.as_str()).collect();
    for (a, b, _) in edges {
        nodes.insert(a);
        nodes.insert(b);
    }
    for n in &nodes {
        let shape = if *n == "client" || *n == "timer" { "ellipse" } else { "box" };
        lines.push(format!("  \"{n}\" [shape={shape}];"));
    }
    for (a, b, lab) in edges {
        lines.push(format!("  \"{a}\" -> \"{b}\" [label=\"{lab}\"];"));
    }
    lines.push("}".to_string());
    lines.join("\n")
}

/// The message-flow file set: protocol core + client + Paxos substrate.
fn flow_files(root: &Path) -> Vec<ParsedFile> {
    let mut files: Vec<ParsedFile> = Vec::new();
    for rel in crate::rs_files_under(root, "rust/src/protocols") {
        if rel.ends_with("tests.rs") {
            continue;
        }
        if let Some(f) = analyze::parse_rel(root, &rel) {
            files.push(f);
        }
    }
    for rel in ["rust/src/client/mod.rs", "rust/src/paxos/mod.rs"] {
        if let Some(f) = analyze::parse_rel(root, rel) {
            files.push(f);
        }
    }
    files
}

/// `cargo xtask graph`: write both DOT files and print their paths.
pub fn run(root: &Path) -> ExitCode {
    let flow = message_flow_edges(&flow_files(root));
    let mf = dot("message_flow", &flow, &[]);

    let mut lfiles: Vec<ParsedFile> = Vec::new();
    for rel in analyze::LOCK_FILES {
        if let Some(f) = analyze::parse_rel(root, rel) {
            lfiles.push(f);
        }
    }
    let ledges = analyze::locks::edges(&lfiles);
    // witness shortened to file:line for the figure
    let short: BTreeSet<(String, String, String)> = ledges
        .iter()
        .map(|(a, b, w)| {
            (a.clone(), b.clone(), w.split(" in ").next().unwrap_or("").to_string())
        })
        .collect();
    // show acquired locks as nodes even when edge-free (the healthy case)
    let mut nodes: BTreeSet<String> = BTreeSet::new();
    for (a, b, _) in &ledges {
        nodes.insert(a.clone());
        nodes.insert(b.clone());
    }
    for f in &lfiles {
        for func in &f.fns {
            if func.in_test {
                continue;
            }
            // reuse the journal-agnostic acquisition scan: any `x.lock(`
            for i in func.body.0..func.body.1.min(f.toks.len()) {
                if f.toks[i].kind == Kind::Ident
                    && f.toks[i].text == "lock"
                    && i + 1 < f.toks.len()
                    && f.toks[i + 1].text == "("
                    && is_method(&f.toks, i)
                    && i >= 2
                    && f.toks[i - 2].kind == Kind::Ident
                {
                    nodes.insert(f.toks[i - 2].text.clone());
                }
            }
        }
    }
    let node_list: Vec<String> = nodes.into_iter().collect();
    let lo = dot("lock_order", &short, &node_list);

    let dir = root.join("target/analyze");
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("xtask graph: create {dir:?}: {e}");
        return ExitCode::FAILURE;
    }
    let mf_path = dir.join("message_flow.dot");
    let lo_path = dir.join("lock_order.dot");
    for (path, content) in [(&mf_path, &mf), (&lo_path, &lo)] {
        if let Err(e) = std::fs::write(path, format!("{content}\n")) {
            eprintln!("xtask graph: write {path:?}: {e}");
            return ExitCode::FAILURE;
        }
    }
    println!(
        "xtask graph: wrote {} ({} edges) and {} ({} nodes)",
        mf_path.display(),
        flow.len(),
        lo_path.display(),
        node_list.len()
    );
    ExitCode::SUCCESS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf(path: &str, src: &str) -> ParsedFile {
        ParsedFile::parse(path, src)
    }

    #[test]
    fn proto_labels() {
        assert_eq!(proto_label("rust/src/protocols/wbcast/mod.rs"), "wbcast");
        assert_eq!(proto_label("rust/src/protocols/wbcast/recovery.rs"), "wbcast");
        assert_eq!(proto_label("rust/src/protocols/skeen.rs"), "skeen");
        assert_eq!(proto_label("rust/src/client/mod.rs"), "client");
    }

    #[test]
    fn on_wire_arm_sends_become_edges_including_let_bound() {
        let src = "
impl Node for N {
    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
        match wire {
            Wire::Multicast { m } => {
                let acc = Wire::Accept { m };
                out.send_to_many(peers, acc);
            }
            Wire::Accept { m } => self.ack(m, out),
            _ => {}
        }
    }
    fn ack(&mut self, m: M, out: &mut Outbox) {
        out.send(from, Wire::AcceptAck { m });
    }
}
";
        let edges = message_flow_edges(&[pf("protocols/wbcast/mod.rs", src)]);
        assert!(edges.contains(&("Multicast".into(), "Accept".into(), "wbcast".into())), "{edges:#?}");
        assert!(edges.contains(&("Accept".into(), "AcceptAck".into(), "wbcast".into())), "{edges:#?}");
    }

    #[test]
    fn timer_and_client_pseudo_nodes() {
        let src = "
impl Node for N {
    fn on_timer(&mut self, now: u64, out: &mut Outbox) {
        out.send_to_many(peers, Wire::Heartbeat { t: now });
    }
}
impl Client {
    fn multicast(&mut self, m: M, out: &mut Outbox) {
        out.send(self.coord, Wire::Multicast { m });
    }
}
";
        let edges = message_flow_edges(&[pf("protocols/x.rs", src)]);
        assert!(edges.contains(&("timer".into(), "Heartbeat".into(), "x".into())), "{edges:#?}");
        assert!(edges.contains(&("client".into(), "Multicast".into(), "x".into())), "{edges:#?}");
    }

    #[test]
    fn dot_output_is_deterministic_and_shaped() {
        let mut edges = BTreeSet::new();
        edges.insert(("timer".to_string(), "Deliver".to_string(), "p".to_string()));
        let d = dot("message_flow", &edges, &[]);
        assert!(d.starts_with("digraph message_flow {"));
        assert!(d.contains("\"timer\" [shape=ellipse];"));
        assert!(d.contains("\"Deliver\" [shape=box];"));
        assert!(d.contains("\"timer\" -> \"Deliver\" [label=\"p\"];"));
        assert!(d.ends_with('}'));
    }
}
