//! Lock-order deadlock freedom over the `crate::sync` facade.
//!
//! Builds the *held-while-acquiring* graph: an edge `a -> b` means some
//! code path acquires lock `b` (directly or via a call chain) while
//! holding lock `a`. Lock identity is the receiver identifier of
//! `.lock(` (`inner`, `cb`, ...). Guard lifetimes are approximated
//! structurally:
//!
//! * `let g = <chain>.lock()...;` — held until `drop(g)` or the end of
//!   the enclosing brace;
//! * a temporary (`x.lock().unwrap().touch();`) — held to the end of
//!   the statement.
//!
//! Cycles in the graph (including self-loops, i.e. re-acquiring the
//! same lock while holding it) are reported. Audited non-edges carry
//! `// lock-ok: <reason>` on the acquisition line or on a call line to
//! exclude that call from the held-scope walk (e.g. a callee that
//! shares a method name with a lock-taking function but never takes
//! the lock).

use super::{close_over_calls, FnKey};
use crate::lexer::Kind;
use crate::parser::{calls_in, FnInfo, ParsedFile};
use crate::Violation;
use std::collections::{BTreeMap, BTreeSet};

/// `(lock ident, token idx of `lock`, scope end token idx, line)` for
/// every unannotated `.lock(` acquisition in the function body.
fn lock_acquisitions(f: &ParsedFile, func: &FnInfo) -> Vec<(String, usize, usize, usize)> {
    let toks = &f.toks;
    let (start, end) = func.body;
    let end = end.min(toks.len());
    let mut out = Vec::new();
    for i in start..end {
        let t = &toks[i];
        if t.kind != Kind::Ident || t.text != "lock" {
            continue;
        }
        if i + 1 >= end || toks[i + 1].text != "(" {
            continue;
        }
        if !(i > 0 && toks[i - 1].text == ".") {
            continue;
        }
        if i < 2 || toks[i - 2].kind != Kind::Ident {
            continue;
        }
        let ident = toks[i - 2].text.clone();
        if f.has_marker(t.line, "lock-ok") {
            continue;
        }
        // walk back over the receiver chain (`a . b . lock`) to find a
        // possible `let [mut] g =` guard binding
        let mut j = i - 2;
        while j >= 2 && toks[j - 1].text == "." && toks[j - 2].kind == Kind::Ident {
            j -= 2;
        }
        let mut guard: Option<String> = None;
        if j >= 2 && toks[j - 1].text == "=" && toks[j - 2].kind == Kind::Ident {
            let g = toks[j - 2].text.clone();
            let mut k2 = j as i64 - 3;
            if k2 >= 0 && toks[k2 as usize].text == "mut" {
                k2 -= 1;
            }
            if k2 >= 0 && toks[k2 as usize].text == "let" {
                guard = Some(g);
            }
        }
        let scope_end;
        if let Some(g) = guard {
            // held until `drop(g)` or the end of the enclosing brace
            let mut d = 0i64;
            let mut se = end;
            let mut k = i;
            while k < end {
                let tx = toks[k].text.as_str();
                if tx == "{" {
                    d += 1;
                } else if tx == "}" {
                    d -= 1;
                    if d < 0 {
                        se = k;
                        break;
                    }
                } else if toks[k].kind == Kind::Ident
                    && tx == "drop"
                    && k + 2 < end
                    && toks[k + 1].text == "("
                    && toks[k + 2].text == g
                {
                    se = k;
                    break;
                }
                k += 1;
            }
            scope_end = se;
        } else {
            // temporary: dropped at the end of the statement
            let mut d = 0i64;
            let mut se = end;
            let mut k = i;
            while k < end {
                let tx = toks[k].text.as_str();
                if tx == "(" || tx == "[" || tx == "{" {
                    d += 1;
                } else if tx == ")" || tx == "]" || tx == "}" {
                    d -= 1;
                    if d < 0 {
                        se = k;
                        break;
                    }
                } else if tx == ";" && d == 0 {
                    se = k;
                    break;
                }
                k += 1;
            }
            scope_end = se;
        }
        out.push((ident, i, scope_end, t.line));
    }
    out
}

/// The held-while-acquiring edge set: `(held, acquired, witness)`.
pub(crate) fn edges(files: &[ParsedFile]) -> BTreeSet<(String, String, String)> {
    // per-name transitive lock sets
    let mut direct: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut callees: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut by_name: BTreeMap<String, Vec<FnKey>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (fni, func) in f.fns.iter().enumerate() {
            if func.in_test {
                continue;
            }
            let key = (fi, fni);
            direct.insert(key, lock_acquisitions(f, func).into_iter().map(|a| a.0).collect());
            callees.insert(key, calls_in(&f.toks, func.body).into_iter().map(|(n, _)| n).collect());
            by_name.entry(func.name.clone()).or_default().push(key);
        }
    }
    let locks = close_over_calls(direct, &callees, &by_name);
    let mut name_locks: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for ((fi, fni), ls) in &locks {
        let nm = &files[*fi].fns[*fni].name;
        name_locks.entry(nm.clone()).or_default().extend(ls.iter().cloned());
    }

    let mut edges: BTreeSet<(String, String, String)> = BTreeSet::new();
    for f in files {
        for func in &f.fns {
            if func.in_test {
                continue;
            }
            let acqs = lock_acquisitions(f, func);
            for (ident, i, scope_end, _line) in &acqs {
                // nested direct acquisitions inside the held scope
                for (ident2, i2, _, line2) in &acqs {
                    if *i < *i2 && *i2 < *scope_end {
                        edges.insert((
                            ident.clone(),
                            ident2.clone(),
                            format!("{}:{} in {}", f.path, line2, func.qname),
                        ));
                    }
                }
                // calls made while held (lock-ok on the call line excludes)
                for (name, ci) in calls_in(&f.toks, (*i, *scope_end)) {
                    if f.has_marker(f.toks[ci].line, "lock-ok") {
                        continue;
                    }
                    if let Some(ls) = name_locks.get(&name) {
                        for l2 in ls {
                            edges.insert((
                                ident.clone(),
                                l2.clone(),
                                format!(
                                    "{}:{} in {} via {}()",
                                    f.path,
                                    f.toks[ci].line,
                                    func.qname,
                                    name
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
    edges
}

fn dfs(
    node: &str,
    path: &[(String, String)],
    names: &[String],
    adj: &BTreeMap<String, Vec<(String, String)>>,
    seen: &mut BTreeSet<Vec<String>>,
    out: &mut Vec<Violation>,
) {
    let Some(nbrs) = adj.get(node) else { return };
    for (b, w) in nbrs {
        if let Some(pos) = names.iter().position(|n| n == b) {
            let mut cyc: Vec<(String, String)> = path[pos..].to_vec();
            cyc.push((b.clone(), w.clone()));
            let mut sig: Vec<String> = cyc.iter().map(|x| x.0.clone()).collect();
            sig.sort();
            sig.dedup();
            if seen.insert(sig) {
                let desc = cyc.iter().map(|x| x.0.as_str()).collect::<Vec<_>>().join(" -> ");
                let wits = cyc
                    .iter()
                    .filter(|x| !x.1.is_empty())
                    .map(|x| x.1.as_str())
                    .collect::<Vec<_>>()
                    .join("; ");
                out.push(Violation {
                    file: "(lock graph)".to_string(),
                    line: 1,
                    rule: "lock-order",
                    msg: format!("lock acquisition cycle {desc}: {wits}"),
                });
            }
            continue;
        }
        let mut p2 = path.to_vec();
        p2.push((b.clone(), w.clone()));
        let mut n2 = names.to_vec();
        n2.push(b.clone());
        dfs(b, &p2, &n2, adj, seen, out);
    }
}

/// Run the lock-order analysis: report every acquisition cycle once.
pub fn check(files: &[ParsedFile]) -> Vec<Violation> {
    let e = edges(files);
    let mut adj: BTreeMap<String, Vec<(String, String)>> = BTreeMap::new();
    for (a, b, w) in &e {
        adj.entry(a.clone()).or_default().push((b.clone(), w.clone()));
    }
    let mut out = Vec::new();
    let mut seen: BTreeSet<Vec<String>> = BTreeSet::new();
    for start in adj.keys() {
        dfs(
            start,
            &[(start.clone(), String::new())],
            &[start.clone()],
            &adj,
            &mut seen,
            &mut out,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf(src: &str) -> ParsedFile {
        ParsedFile::parse("l.rs", src)
    }

    const CYCLE: &str = "
impl S {
    fn a(&self) {
        let g = self.x.lock().unwrap();
        self.helper_y();
    }
    fn helper_y(&self) {
        self.y.lock().unwrap().touch();
    }
    fn b(&self) {
        let g = self.y.lock().unwrap();
        self.helper_x();
    }
    fn helper_x(&self) {
        self.x.lock().unwrap().touch();
    }
}
";

    #[test]
    fn two_lock_cycle_via_calls_fires_once() {
        let vs = check(&[pf(CYCLE)]);
        assert_eq!(vs.len(), 1, "{vs:#?}");
        assert_eq!(vs[0].rule, "lock-order");
        assert!(vs[0].msg.contains('x') && vs[0].msg.contains('y'));
    }

    #[test]
    fn one_direction_only_is_clean() {
        let no_cycle = CYCLE.replace("self.helper_x();", "");
        assert!(check(&[pf(&no_cycle)]).is_empty());
    }

    const SELF_CYCLE: &str = "
impl S {
    fn a(&self) {
        let g = self.x.lock().unwrap();
        self.helper();
    }
    fn helper(&self) {
        self.x.lock().unwrap().touch();
    }
}
";

    #[test]
    fn double_acquire_is_a_self_cycle() {
        let vs = check(&[pf(SELF_CYCLE)]);
        assert_eq!(vs.len(), 1, "{vs:#?}");
    }

    #[test]
    fn drop_releases_the_guard() {
        let src = "
impl S {
    fn a(&self) {
        let g = self.x.lock().unwrap();
        drop(g);
        self.helper();
    }
    fn helper(&self) {
        self.x.lock().unwrap().touch();
    }
}
";
        assert!(check(&[pf(src)]).is_empty());
    }

    #[test]
    fn temporary_guard_dies_at_statement_end() {
        let src = "
impl S {
    fn a(&self) {
        self.x.lock().unwrap().touch();
        self.helper();
    }
    fn helper(&self) {
        self.y.lock().unwrap().touch();
        self.back();
    }
    fn back(&self) {
        self.x.lock().unwrap().touch();
    }
}
";
        assert!(check(&[pf(src)]).is_empty());
    }

    #[test]
    fn lock_ok_on_the_call_line_suppresses() {
        let marked = SELF_CYCLE.replace(
            "self.helper();",
            "// lock-ok: not a reentry\n        self.helper();",
        );
        assert!(check(&[pf(&marked)]).is_empty());
    }
}
