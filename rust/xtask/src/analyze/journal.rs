//! Journal-before-ack dataflow (§"the ACCEPT_ACK is a promise").
//!
//! In the white-box protocol an ACCEPT_ACK doubles as a Paxos phase-2b
//! promise, NEWLEADER_ACK as a phase-1b promise, and NEWSTATE_ACK as
//! adopting a new epoch — all three bind the sender across a
//! crash-recover, so the corresponding journal record must hit the
//! outbox's record stage *before* the send on every path:
//!
//! | reply               | required record   |
//! |---------------------|-------------------|
//! | `Wire::AcceptAck`   | `Record::State`   |
//! | `Wire::NewLeaderAck`| `Record::Promote` |
//! | `Wire::NewStateAck` | `Record::Adopt`   |
//!
//! Black-box Paxos promises (`PaxosMsg::P1b`/`P2b`) require *some*
//! record on the path (the baselines journal nothing by design and
//! carry a `// durability-ok:` annotation instead).
//!
//! The check is a linear scan of each function body in token order,
//! accumulating record kinds seen so far — both direct `out.record(..)`
//! calls and calls into functions that (transitively) record, resolved
//! through a name-based call-graph fixpoint. `let`-bound acks
//! (`let ack = Wire::AcceptAck {..}; out.send(to, ack)`) are tracked
//! through the binding.

use super::{close_over_calls, is_method, matching_paren, FnKey, SENDS};
use crate::lexer::{Kind, Tok};
use crate::parser::{calls_in, path_variants, FnInfo, ParsedFile};
use crate::Violation;
use std::collections::{BTreeMap, BTreeSet};

/// Promise-carrying acks and the record kind each requires.
const ACK_RECORD: &[(&str, &str)] =
    &[("AcceptAck", "State"), ("NewLeaderAck", "Promote"), ("NewStateAck", "Adopt")];

/// Black-box Paxos promise replies: require *any* record on the path.
const PAXOS_PROMISES: &[&str] = &["P1b", "P2b"];

fn ack_record(variant: &str) -> Option<&'static str> {
    ACK_RECORD.iter().find(|(v, _)| *v == variant).map(|(_, r)| *r)
}

/// `toks[i]` is a `record` ident with `(` next: `Record::K` kinds in
/// the argument list.
fn record_kinds_at(toks: &[Tok], i: usize) -> Vec<String> {
    let close = matching_paren(toks, i + 1);
    path_variants(toks, (i + 1, close), "Record").into_iter().map(|(k, _)| k).collect()
}

/// Per-function-name union of record kinds each function transitively
/// emits (through the call graph).
fn record_closure(files: &[ParsedFile]) -> BTreeMap<String, BTreeSet<String>> {
    let mut direct: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut callees: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut by_name: BTreeMap<String, Vec<FnKey>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (fni, func) in f.fns.iter().enumerate() {
            let key = (fi, fni);
            let mut kinds = BTreeSet::new();
            for i in func.body.0..func.body.1.min(f.toks.len()) {
                let t = &f.toks[i];
                if t.kind == Kind::Ident
                    && t.text == "record"
                    && i + 1 < f.toks.len()
                    && f.toks[i + 1].text == "("
                    && is_method(&f.toks, i)
                {
                    kinds.extend(record_kinds_at(&f.toks, i));
                }
            }
            direct.insert(key, kinds);
            callees.insert(key, calls_in(&f.toks, func.body).into_iter().map(|(n, _)| n).collect());
            by_name.entry(func.name.clone()).or_default().push(key);
        }
    }
    let emits = close_over_calls(direct, &callees, &by_name);
    let mut name_emits: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for ((fi, fni), kinds) in &emits {
        let nm = &files[*fi].fns[*fni].name;
        name_emits.entry(nm.clone()).or_default().extend(kinds.iter().cloned());
    }
    name_emits
}

/// Idents `let`-bound to an ack-bearing `Wire::` construction in this
/// function body: `name -> variant`.
fn wire_let_bindings(f: &ParsedFile, func: &FnInfo) -> BTreeMap<String, String> {
    let mut bound = BTreeMap::new();
    let toks = &f.toks;
    let (start, end) = func.body;
    let end = end.min(toks.len());
    let mut i = start;
    while i < end {
        if toks[i].kind == Kind::Ident && toks[i].text == "let" {
            // let [mut] name = ... ;
            let mut j = i + 1;
            if j < end && toks[j].text == "mut" {
                j += 1;
            }
            if j < end && toks[j].kind == Kind::Ident && j + 1 < end && toks[j + 1].text == "=" {
                let name = toks[j].text.clone();
                let mut k = j + 2;
                let mut d = 0i64;
                while k < end {
                    let t = toks[k].text.as_str();
                    if t == "(" || t == "[" || t == "{" {
                        d += 1;
                    } else if t == ")" || t == "]" || t == "}" {
                        d -= 1;
                    } else if t == ";" && d == 0 {
                        break;
                    }
                    k += 1;
                }
                for (v, _) in path_variants(toks, (j + 2, k), "Wire") {
                    if ack_record(&v).is_some() {
                        bound.insert(name.clone(), v);
                    }
                }
                i = k;
            }
        }
        i += 1;
    }
    bound
}

/// Run the journal-before-ack analysis over a file set.
pub fn check(files: &[ParsedFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    let name_emits = record_closure(files);
    for f in files {
        if f.path.ends_with("tests.rs") {
            continue;
        }
        for func in &f.fns {
            if func.in_test {
                continue;
            }
            let bound = wire_let_bindings(f, func);
            let mut seen: BTreeSet<String> = BTreeSet::new();
            let toks = &f.toks;
            for i in func.body.0..func.body.1.min(toks.len()) {
                let t = &toks[i];
                if t.kind != Kind::Ident || i + 1 >= toks.len() || toks[i + 1].text != "(" {
                    continue;
                }
                if t.text == "record" && is_method(toks, i) {
                    seen.extend(record_kinds_at(toks, i));
                    seen.insert("*any*".to_string());
                    continue;
                }
                if SENDS.contains(&t.text.as_str()) && is_method(toks, i) {
                    let close = matching_paren(toks, i + 1);
                    let mut sent: Vec<String> = path_variants(toks, (i + 1, close), "Wire")
                        .into_iter()
                        .map(|(v, _)| v)
                        .collect();
                    for k in (i + 2)..close {
                        if toks[k].kind == Kind::Ident {
                            if let Some(v) = bound.get(&toks[k].text) {
                                sent.push(v.clone());
                            }
                        }
                    }
                    for v in &sent {
                        let Some(need) = ack_record(v) else { continue };
                        if seen.contains(need) {
                            continue;
                        }
                        if f.has_marker(t.line, "durability-ok") {
                            continue;
                        }
                        out.push(Violation {
                            file: f.path.clone(),
                            line: t.line,
                            rule: "journal-before-ack",
                            msg: format!(
                                "Wire::{v} sent in `{}` without a preceding \
                                 out.record(Record::{need}) on this path",
                                func.qname
                            ),
                        });
                    }
                    for (p, _) in path_variants(toks, (i + 1, close), "PaxosMsg") {
                        if !PAXOS_PROMISES.contains(&p.as_str()) {
                            continue;
                        }
                        if !seen.is_empty() {
                            continue;
                        }
                        if f.has_marker(t.line, "durability-ok") {
                            continue;
                        }
                        out.push(Violation {
                            file: f.path.clone(),
                            line: t.line,
                            rule: "journal-before-ack",
                            msg: format!(
                                "PaxosMsg::{p} promise reply sent in `{}` with no \
                                 journaling on this path",
                                func.qname
                            ),
                        });
                    }
                    continue;
                }
                // a call into a fn that (transitively) records
                if let Some(ks) = name_emits.get(&t.text) {
                    if !ks.is_empty() {
                        seen.extend(ks.iter().cloned());
                        seen.insert("*any*".to_string());
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf(path: &str, src: &str) -> ParsedFile {
        ParsedFile::parse(path, src)
    }

    fn rules(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    const CLEAN: &str = "
impl Node {
    fn journal_state(&mut self, out: &mut Outbox) {
        if self.cfg.durability {
            out.record(Record::State { s: 1 });
        }
    }
    fn try_ack(&mut self, out: &mut Outbox) {
        self.journal_state(out);
        out.send_staged(Wire::AcceptAck { m, g, bals });
    }
}
";

    #[test]
    fn record_via_helper_call_counts() {
        assert!(check(&[pf("p/x.rs", CLEAN)]).is_empty());
    }

    #[test]
    fn record_after_send_fires() {
        let src = "
impl Node {
    fn try_ack(&mut self, out: &mut Outbox) {
        out.send_staged(Wire::AcceptAck { m, g, bals });
        out.record(Record::State { s: 1 });
    }
}
";
        let vs = check(&[pf("p/x.rs", src)]);
        assert_eq!(rules(&vs), ["journal-before-ack"]);
        assert_eq!(vs[0].line, 4, "flag the send line");
    }

    #[test]
    fn durability_ok_marker_suppresses() {
        let src = "
impl Node {
    fn try_ack(&mut self, out: &mut Outbox) {
        // durability-ok: in-memory baseline, crash-stop only
        out.send(to, Wire::AcceptAck { m, g, bals });
    }
}
";
        assert!(check(&[pf("p/x.rs", src)]).is_empty());
    }

    #[test]
    fn let_bound_ack_is_tracked() {
        let src = "
impl Node {
    fn try_ack(&mut self, out: &mut Outbox) {
        let ack = Wire::AcceptAck { m, g, bals };
        out.send(to, ack);
    }
}
";
        assert_eq!(rules(&check(&[pf("p/x.rs", src)])), ["journal-before-ack"]);
    }

    #[test]
    fn paxos_promise_without_any_record_fires() {
        let src = "
impl Paxos {
    fn on_p2a(&mut self, out: &mut Outbox) {
        out.send(from, Wire::Paxos { g, msg: PaxosMsg::P2b { bal, slot } });
    }
}
";
        let vs = check(&[pf("p/x.rs", src)]);
        assert_eq!(rules(&vs), ["journal-before-ack"]);
        assert!(vs[0].msg.contains("P2b"));
    }

    #[test]
    fn tests_rs_and_test_fns_are_skipped() {
        let src = "
impl Node {
    fn try_ack(&mut self, out: &mut Outbox) {
        out.send(to, Wire::AcceptAck { m });
    }
}
";
        assert!(check(&[pf("p/tests.rs", src)]).is_empty());
        let in_test = "
#[cfg(test)]
mod tests {
    fn try_ack(out: &mut Outbox) {
        out.send(to, Wire::AcceptAck { m });
    }
}
";
        assert!(check(&[pf("p/x.rs", in_test)]).is_empty());
    }
}
