//! Wire exhaustiveness: enum ↔ codec ↔ dispatch cross-check.
//!
//! For every variant of `enum Wire`:
//!
//! * exactly one encoder arm in `codec::encode_into`, whose tag byte is
//!   the first `u8(N)` literal in the arm body;
//! * exactly one decoder arm in `codec::get_wire`, keyed by a unique
//!   integer tag, whose constructed variant is the last `Wire::V` path
//!   in the arm body (arms may build nested values first);
//! * encoder tag == decoder tag;
//! * some protocol `on_wire` dispatches the variant (match arm or
//!   `let Wire::V .. else` binding), unless it is in the exempt list
//!   (runtime framing like `Batch` that nodes never see).
//!
//! This subsumes the old duplicate-tag lint and catches the
//! add-a-variant-forget-a-site class of bug at lint time instead of at
//! the first decode error in a cluster.

use crate::lexer::{Kind, Tok};
use crate::parser::{match_arms, matching_brace, path_variants, Arm, FnInfo, ParsedFile};
use crate::Violation;
use std::collections::BTreeMap;

/// Variant names of `enum <enum_name>` in `f`: idents at brace depth 1
/// and paren depth 0 followed by `,` `{` `(` `}` or `=`.
pub(crate) fn enum_variants(f: &ParsedFile, enum_name: &str) -> Vec<String> {
    let toks = &f.toks;
    if toks.len() < 3 {
        return Vec::new();
    }
    for i in 0..toks.len() - 2 {
        if !(toks[i].kind == Kind::Ident
            && toks[i].text == "enum"
            && toks[i + 1].kind == Kind::Ident
            && toks[i + 1].text == enum_name)
        {
            continue;
        }
        let mut j = i + 2;
        while j < toks.len() && toks[j].text != "{" {
            j += 1;
        }
        if j >= toks.len() {
            return Vec::new();
        }
        let close = matching_brace(toks, j);
        let mut variants = Vec::new();
        let mut d = 0i64;
        let mut pd = 0i64;
        let mut k = j + 1;
        while k < close {
            let t = &toks[k];
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "{" => d += 1,
                    "}" => d -= 1,
                    "(" | "[" => pd += 1,
                    ")" | "]" => pd -= 1,
                    _ => {}
                }
            } else if t.kind == Kind::Ident
                && d == 0
                && pd == 0
                && k + 1 < close
                && matches!(toks[k + 1].text.as_str(), "," | "{" | "(" | "}" | "=")
            {
                variants.push(t.text.clone());
            }
            k += 1;
        }
        return variants;
    }
    Vec::new()
}

fn find_fn<'a>(f: &'a ParsedFile, name: &str) -> Option<&'a FnInfo> {
    f.fns.iter().find(|fn_| fn_.name == name && !fn_.in_test)
}

fn first_match_arms(f: &ParsedFile, func: &FnInfo) -> Vec<Arm> {
    let toks = &f.toks;
    for i in func.body.0..func.body.1.min(toks.len()) {
        if toks[i].kind == Kind::Ident && toks[i].text == "match" {
            return match_arms(toks, i, func.body.1);
        }
    }
    Vec::new()
}

/// Leading decimal digits of a numeric token (`14`, `14u8` -> 14).
fn tag_of(tok: &Tok) -> Option<u64> {
    let digits: String = tok.text.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() {
        None
    } else {
        digits.parse().ok()
    }
}

/// Cross-check `enum Wire` (in `wire_f`) against the codec (`codec_f`)
/// and the protocol dispatchers. `exempt` variants skip the dispatch
/// requirement only.
pub fn check(
    wire_f: &ParsedFile,
    codec_f: &ParsedFile,
    dispatch_files: &[ParsedFile],
    exempt: &[&str],
) -> Vec<Violation> {
    let mut out = Vec::new();
    let viol = |file: &str, line: usize, msg: String| Violation {
        file: file.to_string(),
        line,
        rule: "wire-exhaustive",
        msg,
    };
    let variants = enum_variants(wire_f, "Wire");
    if variants.is_empty() {
        return vec![viol(&wire_f.path, 1, "enum Wire not found".to_string())];
    }

    // encoder arms: Wire::V pattern -> first u8(N) tag in the body
    let mut enc: BTreeMap<String, (Option<u64>, usize)> = BTreeMap::new();
    let Some(func) = find_fn(codec_f, "encode_into") else {
        return vec![viol(&codec_f.path, 1, "encode_into not found".to_string())];
    };
    for arm in first_match_arms(codec_f, func) {
        let pv = path_variants(&codec_f.toks, arm.pat, "Wire");
        let Some((v, _)) = pv.first() else { continue };
        let mut tag = None;
        let (s, e) = arm.body;
        let e = e.min(codec_f.toks.len());
        for k in s..e.saturating_sub(2) {
            if codec_f.toks[k].kind == Kind::Ident
                && codec_f.toks[k].text == "u8"
                && codec_f.toks[k + 1].text == "("
                && codec_f.toks[k + 2].kind == Kind::Num
            {
                tag = tag_of(&codec_f.toks[k + 2]);
                break;
            }
        }
        let line = codec_f.toks[arm.pat.0].line;
        if enc.contains_key(v) {
            out.push(viol(&codec_f.path, line, format!("Wire::{v} has more than one encoder arm")));
        }
        enc.insert(v.clone(), (tag, line));
    }

    // decoder arms: single-integer pattern -> last Wire::V in the body
    let mut dec: BTreeMap<String, (Option<u64>, usize)> = BTreeMap::new();
    let mut dec_tags: Vec<u64> = Vec::new();
    let Some(func) = find_fn(codec_f, "get_wire") else {
        return vec![viol(&codec_f.path, 1, "get_wire not found".to_string())];
    };
    for arm in first_match_arms(codec_f, func) {
        let (s, e) = arm.pat;
        if e - s != 1 || codec_f.toks[s].kind != Kind::Num {
            continue;
        }
        let Some(tag) = tag_of(&codec_f.toks[s]) else { continue };
        let line = codec_f.toks[s].line;
        let bv = path_variants(&codec_f.toks, arm.body, "Wire");
        if dec_tags.contains(&tag) {
            out.push(viol(&codec_f.path, line, format!("duplicate decoder tag {tag} in get_wire")));
        }
        dec_tags.push(tag);
        let Some((v, _)) = bv.last() else {
            out.push(viol(
                &codec_f.path,
                line,
                format!("decoder arm {tag} constructs no Wire variant"),
            ));
            continue;
        };
        if dec.contains_key(v) {
            out.push(viol(&codec_f.path, line, format!("Wire::{v} decoded by more than one arm")));
        }
        dec.insert(v.clone(), (Some(tag), line));
    }

    for v in &variants {
        if !enc.contains_key(v) {
            out.push(viol(&codec_f.path, 1, format!("Wire::{v} has no encoder arm in encode_into")));
        }
        if !dec.contains_key(v) {
            out.push(viol(&codec_f.path, 1, format!("Wire::{v} has no decoder arm in get_wire")));
        }
        if let (Some((et, _)), Some((dt, dline))) = (enc.get(v), dec.get(v)) {
            if et != dt {
                let show = |t: &Option<u64>| t.map_or("?".to_string(), |x| x.to_string());
                out.push(viol(
                    &codec_f.path,
                    *dline,
                    format!("Wire::{v} encoder tag {} != decoder tag {}", show(et), show(dt)),
                ));
            }
        }
    }

    // dispatch coverage: any on_wire match arm or let-else binding
    let mut handled: Vec<String> = Vec::new();
    for f in dispatch_files {
        for func in &f.fns {
            if func.name != "on_wire" || func.in_test {
                continue;
            }
            let toks = &f.toks;
            for i in func.body.0..func.body.1.min(toks.len()) {
                if toks[i].kind == Kind::Ident && toks[i].text == "match" {
                    for arm in match_arms(toks, i, func.body.1) {
                        for (v, _) in path_variants(toks, arm.pat, "Wire") {
                            handled.push(v);
                        }
                    }
                }
                if toks[i].kind == Kind::Ident
                    && toks[i].text == "let"
                    && i + 1 < toks.len()
                    && toks[i + 1].text == "Wire"
                {
                    for (v, _) in path_variants(toks, (i + 1, i + 5), "Wire") {
                        handled.push(v);
                    }
                }
            }
        }
    }
    for v in &variants {
        if exempt.contains(&v.as_str()) {
            continue;
        }
        if !handled.contains(v) {
            out.push(viol(
                &wire_f.path,
                1,
                format!("Wire::{v} is decodable but no protocol on_wire dispatches it"),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE_FIX: &str = "
pub enum Wire {
    A { x: u32 },
    B(Vec<Wire>),
    C,
}
";

    const CODEC_FIX: &str = "
pub fn encode_into(e: &mut Enc, w: &Wire) {
    match w {
        Wire::A { x } => { e.u8(0); e.u32(*x); }
        Wire::B(inner) => { e.u8(1); }
        Wire::C => { e.u8(2); }
    }
}
fn get_wire(d: &mut Dec) -> Result<Wire> {
    Ok(match d.u8()? {
        0 => Wire::A { x: d.u32()? },
        1 => Wire::B(vec![]),
        2 => Wire::C,
        v => return Err(bad(v)),
    })
}
";

    const DISPATCH_FIX: &str = "
impl Node for N {
    fn on_wire(&mut self, from: Pid, wire: Wire, now: u64, out: &mut Outbox) {
        match wire {
            Wire::A { x } => self.on_a(x),
            Wire::C => {}
            _ => {}
        }
    }
}
";

    fn pf(path: &str, src: &str) -> ParsedFile {
        ParsedFile::parse(path, src)
    }

    #[test]
    fn variants_extracted_from_enum() {
        assert_eq!(enum_variants(&pf("w.rs", WIRE_FIX), "Wire"), vec!["A", "B", "C"]);
    }

    #[test]
    fn consistent_fixture_is_clean() {
        let vs = check(&pf("w.rs", WIRE_FIX), &pf("c.rs", CODEC_FIX), &[pf("d.rs", DISPATCH_FIX)], &["B"]);
        assert!(vs.is_empty(), "{vs:#?}");
    }

    #[test]
    fn missing_decoder_arm_fires() {
        let codec = CODEC_FIX.replace("2 => Wire::C,", "");
        let vs = check(&pf("w.rs", WIRE_FIX), &pf("c.rs", &codec), &[pf("d.rs", DISPATCH_FIX)], &["B"]);
        assert!(vs.iter().any(|v| v.msg.contains("no decoder arm")), "{vs:#?}");
    }

    #[test]
    fn duplicate_decoder_tag_fires() {
        let codec = CODEC_FIX.replace("2 => Wire::C,", "1 => Wire::C,");
        let vs = check(&pf("w.rs", WIRE_FIX), &pf("c.rs", &codec), &[pf("d.rs", DISPATCH_FIX)], &["B"]);
        assert!(vs.iter().any(|v| v.msg.contains("duplicate decoder tag")), "{vs:#?}");
    }

    #[test]
    fn encoder_decoder_tag_mismatch_fires() {
        let codec = CODEC_FIX.replace("Wire::C => { e.u8(2); }", "Wire::C => { e.u8(3); }");
        let vs = check(&pf("w.rs", WIRE_FIX), &pf("c.rs", &codec), &[pf("d.rs", DISPATCH_FIX)], &["B"]);
        assert!(vs.iter().any(|v| v.msg.contains("encoder tag 3 != decoder tag 2")), "{vs:#?}");
    }

    #[test]
    fn undispatched_variant_fires() {
        let disp = DISPATCH_FIX.replace("Wire::C => {}", "");
        let vs = check(&pf("w.rs", WIRE_FIX), &pf("c.rs", CODEC_FIX), &[pf("d.rs", &disp)], &["B"]);
        assert!(vs.iter().any(|v| v.msg.contains("no protocol on_wire dispatches")), "{vs:#?}");
    }

    #[test]
    fn let_else_dispatch_counts() {
        let disp = "
impl Client {
    fn on_wire(&mut self, wire: Wire) {
        let Wire::A { x } = wire else { return };
        self.on_a(x);
    }
}
";
        let vs = check(&pf("w.rs", WIRE_FIX), &pf("c.rs", CODEC_FIX), &[pf("d.rs", disp)], &["B", "C"]);
        assert!(vs.is_empty(), "{vs:#?}");
    }
}
