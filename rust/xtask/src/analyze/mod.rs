//! Protocol-aware static analyses: `cargo xtask analyze`.
//!
//! Four analyses, each encoding a whole-protocol invariant that no
//! single-file lint (and no compiler) can check:
//!
//! * [`journal`] — **journal-before-ack**: every send of a
//!   promise-carrying reply (`Wire::AcceptAck`, `Wire::NewLeaderAck`,
//!   `Wire::NewStateAck`, Paxos `P1b`/`P2b`) must be preceded on the
//!   same path by the matching `out.record(..)` call. This is the
//!   paper's core durability obligation: the white-box protocol's
//!   ACCEPT_ACK *is* a Paxos promise, so sending it before journaling
//!   breaks safety across a crash-recover.
//! * [`wire`] — **wire-exhaustive**: every `Wire` enum variant has
//!   exactly one encoder arm, exactly one decoder arm with a unique
//!   tag, matching tags on both sides, and a protocol `on_wire` that
//!   dispatches it.
//! * [`locks`] — **lock-order**: build the held-while-acquiring graph
//!   over sync-facade locks (propagated through the call graph) and
//!   reject cycles, including self-cycles (double acquisition).
//! * [`blocking`] — **blocking-in-loop**: no `sync_all`/`sync_data`/
//!   `fsync_dir`/`sleep` reachable from the event-loop poll paths
//!   outside the designated commit points.
//!
//! Audited exceptions are annotated in source: `// durability-ok:
//! <reason>`, `// lock-ok: <reason>`, `// blocking-ok: <reason>` on the
//! flagged line or the contiguous comment block directly above it.

pub mod blocking;
pub mod journal;
pub mod locks;
pub mod wire;

use crate::lexer::Tok;
use crate::parser::ParsedFile;
use crate::Violation;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Send methods on `Outbox` (and the staged variant): the analyzer
/// treats any `.<name>(` of these as a message send.
pub(crate) const SENDS: &[&str] = &["send", "send_staged", "send_to_many"];

/// `toks[open_idx]` must be `(`; index of the matching `)` (or len).
pub(crate) fn matching_paren(toks: &[Tok], open_idx: usize) -> usize {
    let mut d = 0i64;
    let mut i = open_idx;
    while i < toks.len() {
        let t = toks[i].text.as_str();
        if t == "(" {
            d += 1;
        } else if t == ")" {
            d -= 1;
            if d == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len()
}

/// True when token `i` is preceded by `.` (a method call receiver).
pub(crate) fn is_method(toks: &[Tok], i: usize) -> bool {
    i > 0 && toks[i - 1].text == "."
}

/// `(file index, fn index)` — identity of a function across a file set.
pub(crate) type FnKey = (usize, usize);

/// Propagate per-function string sets (record kinds, lock idents, sent
/// wire variants) through the name-based call graph to a fixpoint:
/// a function's set absorbs the sets of everything it calls,
/// transitively.
pub(crate) fn close_over_calls(
    direct: BTreeMap<FnKey, BTreeSet<String>>,
    callees: &BTreeMap<FnKey, BTreeSet<String>>,
    by_name: &BTreeMap<String, Vec<FnKey>>,
) -> BTreeMap<FnKey, BTreeSet<String>> {
    let mut sets = direct;
    let keys: Vec<FnKey> = callees.keys().copied().collect();
    let mut changed = true;
    while changed {
        changed = false;
        for key in &keys {
            let mut add: Vec<String> = Vec::new();
            if let (Some(names), Some(cur)) = (callees.get(key), sets.get(key)) {
                for nm in names {
                    if let Some(cks) = by_name.get(nm) {
                        for ck in cks {
                            if let Some(s) = sets.get(ck) {
                                for v in s {
                                    if !cur.contains(v) {
                                        add.push(v.clone());
                                    }
                                }
                            }
                        }
                    }
                }
            }
            if !add.is_empty() {
                if let Some(e) = sets.get_mut(key) {
                    let before = e.len();
                    e.extend(add);
                    if e.len() > before {
                        changed = true;
                    }
                }
            }
        }
    }
    sets
}

/// Files scanned by the lock-order analysis: everything using the
/// `crate::sync` facade plus the real-atomics event loops.
pub(crate) const LOCK_FILES: &[&str] = &[
    "rust/src/coordinator/mod.rs",
    "rust/src/net/mod.rs",
    "rust/src/net/epoll.rs",
    "rust/src/net/uring.rs",
    "rust/src/storage/mod.rs",
    "rust/src/protocols/outbox.rs",
];

/// Files scanned by the blocking-call analysis.
pub(crate) const BLOCK_FILES: &[&str] = &[
    "rust/src/net/epoll.rs",
    "rust/src/net/uring.rs",
    "rust/src/net/mod.rs",
    "rust/src/coordinator/mod.rs",
    "rust/src/storage/mod.rs",
];

/// Event-loop entry points for the blocking-call analysis: everything
/// reachable from these (minus designated commit points) must not
/// block.
pub(crate) const LOOP_ENTRIES: &[(&str, &str)] = &[
    ("net/epoll.rs", "EventLoop::run"),
    ("net/uring.rs", "EventLoop::run"),
    ("coordinator/mod.rs", "InlineLoop::route"),
    ("coordinator/mod.rs", "InlineLoop::drain_effects"),
];

/// Wire variants no protocol `on_wire` needs to dispatch. `Batch` is
/// transport framing: it is unpacked by the runtime before any node
/// sees it.
pub(crate) const DISPATCH_EXEMPT: &[&str] = &["Batch"];

pub(crate) fn parse_rel(root: &Path, rel: &str) -> Option<ParsedFile> {
    let src = std::fs::read_to_string(root.join(rel)).ok()?;
    Some(ParsedFile::parse(rel, &src))
}

fn missing(rel: &str) -> Violation {
    Violation {
        file: rel.to_string(),
        line: 1,
        rule: "analyze",
        msg: "file not found (moved? update xtask analyze file sets)".to_string(),
    }
}

/// Run all four analyses over the real tree, sorted by (file, line).
pub fn run_all(root: &Path) -> Vec<Violation> {
    let mut vs: Vec<Violation> = Vec::new();

    // 1. journal-before-ack over the protocol core + the Paxos substrate
    let mut jfiles: Vec<ParsedFile> = Vec::new();
    for rel in crate::rs_files_under(root, "rust/src/protocols") {
        if rel.ends_with("tests.rs") {
            continue;
        }
        match parse_rel(root, &rel) {
            Some(f) => jfiles.push(f),
            None => vs.push(missing(&rel)),
        }
    }
    match parse_rel(root, "rust/src/paxos/mod.rs") {
        Some(f) => jfiles.push(f),
        None => vs.push(missing("rust/src/paxos/mod.rs")),
    }
    vs.extend(journal::check(&jfiles));

    // 2. wire exhaustiveness: enum <-> codec <-> dispatch
    let wire_f = parse_rel(root, "rust/src/types/wire.rs");
    let codec_f = parse_rel(root, "rust/src/codec/mod.rs");
    match (wire_f, codec_f) {
        (Some(wf), Some(cf)) => {
            let mut disp: Vec<ParsedFile> = Vec::new();
            for rel in crate::rs_files_under(root, "rust/src/protocols") {
                if rel.ends_with("tests.rs") {
                    continue;
                }
                if let Some(f) = parse_rel(root, &rel) {
                    disp.push(f);
                }
            }
            match parse_rel(root, "rust/src/client/mod.rs") {
                Some(f) => disp.push(f),
                None => vs.push(missing("rust/src/client/mod.rs")),
            }
            vs.extend(wire::check(&wf, &cf, &disp, DISPATCH_EXEMPT));
        }
        _ => {
            vs.push(missing("rust/src/types/wire.rs or rust/src/codec/mod.rs"));
        }
    }

    // 3. lock-order over the facade modules
    let mut lfiles: Vec<ParsedFile> = Vec::new();
    for rel in LOCK_FILES {
        match parse_rel(root, rel) {
            Some(f) => lfiles.push(f),
            None => vs.push(missing(rel)),
        }
    }
    vs.extend(locks::check(&lfiles));

    // 4. blocking calls reachable from event loops
    let mut bfiles: Vec<ParsedFile> = Vec::new();
    for rel in BLOCK_FILES {
        match parse_rel(root, rel) {
            Some(f) => bfiles.push(f),
            None => vs.push(missing(rel)),
        }
    }
    vs.extend(blocking::check(&bfiles, LOOP_ENTRIES));

    vs.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    vs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::ParsedFile;

    /// The analyzer's own acceptance: the real tree is clean.
    #[test]
    fn analyze_clean_tree() {
        let root = crate::repo_root();
        assert!(root.join("rust/src/lib.rs").exists(), "repo root misdetected: {root:?}");
        let vs = run_all(&root);
        assert!(vs.is_empty(), "analyze violations on clean tree: {vs:#?}");
    }

    /// Liveness proof for the journal rule against the *real* handlers:
    /// strip the `out.record(` calls from the wbcast recovery path and
    /// the NEWLEADER_ACK / NEWSTATE_ACK sends must both be flagged.
    #[test]
    fn journal_rule_fires_on_mutated_recovery() {
        let root = crate::repo_root();
        let rec = std::fs::read_to_string(root.join("rust/src/protocols/wbcast/recovery.rs"))
            .expect("read recovery.rs");
        let mutated = rec.replace("out.record(", "self.skip_record(");
        assert_ne!(rec, mutated, "mutation must change something");
        let modsrc = std::fs::read_to_string(root.join("rust/src/protocols/wbcast/mod.rs"))
            .expect("read wbcast mod.rs");
        let files = vec![
            ParsedFile::parse("rust/src/protocols/wbcast/recovery.rs", &mutated),
            ParsedFile::parse("rust/src/protocols/wbcast/mod.rs", &modsrc),
        ];
        let vs = journal::check(&files);
        assert!(
            vs.iter().any(|v| v.msg.contains("NewLeaderAck")),
            "promise-journal gap on NewLeaderAck not caught: {vs:#?}"
        );
        assert!(
            vs.iter().any(|v| v.msg.contains("NewStateAck")),
            "promise-journal gap on NewStateAck not caught: {vs:#?}"
        );

        // ... and the unmutated pair is clean
        let clean = vec![
            ParsedFile::parse("rust/src/protocols/wbcast/recovery.rs", &rec),
            ParsedFile::parse("rust/src/protocols/wbcast/mod.rs", &modsrc),
        ];
        assert!(journal::check(&clean).is_empty());
    }

    #[test]
    fn close_over_calls_reaches_transitive_callees() {
        // a -> b -> c, only c has a direct fact
        let mk = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<BTreeSet<_>>();
        let direct = BTreeMap::from([((0, 0), mk(&[])), ((0, 1), mk(&[])), ((0, 2), mk(&["K"]))]);
        let callees =
            BTreeMap::from([((0, 0), mk(&["b"])), ((0, 1), mk(&["c"])), ((0, 2), mk(&[]))]);
        let by_name = BTreeMap::from([
            ("a".to_string(), vec![(0usize, 0usize)]),
            ("b".to_string(), vec![(0, 1)]),
            ("c".to_string(), vec![(0, 2)]),
        ]);
        let closed = close_over_calls(direct, &callees, &by_name);
        assert!(closed[&(0, 0)].contains("K"), "fact must flow a <- b <- c");
    }
}
