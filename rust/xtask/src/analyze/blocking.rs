//! Blocking calls reachable from event-loop poll paths.
//!
//! The epoll/io_uring event loops and the coordinator's `InlineLoop`
//! dispatch must never block: an fsync on the poll thread stalls every
//! connection. Durability I/O is allowed only behind the designated
//! commit points (`append_records` / `commit_records` / `commit`),
//! which batch and amortize their syncs by design — the reachability
//! walk stops at those names.
//!
//! The walk is a DFS over the name-based call graph from each
//! configured entry function, preferring same-file candidates when a
//! name is ambiguous, and reports every `sync_all`/`sync_data`/
//! `fsync_dir`/`sleep` call site it can reach together with the call
//! chain that reaches it. `// blocking-ok: <reason>` on the site (or on
//! a call line, to prune that edge) suppresses.

use crate::lexer::Kind;
use crate::parser::{calls_in, FnInfo, ParsedFile};
use crate::Violation;
use std::collections::BTreeMap;

const BLOCKING: &[&str] = &["sync_all", "sync_data", "fsync_dir", "sleep"];
const DESIGNATED: &[&str] = &["append_records", "commit_records", "commit"];

type FnKey = (usize, usize);

/// `(name, line)` of unannotated blocking call sites in the body.
fn direct_blocking(f: &ParsedFile, func: &FnInfo) -> Vec<(String, usize)> {
    let toks = &f.toks;
    let mut out = Vec::new();
    for i in func.body.0..func.body.1.min(toks.len()) {
        let t = &toks[i];
        if t.kind == Kind::Ident
            && BLOCKING.contains(&t.text.as_str())
            && i + 1 < toks.len()
            && toks[i + 1].text == "("
        {
            if f.has_marker(t.line, "blocking-ok") {
                continue;
            }
            out.push((t.text.clone(), t.line));
        }
    }
    out
}

/// Run the blocking-in-loop analysis. `entries` is a list of
/// `(path suffix, qualified fn name)` event-loop entry points.
pub fn check(files: &[ParsedFile], entries: &[(&str, &str)]) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut by_name: BTreeMap<String, Vec<FnKey>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (fni, func) in f.fns.iter().enumerate() {
            if func.in_test {
                continue;
            }
            by_name.entry(func.name.clone()).or_default().push((fi, fni));
        }
    }

    let resolve = |caller_fi: usize, name: &str| -> Vec<FnKey> {
        if DESIGNATED.contains(&name) {
            return Vec::new();
        }
        let Some(cands) = by_name.get(name) else { return Vec::new() };
        let same: Vec<FnKey> = cands.iter().copied().filter(|c| c.0 == caller_fi).collect();
        if same.is_empty() {
            cands.clone()
        } else {
            same
        }
    };

    for (suffix, qname) in entries {
        let mut entry: Option<FnKey> = None;
        for (fi, f) in files.iter().enumerate() {
            if !f.path.ends_with(suffix) {
                continue;
            }
            for (fni, func) in f.fns.iter().enumerate() {
                if !func.in_test && func.qname == *qname {
                    entry = Some((fi, fni));
                }
            }
        }
        let Some(entry) = entry else {
            out.push(Violation {
                file: suffix.to_string(),
                line: 1,
                rule: "blocking-in-loop",
                msg: format!("entry fn `{qname}` not found (renamed? update xtask)"),
            });
            continue;
        };
        let mut stack: Vec<(FnKey, Vec<String>)> = vec![(entry, vec![qname.to_string()])];
        let mut visited: Vec<FnKey> = vec![entry];
        while let Some(((fi, fni), chain)) = stack.pop() {
            let f = &files[fi];
            let func = &f.fns[fni];
            for (name, line) in direct_blocking(f, func) {
                out.push(Violation {
                    file: f.path.clone(),
                    line,
                    rule: "blocking-in-loop",
                    msg: format!(
                        "blocking `{name}()` reachable from event loop: {}",
                        chain.join(" -> ")
                    ),
                });
            }
            for (cname, ci) in calls_in(&f.toks, func.body) {
                if f.has_marker(f.toks[ci].line, "blocking-ok") {
                    continue;
                }
                for key in resolve(fi, &cname) {
                    if !visited.contains(&key) {
                        visited.push(key);
                        let mut c2 = chain.clone();
                        c2.push(files[key.0].fns[key.1].qname.clone());
                        stack.push((key, c2));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf(path: &str, src: &str) -> ParsedFile {
        ParsedFile::parse(path, src)
    }

    #[test]
    fn fsync_reachable_from_run_fires_with_chain() {
        let src = "
impl EventLoop {
    fn run(mut self) {
        loop {
            self.on_readable();
        }
    }
    fn on_readable(&mut self) {
        self.file.sync_data().unwrap();
    }
}
";
        let vs =
            check(&[pf("net/epoll.rs", src)], &[("net/epoll.rs", "EventLoop::run")]);
        assert_eq!(vs.len(), 1, "{vs:#?}");
        assert_eq!(vs[0].rule, "blocking-in-loop");
        assert!(
            vs[0].msg.contains("EventLoop::run -> EventLoop::on_readable"),
            "chain missing: {}",
            vs[0].msg
        );
    }

    #[test]
    fn designated_commit_point_stops_the_walk() {
        let src = "
impl EventLoop {
    fn run(mut self) {
        loop {
            commit_records(&mut self.storage);
        }
    }
}
fn commit_records(s: &mut Storage) {
    s.file.sync_data().unwrap();
}
";
        assert!(check(&[pf("net/epoll.rs", src)], &[("net/epoll.rs", "EventLoop::run")])
            .is_empty());
    }

    #[test]
    fn missing_entry_is_loud_not_silent() {
        let vs = check(&[pf("net/epoll.rs", "fn other() {}")], &[("net/epoll.rs", "EventLoop::run")]);
        assert_eq!(vs.len(), 1);
        assert!(vs[0].msg.contains("not found"));
    }

    #[test]
    fn blocking_ok_marker_suppresses_site() {
        let src = "
impl EventLoop {
    fn run(mut self) {
        // blocking-ok: startup only, before the loop is entered
        std::thread::sleep(d);
    }
}
";
        assert!(check(&[pf("net/epoll.rs", src)], &[("net/epoll.rs", "EventLoop::run")])
            .is_empty());
    }
}
